from repro.configs.base import (ALIASES, ARCH_IDS, INPUT_SHAPES, InputShape,
                                ModelConfig, MoEConfig, SSMConfig,
                                VisionStubConfig, all_configs, get_config)

__all__ = [
    "ALIASES", "ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "MoEConfig", "SSMConfig", "VisionStubConfig", "all_configs", "get_config",
]
