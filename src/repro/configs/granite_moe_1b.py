"""granite-moe-1b-a400m [moe] — 32 experts top-8, fine-grained. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8),
    long_context_variant="sliding",
    notes="fine-grained experts (d_ff=512); 2 experts per model shard",
)
