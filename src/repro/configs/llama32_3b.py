"""llama3.2-3b [dense] — small llama3: RMSNorm + SwiGLU + GQA. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=128256,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    long_context_variant="sliding",
)
