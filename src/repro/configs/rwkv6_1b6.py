"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / head_dim 64 WKV heads
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab_size=65536,
    norm="layernorm",
    mlp="relu_sq",         # rwkv channel-mix uses squared relu
    ssm=SSMConfig(head_dim=64),
    long_context_variant="native",   # recurrent state => O(1) per token
    notes="attention-free WKV recurrence; runs long_500k natively",
)
