"""Config system: model/arch configs, input shapes, and reduced smoke variants.

Every assigned architecture gets one file in this package exporting
``CONFIG``.  ``repro.configs.get_config(name)`` resolves them; reduced smoke
variants for CPU tests come from ``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    router_z_coef: float = 1e-3   # router z-loss coefficient


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State-space / RWKV recurrence parameters."""
    state_dim: int = 16        # per-channel state (mamba) / ignored by rwkv
    head_dim: int = 64         # rwkv6 head size
    conv_dim: int = 4          # mamba depthwise conv width
    expand: int = 2            # mamba inner expansion
    dt_rank: int = 0           # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """VLM/audio modality frontend stub: precomputed embeddings only."""
    n_tokens: int = 0          # patch/frame tokens provided by input_specs()
    embed_dim: int = 0         # frontend embedding dim (pre-projector)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str
    source: str                # citation bracket from the assignment table
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # --- block flavour ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu | relu_sq
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # stablelm uses partial rotary
    sliding_window: Optional[int] = None  # static SWA (hymba)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[VisionStubConfig] = None
    # audio (enc-dec) only
    n_encoder_layers: int = 0
    max_source_positions: int = 0
    # hybrid (hymba): number of learnable meta tokens prepended to the prompt
    n_meta_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    # serving-side options
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8
    long_context_variant: str = "none" # none | sliding | native
    long_context_window: int = 8192
    # notes for DESIGN.md §Arch-applicability
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def attn_out_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_out_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    @property
    def is_encoder_decoder(self) -> bool:
        return self.arch_type == "audio"

    @property
    def supports_long_context(self) -> bool:
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.long_context_variant != "none"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), ignores tiny biases."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
        attn = d * self.attn_out_dim + 2 * d * self.kv_out_dim + self.attn_out_dim * d
        if self.mlp == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.moe is not None:
            ffn = ffn * self.moe.n_experts + d * self.moe.n_experts
        block = attn + ffn
        if self.arch_type == "ssm":       # rwkv6: no attention, wkv mixing
            block = 6 * d * d + ffn       # r,k,v,g,w,out projections
        if self.arch_type == "hybrid" and self.ssm is not None:
            block += 3 * d * d * self.ssm.expand // 2
        total = emb + L * block
        if self.is_encoder_decoder:
            total += self.n_encoder_layers * block
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full_ffn = (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
        dead = L * full_ffn * (self.moe.n_experts - self.moe.top_k)
        return self.param_count() - dead

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU smoke variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        d_head = 32
        n_heads = max(2, d_model // 64)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep the GQA-vs-MHA character of the original
        if self.n_kv_heads == self.n_heads:
            n_kv = n_heads
        else:
            n_kv = max(1, n_heads // 2)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, n_experts=4,
                                      top_k=min(self.moe.top_k, 2))
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, head_dim=32, state_dim=min(ssm.state_dim, 8))
        if self.arch_type == "ssm" and ssm is not None:
            # rwkv: WKV heads tile d_model exactly
            n_heads = n_kv = d_model // ssm.head_dim
        frontend = None
        if self.frontend is not None:
            frontend = VisionStubConfig(n_tokens=min(self.frontend.n_tokens, 16),
                                        embed_dim=64)
        return dataclasses.replace(
            self, n_layers=2, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            d_head=d_head, d_ff=min(self.d_ff, 512), vocab_size=min(self.vocab_size, 512),
            moe=moe, ssm=ssm, frontend=frontend,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            max_source_positions=min(self.max_source_positions, 64) if self.max_source_positions else 0,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            sliding_window=(64 if self.sliding_window else None),
            long_context_window=256,
            dtype="float32", kv_cache_dtype="float32")


# ----------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = (
    "llava_next_34b", "stablelm_3b", "llama32_3b", "rwkv6_1b6", "hymba_1b5",
    "smollm_360m", "whisper_tiny", "phi35_moe", "qwen15_32b", "granite_moe_1b",
)
# CLI aliases matching the assignment table spelling.
ALIASES = {
    "llava-next-34b": "llava_next_34b",
    "stablelm-3b": "stablelm_3b",
    "llama3.2-3b": "llama32_3b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "hymba-1.5b": "hymba_1b5",
    "smollm-360m": "smollm_360m",
    "whisper-tiny": "whisper_tiny",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "qwen1.5-32b": "qwen15_32b",
    "granite-moe-1b-a400m": "granite_moe_1b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Tuple[ModelConfig, ...]:
    return tuple(get_config(a) for a in ARCH_IDS)
