"""hymba-1.5b [hybrid] — parallel attention + mamba heads, SWA + meta tokens. [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    norm="rmsnorm",
    mlp="swiglu",
    sliding_window=1024,      # most layers use SWA in the paper
    n_meta_tokens=128,
    ssm=SSMConfig(state_dim=16, expand=2, conv_dim=4),
    long_context_variant="native",   # SSM state + SWA => sub-quadratic
    notes="parallel attn+mamba heads fused per layer; 128 learnable meta tokens",
)
