"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab_size=32064,
    norm="layernorm",
    mlp="swiglu",
    moe=MoEConfig(n_experts=16, top_k=2),
    long_context_variant="sliding",
    notes="16 experts map 1:1 onto the 16-way model axis (pure expert parallel)",
)
