"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_head=64,
    d_ff=2560,
    vocab_size=49152,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
    long_context_variant="sliding",
)
