"""stablelm-3b [dense] — MHA, partial rotary, LayerNorm. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    mlp="swiglu",
    qkv_bias=True,
    rotary_pct=0.25,
    long_context_variant="sliding",
)
