"""whisper-tiny [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356]

The mel-spectrogram + conv1d feature extractor is a stub per the carve-out:
``input_specs`` provides 1500 precomputed frame embeddings (d=384).
"""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=4,               # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    max_source_positions=1500,
    frontend=VisionStubConfig(n_tokens=1500, embed_dim=384),
    tie_embeddings=True,
    long_context_variant="none",
    notes="enc-dec; long_500k skipped (decoder context architecturally bounded)",
)
