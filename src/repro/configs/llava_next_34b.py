"""llava-next-34b [vlm] — anyres tiling, GQA decoder backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] (scaled per assignment table).
Vision tower (ViT) is a stub per the carve-out: ``input_specs`` provides
precomputed anyres patch embeddings; we implement the projector + decoder.
"""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab_size=64000,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=5_000_000.0,
    # anyres tiling: base 24x24 grid + up to 4 tiles -> 5 * 576 = 2880 patch
    # tokens after projection (CLIP-ViT-L/14 @ 336px, embed 1024).
    frontend=VisionStubConfig(n_tokens=2880, embed_dim=1024),
    long_context_variant="sliding",   # enables long_500k decode (documented deviation)
    long_context_window=8192,
    notes="anyres tiling; vision encoder stubbed (precomputed patch embeddings)",
)
