"""qwen1.5-32b [dense] — QKV bias, near-MHA (kv=40). [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27392,
    vocab_size=152064,
    norm="rmsnorm",
    mlp="swiglu",
    qkv_bias=True,
    long_context_variant="sliding",
    kv_cache_dtype="int8",   # 40 MHA kv heads @32k x 128 batch does not fit bf16
    notes="int8 KV cache required for decode_32k memory (see EXPERIMENTS.md)",
)
