"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in interpret mode, which is how correctness is
validated here) and to False on TPU, where the Mosaic-compiled kernels are
the production hot path.
"""
from __future__ import annotations

import jax

from repro.kernels.ttt_probe import make_unroll_kernel, ttt_probe_scan
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import flash_decode
from repro.kernels.rwkv6_scan import wkv_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


__all__ = ["ttt_probe_scan", "make_unroll_kernel", "flash_attention",
           "flash_decode", "wkv_scan", "on_tpu", "default_interpret"]
