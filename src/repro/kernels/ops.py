"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in interpret mode, which is how correctness is
validated here) and to False on TPU, where the Mosaic-compiled kernels are
the production hot path.  ``REPRO_PALLAS_INTERPRET=1|0`` overrides the
autodetection — CI's kernel-parity job forces ``1`` so the fused serving
step is exercised through the Pallas machinery on every PR.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.ttt_probe import (ProbeStepOut, SpecProbeOut,
                                     make_unroll_kernel, serving_probe_step,
                                     serving_probe_spec_step,
                                     ttt_probe_batched, ttt_probe_scan)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import (flash_decode, paged_flash_decode,
                                             paged_flash_packed_chunk,
                                             paged_flash_prefill_chunk)
from repro.kernels.rwkv6_scan import wkv_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None and forced != "":
        return forced not in ("0", "false", "False")
    return not on_tpu()


__all__ = ["ProbeStepOut", "SpecProbeOut", "ttt_probe_scan",
           "ttt_probe_batched", "make_unroll_kernel", "serving_probe_step",
           "serving_probe_spec_step", "flash_attention",
           "flash_decode", "paged_flash_decode", "paged_flash_packed_chunk",
           "paged_flash_prefill_chunk", "wkv_scan", "on_tpu",
           "default_interpret"]
