"""Fused TTT-probe inner-loop scan — Pallas TPU kernel.

The paper's hot loop (Algorithm 2 lines 8-16): for each trajectory, at every
step score with the current fast weights, then apply one Brier-gradient
update.  The recurrence is sequential in T, so the kernel exploits the TPU
grid's sequential-iteration order: grid = (N, T/T_CHUNK); the fast weights
(W, b) live in VMEM scratch and persist across the T-chunks of one
trajectory while phi-chunks stream HBM->VMEM.  This is the same adaptation
TTT-linear uses on TPU (DESIGN.md §3) — on GPU this loop is a per-step
kernel launch or a fori_loop over HBM; on TPU the whole trajectory's
adaptation runs out of VMEM.

Layouts (f = feature dim, padded to a multiple of 128):
    zq, zk : (N, T, f)   score / update views of the step features
    c      : (N, T)      inner labels (zeros at deployment)
    m      : (N, T)      validity mask (freezes updates on padding)
    -> scores (N, T), W_final (N, f), b_final (N, 1)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_T_CHUNK = 128


def _kernel(zq_ref, zk_ref, c_ref, m_ref, w0_ref, b0_ref, eta_ref,
            scores_ref, wf_ref, bf_ref, w_s, b_s, *, t_chunk: int,
            n_chunks: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        w_s[...] = w0_ref[...]
        b_s[0, 0] = b0_ref[0]

    eta = eta_ref[0]

    def step(i, _):
        w = w_s[...]                                  # (1, f)
        b = b_s[0, 0]
        zq = zq_ref[0, i, :][None, :]                 # (1, f)
        zk = zk_ref[0, i, :][None, :]
        s_q = jax.nn.sigmoid(jnp.sum(zq * w) + b)
        scores_ref[0, i] = s_q
        # Brier-gradient update on the K view (score-then-update)
        s_k = jax.nn.sigmoid(jnp.sum(zk * w) + b)
        coeff = 2.0 * (s_k - c_ref[0, i]) * s_k * (1.0 - s_k)
        coeff = coeff * m_ref[0, i] * eta
        w_s[...] = w - coeff * zk
        b_s[0, 0] = b - coeff
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)

    @pl.when(t_idx == n_chunks - 1)
    def _fin():
        wf_ref[...] = w_s[...]
        bf_ref[0, 0] = b_s[0, 0]


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def ttt_probe_scan(zq, zk, c, m, w0, b0, eta, *, t_chunk: int = DEFAULT_T_CHUNK,
                   interpret: bool = True):
    """Run the fused inner-loop scan for a batch of trajectories.

    zq/zk (N, T, f) f32; c/m (N, T) f32; w0 (f,); b0, eta scalars.
    Returns (scores (N, T), w_final (N, f), b_final (N,)).
    """
    n, t, f = zq.shape
    t_chunk = min(t_chunk, t)
    if t % t_chunk:
        pad = t_chunk - t % t_chunk
        zq = jnp.pad(zq, ((0, 0), (0, pad), (0, 0)))
        zk = jnp.pad(zk, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad)))
        m = jnp.pad(m, ((0, 0), (0, pad)))
    t_pad = zq.shape[1]
    n_chunks = t_pad // t_chunk
    f32 = jnp.float32
    kernel = functools.partial(_kernel, t_chunk=t_chunk, n_chunks=n_chunks)
    scores, wf, bf = pl.pallas_call(
        kernel,
        grid=(n, n_chunks),
        in_specs=[
            pl.BlockSpec((1, t_chunk, f), lambda i, j: (i, j, 0)),   # zq
            pl.BlockSpec((1, t_chunk, f), lambda i, j: (i, j, 0)),   # zk
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # c
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # m
            pl.BlockSpec((1, f), lambda i, j: (0, 0)),               # w0
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # b0
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # eta
        ],
        out_specs=[
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # scores
            pl.BlockSpec((1, f), lambda i, j: (i, 0)),               # w_final
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),               # b_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t_pad), f32),
            jax.ShapeDtypeStruct((n, f), f32),
            jax.ShapeDtypeStruct((n, 1), f32),
        ],
        scratch_shapes=[pltpu.VMEM((1, f), f32), pltpu.VMEM((1, 1), f32)],
        interpret=interpret,
    )(zq.astype(f32), zk.astype(f32), c.astype(f32), m.astype(f32),
      w0.astype(f32)[None, :], b0.reshape(1).astype(f32),
      eta.reshape(1).astype(f32))
    return scores[:, :t], wf, bf[:, 0]


def make_unroll_kernel(t_chunk: int = DEFAULT_T_CHUNK, interpret: bool = True):
    """Adapter with the signature repro.core.ttt.inner_unroll expects:
    (zq, zk, c, m, W0, b0, eta) -> (scores, W_f, b_f) for ONE trajectory."""
    def kern(zq, zk, c, m, w0, b0, eta):
        s, wf, bf = ttt_probe_scan(zq[None], zk[None], c[None], m[None],
                                   w0, jnp.asarray(b0), jnp.asarray(eta),
                                   t_chunk=t_chunk, interpret=interpret)
        return s[0], wf[0], bf[0]
    return kern
