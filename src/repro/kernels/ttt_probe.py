"""Fused TTT-probe kernels — Pallas TPU.

Two entry points share one inner formula (``repro.core.probe.score_then_update``):

* ``ttt_probe_scan`` / ``ttt_probe_batched`` — the OFFLINE scan over whole
  trajectories (Algorithm 2 lines 8-16, meta-eval / LTT calibration).  The
  recurrence is sequential in T, so the kernel exploits the TPU grid's
  sequential-iteration order: grid = (N, T/T_CHUNK); the fast weights (W, b)
  live in VMEM scratch and persist across the T-chunks of one trajectory
  while phi-chunks stream HBM->VMEM.  ``ttt_probe_batched`` is the
  vector-state generalization: every trajectory starts from its OWN (W_i,
  b_i) — the chunked multi-step building block for multi-token serving.
* ``serving_probe_step`` — the SERVING hot path: one batched decode step for
  all engine slots, fusing score-then-update with the rolling-window
  smoothing and the calibrated threshold test (the full per-step deployed
  procedure).  The per-slot state (W, b, ring, counters) stays in VMEM for
  the step; the engine jit donates the buffers so XLA updates them in place.

The deployed procedure — decode + probe + threshold — is exactly what gets
LTT-calibrated, so the serving engine routes through these kernels instead
of re-implementing the probe (the PR-1 jnp path survives only as the parity
oracle in ``repro.kernels.ref``).  ``interpret=True`` (the CPU-CI default,
see ``repro.kernels.ops.default_interpret``) executes the same kernel bodies
as plain jax ops.

Layouts (f = feature dim; pad to a multiple of 128 for compiled TPU):
    zq, zk : (N, T, f)   score / update views of the step features
    c      : (N, T)      inner labels (zeros at deployment)
    m      : (N, T)      validity mask (freezes updates on padding)
    -> scores (N, T), W_final (N, f), b_final (N, 1)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import probe as P

DEFAULT_T_CHUNK = 128


def _kernel(zq_ref, zk_ref, c_ref, m_ref, w0_ref, b0_ref, eta_ref,
            scores_ref, wf_ref, bf_ref, w_s, b_s, *, t_chunk: int,
            n_chunks: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        w_s[...] = w0_ref[...]
        b_s[0, 0] = b0_ref[0, 0]

    eta = eta_ref[0]

    def step(i, _):
        w = w_s[...]                                  # (1, f)
        b = b_s[0, 0]
        zq = zq_ref[0, i, :][None, :]                 # (1, f)
        zk = zk_ref[0, i, :][None, :]
        s, w_new, b_new = P.score_then_update(w, b, zq, zk, c_ref[0, i],
                                              m_ref[0, i], eta)
        scores_ref[0, i] = s[0]
        w_s[...] = w_new
        b_s[0, 0] = b_new[0]
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)

    @pl.when(t_idx == n_chunks - 1)
    def _fin():
        wf_ref[...] = w_s[...]
        bf_ref[0, 0] = b_s[0, 0]


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def ttt_probe_batched(zq, zk, c, m, w0, b0, eta, *,
                      t_chunk: int = DEFAULT_T_CHUNK, interpret: bool = True):
    """Chunked multi-step scan with a VECTOR per-trajectory initial state.

    zq/zk (N, T, f); c/m (N, T); w0 (N, f); b0 (N,); eta scalar.
    Returns (scores (N, T), w_final (N, f), b_final (N,)).  Running two
    chunks back to back with the carried (w, b) equals one longer scan —
    the building block for multi-token serving steps.
    """
    n, t, f = zq.shape
    t_chunk = min(t_chunk, t)
    if t % t_chunk:
        pad = t_chunk - t % t_chunk
        zq = jnp.pad(zq, ((0, 0), (0, pad), (0, 0)))
        zk = jnp.pad(zk, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad)))
        m = jnp.pad(m, ((0, 0), (0, pad)))
    t_pad = zq.shape[1]
    n_chunks = t_pad // t_chunk
    f32 = jnp.float32
    kernel = functools.partial(_kernel, t_chunk=t_chunk, n_chunks=n_chunks)
    scores, wf, bf = pl.pallas_call(
        kernel,
        grid=(n, n_chunks),
        in_specs=[
            pl.BlockSpec((1, t_chunk, f), lambda i, j: (i, j, 0)),   # zq
            pl.BlockSpec((1, t_chunk, f), lambda i, j: (i, j, 0)),   # zk
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # c
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # m
            pl.BlockSpec((1, f), lambda i, j: (i, 0)),               # w0
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),               # b0
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # eta
        ],
        out_specs=[
            pl.BlockSpec((1, t_chunk), lambda i, j: (i, j)),         # scores
            pl.BlockSpec((1, f), lambda i, j: (i, 0)),               # w_final
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),               # b_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t_pad), f32),
            jax.ShapeDtypeStruct((n, f), f32),
            jax.ShapeDtypeStruct((n, 1), f32),
        ],
        scratch_shapes=[pltpu.VMEM((1, f), f32), pltpu.VMEM((1, 1), f32)],
        interpret=interpret,
    )(zq.astype(f32), zk.astype(f32), c.astype(f32), m.astype(f32),
      w0.astype(f32), b0.reshape(n, 1).astype(f32),
      eta.reshape(1).astype(f32))
    return scores[:, :t], wf, bf[:, 0]


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def ttt_probe_scan(zq, zk, c, m, w0, b0, eta, *, t_chunk: int = DEFAULT_T_CHUNK,
                   interpret: bool = True):
    """Offline scan with a SHARED initial state (the meta-learned (W0, b0)).

    zq/zk (N, T, f) f32; c/m (N, T) f32; w0 (f,); b0, eta scalars.
    Returns (scores (N, T), w_final (N, f), b_final (N,)).  Thin broadcast
    over ``ttt_probe_batched`` — one kernel implementation serves both the
    offline calibration path and the per-slot serving path.
    """
    n = zq.shape[0]
    w0 = jnp.broadcast_to(w0.astype(jnp.float32)[None, :], (n, w0.shape[0]))
    b0 = jnp.broadcast_to(jnp.asarray(b0, jnp.float32).reshape(()), (n,))
    return ttt_probe_batched(zq, zk, c, m, w0, b0, jnp.asarray(eta),
                             t_chunk=t_chunk, interpret=interpret)


def make_unroll_kernel(t_chunk: int = DEFAULT_T_CHUNK, interpret: bool = True):
    """Adapter with the signature repro.core.ttt.inner_unroll expects:
    (zq, zk, c, m, W0, b0, eta) -> (scores, W_f, b_f) for ONE trajectory."""
    def kern(zq, zk, c, m, w0, b0, eta):
        s, wf, bf = ttt_probe_scan(zq[None], zk[None], c[None], m[None],
                                   w0, jnp.asarray(b0), jnp.asarray(eta),
                                   t_chunk=t_chunk, interpret=interpret)
        return s[0], wf[0], bf[0]
    return kern


# ---------------------------------------------------------------------------
# Serving hot path: batched single step, fused with smoothing + threshold


class ProbeStepOut(NamedTuple):
    """One fused serving step's per-slot observations + updated state."""
    s: jnp.ndarray           # (B,) raw probe score this token
    W: jnp.ndarray           # (B, f) fast weights after the step
    b: jnp.ndarray           # (B,)
    ring: jnp.ndarray        # (B, window) rolling raw-score window
    n_scores: jnp.ndarray    # (B,) int32 scores emitted since admission
    smoothed: jnp.ndarray    # (B,) rolling-mean score
    stopped: jnp.ndarray     # (B,) bool — calibrated threshold crossed
    stop_step: jnp.ndarray   # (B,) int32 reasoning step at stop (-1 active)


def _serving_kernel(zq_ref, zk_ref, bnd_ref, w_ref, b_ref, ring_ref, n_ref,
                    stopped_ref, step_ref, eta_ref, lam_ref,
                    s_out, w_out, b_out, ring_out, n_out, sm_out,
                    stopped_out, step_out, *, burn_in: int):
    zq, zk, w = zq_ref[...], zk_ref[...], w_ref[...]
    b = b_ref[...][:, 0]
    stopped = stopped_ref[...][:, 0] > 0.5
    # a stopped slot is frozen compute: no boundary, no update, no scores
    bnd = jnp.where(stopped, 0.0, bnd_ref[...][:, 0])   # f32 0/1
    n0 = n_ref[...][:, 0]
    step0 = step_ref[...][:, 0]
    eta, lam = eta_ref[0], lam_ref[0]

    # score-then-update (single shared formula); the update is masked to
    # boundary tokens — a stop firing THIS step is rolled back below so the
    # stopping step leaves the fast weights untouched (Algorithm 2 order)
    s, w_upd, b_upd = P.score_then_update(w, b, zq, zk, 0.0, bnd, eta)

    bnd_b = bnd > 0.5
    ring = jnp.where(bnd_b[:, None],
                     jnp.concatenate([ring_ref[...][:, 1:], s[:, None]],
                                     axis=1),
                     ring_ref[...])
    n = n0 + bnd_b.astype(jnp.int32)
    win = ring.shape[1]
    denom = jnp.minimum(n, win).astype(jnp.float32)
    smoothed = jnp.where(n > 0, jnp.sum(ring, axis=1) / jnp.maximum(denom, 1.0),
                         0.0)
    # threshold test (Algorithm 2 line 11), after the burn-in
    stop_now = bnd_b & (smoothed >= lam) & (n > burn_in)
    stopped_new = stopped | stop_now
    step_new = jnp.where(stop_now & (step0 < 0), n, step0)

    s_out[...] = s[:, None]
    w_out[...] = jnp.where(stop_now[:, None], w, w_upd)
    b_out[...] = jnp.where(stop_now, b, b_upd)[:, None]
    ring_out[...] = ring
    n_out[...] = n[:, None]
    sm_out[...] = smoothed[:, None]
    stopped_out[...] = stopped_new.astype(jnp.float32)[:, None]
    step_out[...] = step_new[:, None]


class SpecProbeOut(NamedTuple):
    """A masked multi-token (speculative verify) probe step's outputs.

    The per-token sequences let the scheduler replay the chain on the host:
    token t of slot i emitted a score iff ``n_seq[i, t]`` exceeds the count
    before it, and ``smoothed_seq[i, t]`` is that score's rolling mean."""
    s: jnp.ndarray            # (B, T) raw probe score per verify token
    smoothed_seq: jnp.ndarray  # (B, T) rolling mean AFTER each token
    n_seq: jnp.ndarray        # (B, T) int32 scores emitted AFTER each token
    W: jnp.ndarray            # (B, f) final fast weights
    b: jnp.ndarray            # (B,)
    ring: jnp.ndarray         # (B, window)
    n_scores: jnp.ndarray     # (B,) int32
    smoothed: jnp.ndarray     # (B,)
    stopped: jnp.ndarray      # (B,) bool
    stop_step: jnp.ndarray    # (B,) int32


def _spec_kernel(zq_ref, zk_ref, bnd_ref, acc_ref, w_ref, b_ref, ring_ref,
                 n_ref, stopped_ref, step_ref, eta_ref, lam_ref,
                 s_out, sm_seq_out, n_seq_out, w_out, b_out, ring_out,
                 n_out, sm_out, stopped_out, step_out, *, burn_in: int,
                 t_total: int):
    """T chained serving-probe steps with a per-slot accepted-length mask.

    Token t of slot i participates iff ``t < accept[i]`` (and its boundary
    flag is set); each participating token runs EXACTLY the
    ``_serving_kernel`` per-token math, so the chain is bit-identical to
    ``accept[i]`` sequential one-token steps.  Rejected-draft tokens
    (t >= accept) leave every piece of state untouched."""
    eta, lam = eta_ref[0], lam_ref[0]
    acc = acc_ref[...][:, 0]                           # (B,) int32

    def body(t, carry):
        w, b, ring, n0, stopped_f, step0 = carry
        zq = pl.load(zq_ref, (pl.dslice(t, 1), slice(None), slice(None)))[0]
        zk = pl.load(zk_ref, (pl.dslice(t, 1), slice(None), slice(None)))[0]
        bnd_in = pl.load(bnd_ref, (pl.dslice(t, 1), slice(None)))[0]
        stopped = stopped_f > 0.5
        # the accepted-length mask composes with the frozen-stop mask: a
        # rejected draft position or a slot stopped earlier IN THIS CHAIN
        # contributes no boundary, no update, no score emission
        mask = (t < acc).astype(jnp.float32)
        bnd = jnp.where(stopped, 0.0, bnd_in * mask)
        s, w_upd, b_upd = P.score_then_update(w, b, zq, zk, 0.0, bnd, eta)
        bnd_b = bnd > 0.5
        ring_new = jnp.where(bnd_b[:, None],
                             jnp.concatenate([ring[:, 1:], s[:, None]],
                                             axis=1),
                             ring)
        n = n0 + bnd_b.astype(jnp.int32)
        win = ring_new.shape[1]
        denom = jnp.minimum(n, win).astype(jnp.float32)
        smoothed = jnp.where(n > 0,
                             jnp.sum(ring_new, axis=1)
                             / jnp.maximum(denom, 1.0), 0.0)
        stop_now = bnd_b & (smoothed >= lam) & (n > burn_in)
        stopped_new = stopped | stop_now
        step_new = jnp.where(stop_now & (step0 < 0), n, step0)
        pl.store(s_out, (pl.dslice(t, 1), slice(None)), s[None])
        pl.store(sm_seq_out, (pl.dslice(t, 1), slice(None)), smoothed[None])
        pl.store(n_seq_out, (pl.dslice(t, 1), slice(None)), n[None])
        return (jnp.where(stop_now[:, None], w, w_upd),
                jnp.where(stop_now, b, b_upd),
                ring_new, n, stopped_new.astype(jnp.float32), step_new)

    carry = (w_ref[...], b_ref[...][:, 0], ring_ref[...], n_ref[...][:, 0],
             stopped_ref[...][:, 0], step_ref[...][:, 0])
    w, b, ring, n, stopped_f, step = jax.lax.fori_loop(0, t_total, body,
                                                       carry)
    # the smoothed score is derived state (always recomputed from the
    # ring), so the post-chain recompute equals the last in-chain value
    denom = jnp.minimum(n, ring.shape[1]).astype(jnp.float32)
    smoothed = jnp.where(n > 0,
                         jnp.sum(ring, axis=1) / jnp.maximum(denom, 1.0),
                         0.0)
    w_out[...] = w
    b_out[...] = b[:, None]
    ring_out[...] = ring
    n_out[...] = n[:, None]
    sm_out[...] = smoothed[:, None]
    stopped_out[...] = stopped_f[:, None]
    step_out[...] = step[:, None]


@functools.partial(jax.jit, static_argnames=("burn_in", "interpret"))
def serving_probe_spec_step(zq, zk, boundary, accept, W, b, ring, n_scores,
                            stopped, stop_step, eta, lam, *, burn_in: int,
                            interpret: bool = True) -> SpecProbeOut:
    """Masked multi-token serving probe: T chained per-token steps in ONE
    kernel call, gated by a per-slot accepted length.

    zq/zk (B, T, f) per-token feature views (token t's features already
    reflect the hidden-state pooling up to t); boundary (B, T) the raw
    reasoning-step-crossing flags; accept (B,) int32 — slot i processes
    only its first ``accept[i]`` tokens (the verifier's accepted prefix),
    so the probe scores ONLY accepted tokens.  State args and semantics
    are exactly :func:`serving_probe_step`'s, chained: running this over
    (zq, zk) with accept[i] = a is bit-identical to ``a`` sequential
    one-token ``serving_probe_step`` calls for slot i (the spec-decode
    acceptance invariant; held to
    ``repro.kernels.ref.serving_probe_spec_step_ref``).
    A stop firing mid-chain freezes that slot for the remaining tokens —
    same frozen-slot rule as the one-token kernel, applied within the
    chain."""
    batch, t_total, f = zq.shape
    f32, i32 = jnp.float32, jnp.int32
    f_pad = f if interpret else -(-f // 128) * 128
    if f_pad != f:
        zq = jnp.pad(zq.astype(f32), ((0, 0), (0, 0), (0, f_pad - f)))
        zk = jnp.pad(zk.astype(f32), ((0, 0), (0, 0), (0, f_pad - f)))
        W = jnp.pad(W.astype(f32), ((0, 0), (0, f_pad - f)))
    win = ring.shape[1]
    col = lambda a, dt: a.reshape(batch, 1).astype(dt)
    kernel = functools.partial(_spec_kernel, burn_in=burn_in,
                               t_total=t_total)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)        # whole-array block
    s, sm_seq, n_seq, w_new, b_new, ring_new, n_new, sm_new, stopped_new, \
        step_new = pl.pallas_call(
            kernel,
            in_specs=[vmem] * 10 + [
                pl.BlockSpec(memory_space=pltpu.SMEM),          # eta
                pl.BlockSpec(memory_space=pltpu.SMEM)],         # lam
            out_specs=[vmem] * 10,
            out_shape=[
                jax.ShapeDtypeStruct((t_total, batch), f32),    # s
                jax.ShapeDtypeStruct((t_total, batch), f32),    # smoothed
                jax.ShapeDtypeStruct((t_total, batch), i32),    # n_scores
                jax.ShapeDtypeStruct((batch, f_pad), f32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, win), f32),
                jax.ShapeDtypeStruct((batch, 1), i32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, 1), i32),
            ],
            interpret=interpret,
        )(zq.astype(f32).transpose(1, 0, 2), zk.astype(f32).transpose(1, 0, 2),
          jnp.asarray(boundary, f32).T, col(accept, i32), W.astype(f32),
          col(b, f32), ring.astype(f32), col(n_scores, i32),
          col(stopped, f32), col(stop_step, i32),
          jnp.asarray(eta, f32).reshape(1), jnp.asarray(lam, f32).reshape(1))
    return SpecProbeOut(
        s=s.T, smoothed_seq=sm_seq.T, n_seq=n_seq.T,
        W=w_new[:, :f], b=b_new[:, 0], ring=ring_new,
        n_scores=n_new[:, 0], smoothed=sm_new[:, 0],
        stopped=stopped_new[:, 0] > 0.5, stop_step=step_new[:, 0])


@functools.partial(jax.jit, static_argnames=("burn_in", "interpret"))
def serving_probe_step(zq, zk, boundary, W, b, ring, n_scores,
                       stopped, stop_step, eta, lam, *, burn_in: int,
                       interpret: bool = True) -> ProbeStepOut:
    """One fused serving step for ALL engine slots (vector per-slot state).

    zq/zk (B, f) feature views of the running step embedding; boundary (B,)
    bool marks slots finishing a reasoning step this token; (W, b, ring,
    n_scores, stopped, stop_step) is the per-slot probe state (the smoothed
    score is derived output only — always recomputed from the ring).  Fuses
    score-then-update, rolling smoothing and the calibrated threshold test —
    the complete per-token deployed procedure of Algorithm 2.  Equivalent to
    the PR-1 jnp path (``repro.kernels.ref.serving_probe_step_ref``), which
    the parity suite holds it to.

    For compiled TPU mode the feature axis is zero-padded to a multiple of
    128 lanes (zero features never score or update, so padding is exact);
    interpret mode runs the block unpadded so CPU CI is bit-identical to the
    jnp oracle.
    """
    batch, f = zq.shape
    f32, i32 = jnp.float32, jnp.int32
    f_pad = f if interpret else -(-f // 128) * 128
    if f_pad != f:
        pad = ((0, 0), (0, f_pad - f))
        zq, zk, W = (jnp.pad(a.astype(f32), pad) for a in (zq, zk, W))
    win = ring.shape[1]
    col = lambda a, dt: a.reshape(batch, 1).astype(dt)
    kernel = functools.partial(_serving_kernel, burn_in=burn_in)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)        # whole-array block
    s, w_new, b_new, ring_new, n_new, sm_new, stopped_new, step_new = \
        pl.pallas_call(
            kernel,
            in_specs=[vmem] * 9 + [
                pl.BlockSpec(memory_space=pltpu.SMEM),          # eta
                pl.BlockSpec(memory_space=pltpu.SMEM)],         # lam
            out_specs=[vmem] * 8,
            out_shape=[
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, f_pad), f32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, win), f32),
                jax.ShapeDtypeStruct((batch, 1), i32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, 1), f32),
                jax.ShapeDtypeStruct((batch, 1), i32),
            ],
            interpret=interpret,
        )(zq.astype(f32), zk.astype(f32), col(boundary, f32), W.astype(f32),
          col(b, f32), ring.astype(f32), col(n_scores, i32),
          col(stopped, f32), col(stop_step, i32),
          jnp.asarray(eta, f32).reshape(1), jnp.asarray(lam, f32).reshape(1))
    return ProbeStepOut(
        s=s[:, 0], W=w_new[:, :f], b=b_new[:, 0], ring=ring_new,
        n_scores=n_new[:, 0], smoothed=sm_new[:, 0],
        stopped=stopped_new[:, 0] > 0.5, stop_step=step_new[:, 0])
