"""Flash-decode — single-query attention over a long KV cache (Pallas TPU).

One new token attends to a cache of S positions: grid = (B, KV, S/BS) with
the sequence chunk innermost, online-softmax running stats in VMEM.  The
whole GQA group (G = H/KV query heads) is processed per program so the KV
block is read once per group (bandwidth-bound op — the roofline term this
kernel optimizes).  A validity mask supports ring-buffer SWA caches and
partially-filled caches.

q (B, H, d); k, v (B, KV, S, d); valid (B, S) -> out (B, H, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_s, l_s, acc_s, *,
            bs: int, n_s: int, g: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0, :, :] * scale                     # (G, d)
    k = k_ref[0, 0, :, :]                             # (bs, d)
    v = v_ref[0, 0, :, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, bs)
    ok = valid_ref[0, :][None, :]                     # (1, bs)
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(si == n_s - 1)
    def _fin():
        o_ref[0, 0, :, :] = (acc_s[...] /
                          jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode(q, k, v, valid, *, bs: int = 512, interpret: bool = True):
    b, h, d = q.shape
    _, n_kv, s_len, _ = k.shape
    assert h % n_kv == 0
    g = h // n_kv
    bs = min(bs, s_len)
    assert s_len % bs == 0, (s_len, bs)
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, n_kv, g, d)
    kernel = functools.partial(_kernel, bs=bs, n_s=s_len // bs, g=g, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv, s_len // bs),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, kv, si: (b_, kv, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b_, kv, si: (b_, kv, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b_, kv, si: (b_, kv, si, 0)),
            pl.BlockSpec((1, bs), lambda b_, kv, si: (b_, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, kv, si: (b_, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(b, h, d)
