"""Flash-decode — single-query attention over a long KV cache (Pallas TPU).

One new token attends to a cache of S positions: grid = (B, KV, S/BS) with
the sequence chunk innermost, online-softmax running stats in VMEM.  The
whole GQA group (G = H/KV query heads) is processed per program so the KV
block is read once per group (bandwidth-bound op — the roofline term this
kernel optimizes).  A validity mask supports ring-buffer SWA caches and
partially-filled caches.

q (B, H, d); k, v (B, KV, S, d); valid (B, S) -> out (B, H, d)

``paged_flash_decode`` is the paged-KV variant: K/V live in a pool of
fixed-size token blocks (pages) shared by all requests, and each batch row
reads *through its block table* — the table is a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``), so the index map dereferences
``table[b, block]`` to pick which physical page the next DMA fetches.  The
kernel body is the same online softmax; int8-KV pages carry per-(position,
head) scales and are dequantized per VMEM block (no HBM-sized temp).

``paged_flash_prefill_chunk`` extends the paged kernel to a q-block > 1:
the C queries of a prefill chunk share each page DMA (Sarathi-style chunked
prefill — the serving engine's unified token-budget step), emitting
unnormalized partials the caller merges with the causal within-chunk block.

``paged_flash_packed_chunk`` is the PACKED variant: one fused chunk carries
tokens of up to R different requests (the tail of one prompt piggybacked
with the head of the next).  Cross-request isolation is block-diagonal by
construction — each request's tokens read the pages through their OWN
block-table row with their own validity prefix, and the within-chunk keys
are gated by the caller's block-diagonal chunk mask
(``attention.packed_chunk_mask``), so tokens of different requests never
attend each other anywhere in the fused chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_s, l_s, acc_s, *,
            bs: int, n_s: int, g: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0, :, :] * scale                     # (G, d)
    k = k_ref[0, 0, :, :]                             # (bs, d)
    v = v_ref[0, 0, :, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, bs)
    ok = valid_ref[0, :][None, :]                     # (1, bs)
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(si == n_s - 1)
    def _fin():
        o_ref[0, 0, :, :] = (acc_s[...] /
                          jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode(q, k, v, valid, *, bs: int = 512, interpret: bool = True):
    b, h, d = q.shape
    _, n_kv, s_len, _ = k.shape
    assert h % n_kv == 0
    g = h // n_kv
    bs = min(bs, s_len)
    assert s_len % bs == 0, (s_len, bs)
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, n_kv, g, d)
    kernel = functools.partial(_kernel, bs=bs, n_s=s_len // bs, g=g, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv, s_len // bs),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, kv, si: (b_, kv, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b_, kv, si: (b_, kv, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b_, kv, si: (b_, kv, si, 0)),
            pl.BlockSpec((1, bs), lambda b_, kv, si: (b_, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, kv, si: (b_, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# Paged decode: gather K/V through a per-request block table


def _paged_kernel(tables_ref, q_ref, k_ref, v_ref, valid_ref, *rest,
                  n_b: int, quantized: bool, scale: float):
    """One (batch row, kv head, table entry) program.  The page this program
    sees was selected by the index map via ``tables_ref[b, bi]`` — the
    kernel body itself is table-oblivious online softmax.  Emits the
    UNNORMALIZED (acc, l, m) triple so the caller can merge the current
    token's column (``extra_kv``) before normalizing, exactly like the
    dense ``_decode_partial`` path.

    The query block is (R, d) with R = G query rows for decode or R = G*Q
    for the chunked-prefill variant (q-block > 1): the body is row-count
    oblivious, so one kernel serves both."""
    if quantized:
        ks_ref, vs_ref, o_ref, l_ref, m_ref, m_s, l_s, acc_s = rest
    else:
        o_ref, l_ref, m_ref, m_s, l_s, acc_s = rest
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0, :, :] * scale                     # (G, d)
    k = k_ref[0, 0, :, :]                             # (bs, d)
    v = v_ref[0, 0, :, :]
    if quantized:
        # per-(position, head) absmax scales: dequantize this page in VMEM
        k = k.astype(jnp.float32) * ks_ref[0, 0, :, :]
        v = v.astype(jnp.float32) * vs_ref[0, 0, :, :]
    s = jnp.dot(q, k.astype(q.dtype).T, preferred_element_type=jnp.float32)
    ok = valid_ref[0, :][None, :]                     # (1, bs)
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p.astype(jnp.float32), v.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(bi == n_b - 1)
    def _fin():
        o_ref[0, 0, :, :] = acc_s[...]
        l_ref[0, 0, :, :] = l_s[...]
        m_ref[0, 0, :, :] = m_s[...]


def _paged_attend(qg, k_pages, v_pages, block_tables, valid,
                  k_scale_pages, v_scale_pages, *, interpret: bool):
    """Shared launcher: online-softmax attention of an (R, d) query block
    per (batch row, kv head) against that row's pages, gathered through the
    scalar-prefetched block table.  R = G (decode) or G*Q (chunked
    prefill).  qg (B, KV, R, d) -> unnormalized (o (B,KV,R,d), l (B,KV,R),
    m (B,KV,R))."""
    b, n_kv, r, d = qg.shape
    _, _, bs, _ = k_pages.shape
    nb = block_tables.shape[1]
    assert valid.shape == (b, nb * bs), (valid.shape, b, nb, bs)
    quantized = k_scale_pages is not None
    assert quantized == (v_scale_pages is not None)
    scale = 1.0 / (d ** 0.5)

    # index maps receive the scalar-prefetch block table last: the page a
    # program DMAs is table[b, bi] — this indirection IS paged attention
    page_spec = pl.BlockSpec(
        (1, 1, bs, d), lambda b_, kv, bi, tbl: (tbl[b_, bi], kv, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, r, d), lambda b_, kv, bi, tbl: (b_, kv, 0, 0)),
        page_spec,
        page_spec,
        pl.BlockSpec((1, bs), lambda b_, kv, bi, tbl: (b_, bi)),
    ]
    operands = [qg, k_pages, v_pages, valid]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, 1, bs, 1), lambda b_, kv, bi, tbl: (tbl[b_, bi], kv, 0, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale_pages, v_scale_pages]

    kernel = functools.partial(_paged_kernel, n_b=nb, quantized=quantized,
                               scale=scale)
    stat_spec = pl.BlockSpec((1, 1, r, 1),
                             lambda b_, kv, bi, tbl: (b_, kv, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_kv, nb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, r, d),
                         lambda b_, kv, bi, tbl: (b_, kv, 0, 0)),
            stat_spec,
            stat_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((r, 1), jnp.float32),
            pltpu.VMEM((r, 1), jnp.float32),
            pltpu.VMEM((r, d), jnp.float32),
        ],
    )
    o_un, l, m = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, n_kv, r, d), jnp.float32),
            jax.ShapeDtypeStruct((b, n_kv, r, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, n_kv, r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), *operands)
    return o_un, l[..., 0], m[..., 0]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "return_partials"))
def paged_flash_decode(q, k_pages, v_pages, block_tables, valid,
                       k_scale_pages=None, v_scale_pages=None, *,
                       interpret: bool = True,
                       return_partials: bool = False):
    """Single-query attention where each batch row gathers its K/V pages
    through its block table.

    q            (B, H, d)
    k/v_pages    (P, KV, bs, d)   — the whole pool, pages shared by rows
    block_tables (B, nb) int32    — physical page id per virtual block
    valid        (B, nb * bs)     — readable virtual positions (masks both
                                    unwritten tail positions and any NULL /
                                    stale table entries)
    k/v_scale_pages (P, KV, bs, 1) f32 — int8 dequant scales (both or none)

    -> out (B, H, d), or with ``return_partials`` the unnormalized online-
    softmax triple (o_un (B,KV,G,d), l (B,KV,G), m (B,KV,G)) so the caller
    can fold in the current token's (k, v) before normalizing.
    """
    b, h, d = q.shape
    n_kv = k_pages.shape[1]
    assert h % n_kv == 0
    g = h // n_kv
    qg = q.reshape(b, n_kv, g, d)
    o_un, l, m = _paged_attend(qg, k_pages, v_pages, block_tables, valid,
                               k_scale_pages, v_scale_pages,
                               interpret=interpret)
    if return_partials:
        return o_un, l, m
    out = o_un / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_prefill_chunk(q, k_pages, v_pages, block_tables, valid,
                              k_scale_pages=None, v_scale_pages=None, *,
                              interpret: bool = True):
    """Chunked-prefill attention over the pages: ``paged_flash_decode``
    extended to a q-block > 1 — all C chunk queries of a request ride ONE
    program per (row, kv head, page), so each K/V page is DMA'd once for
    the whole chunk instead of once per token.

    q (B, C, H, d) — the query chunk; every chunk query attends the same
    readable cache positions ``valid`` (B, nb*bs) = [0, pos_start), so the
    per-key mask is shared across the q-block (causal-within-chunk is the
    caller's merge step, the chunk's K/V not being in pages yet).

    -> UNNORMALIZED (o (B,KV,G,C,d), l (B,KV,G,C), m (B,KV,G,C)): the
    caller folds in the within-chunk causal block (``_merge_kv_block``)
    before normalizing — the same partials contract as the decode kernel's
    ``extra_kv`` merge.
    """
    b, c, h, d = q.shape
    n_kv = k_pages.shape[1]
    assert h % n_kv == 0
    g = h // n_kv
    # (B, C, H, d) -> (B, KV, G*C, d): the kernel sees one (G*C, d) q-block
    qg = q.reshape(b, c, n_kv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b, n_kv, g * c, d)
    o_un, l, m = _paged_attend(qg, k_pages, v_pages, block_tables, valid,
                               k_scale_pages, v_scale_pages,
                               interpret=interpret)
    return (o_un.reshape(b, n_kv, g, c, d), l.reshape(b, n_kv, g, c),
            m.reshape(b, n_kv, g, c))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_packed_chunk(q, k_pages, v_pages, seg, seg_tables, seg_valid,
                             k_scale_pages=None, v_scale_pages=None, *,
                             interpret: bool = True):
    """Packed multi-request chunk attention over the pages.

    One fused C-token chunk carries tokens of up to R requests ("segments"
    — e.g. the tail of one prompt packed with the head of the next).  Each
    segment r owns block-table row ``seg_tables[r]`` and validity prefix
    ``seg_valid[r]`` ([0, its prefill progress)); ``seg[i]`` names the
    segment token i belongs to.  The launch keeps the chunked-prefill
    kernel's page economics: ONE q-block of all C chunk queries rides each
    (segment, kv head, page) program, so a page is DMA'd once per chunk,
    not once per token — then each token keeps only the partials of ITS
    segment's pass.  Cross-request cache isolation is block-diagonal by
    construction (a token can only ever see its own request's pages); the
    within-chunk block (keys not yet in pages) is the caller's merge under
    ``attention.packed_chunk_mask`` — block-diagonal causal for chunked
    prefill / linear speculative verify, or the per-token ANCESTOR mask
    when the segment carries a speculative token tree.  The kernel itself
    is ancestor-oblivious on purpose: every tree node shares its slot's
    committed cache prefix [0, pos) verbatim (``seg_valid`` is per
    segment, not per token), so the tree shape only ever reaches the
    caller-side within-chunk merge, never the page loop.

    q (C, H, d); seg (C,) int32 in [0, R); seg_tables (R, nb) int32;
    seg_valid (R, nb * bs) bool; k/v_scale_pages (P, KV, bs, 1) or None.

    -> UNNORMALIZED per-token partials (o (C, KV, G, d), l (C, KV, G),
    m (C, KV, G)) — same contract as ``paged_flash_decode``'s partials, so
    the caller folds the within-chunk block exactly like ``extra_kv``.
    Tokens of a segment with an all-False validity row (a prompt head with
    no cache yet) come back with m = NEG_INF partials, which the merge
    flushes to exact zeros.
    """
    c, h, d = q.shape
    n_kv = k_pages.shape[1]
    assert h % n_kv == 0
    g = h // n_kv
    r = seg_tables.shape[0]
    # every segment's program sees the FULL C-token q-block (page DMA'd
    # once per segment); (C,H,d) -> (R, KV, G*C, d)
    qg = q.reshape(c, n_kv, g, d).transpose(1, 2, 0, 3).reshape(n_kv, g * c, d)
    qg = jnp.broadcast_to(qg[None], (r, n_kv, g * c, d))
    o_un, l, m = _paged_attend(qg, k_pages, v_pages, seg_tables, seg_valid,
                               k_scale_pages, v_scale_pages,
                               interpret=interpret)
    o_un = o_un.reshape(r, n_kv, g, c, d)
    l = l.reshape(r, n_kv, g, c)
    m = m.reshape(r, n_kv, g, c)
    # token i keeps the partials of its own segment's pass
    seg = jnp.asarray(seg, jnp.int32)
    tok = jnp.arange(c)
    return o_un[seg, :, :, tok], l[seg, :, :, tok], m[seg, :, :, tok]
