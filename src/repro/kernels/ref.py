"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These share code with the model/core reference paths on purpose: the model
zoo and ORCA core are *defined* by these semantics; the kernels must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.probe import ProbeConfig
from repro.core import ttt as _ttt
from repro.models.attention import attn_prefill_einsum, _decode_core
from repro.models import rwkv6 as _rwkv6


def ttt_probe_ref(zq, zk, c, m, w0, b0, eta):
    """Batched inner-loop unroll. zq/zk (N,T,f) -> scores (N,T), wf, bf."""
    def one(zq1, zk1, c1, m1):
        def step(fast, xs):
            zq_t, zk_t, c_t, m_t = xs
            w, b = fast
            s_q = jax.nn.sigmoid(jnp.dot(zq_t, w) + b)
            s_k = jax.nn.sigmoid(jnp.dot(zk_t, w) + b)
            coeff = 2.0 * (s_k - c_t) * s_k * (1 - s_k) * m_t * eta
            return (w - coeff * zk_t, b - coeff), s_q
        (wf, bf), scores = jax.lax.scan(step, (w0, b0), (zq1, zk1, c1, m1))
        return scores, wf, bf
    return jax.vmap(one)(zq, zk, c, m)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    return attn_prefill_einsum(q, k, v, causal=causal, window=window)


def flash_decode_ref(q, k, v, valid):
    b, h, d = q.shape
    n_kv = k.shape[1]
    qg = q.reshape(b, n_kv, h // n_kv, d).astype(jnp.float32)
    out = _decode_core(qg, k.astype(jnp.float32), v.astype(jnp.float32), valid)
    return out.reshape(b, h, d).astype(q.dtype)


def wkv_scan_ref(r, k, v, w, u, s0):
    return _rwkv6.wkv_scan(r, k, v, w, u, s0)
