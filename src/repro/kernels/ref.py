"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These share code with the model/core reference paths on purpose: the model
zoo and ORCA core are *defined* by these semantics; the kernels must match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ttt as _ttt
from repro.models.attention import attn_prefill_einsum, _decode_core
from repro.models import rwkv6 as _rwkv6


def _ttt_unroll_one(zq1, zk1, c1, m1, w1, b1, eta):
    """Inner-loop unroll for ONE trajectory from state (w1, b1)."""
    def step(fast, xs):
        zq_t, zk_t, c_t, m_t = xs
        w, b = fast
        s_q = jax.nn.sigmoid(jnp.dot(zq_t, w) + b)
        s_k = jax.nn.sigmoid(jnp.dot(zk_t, w) + b)
        coeff = 2.0 * (s_k - c_t) * s_k * (1 - s_k) * m_t * eta
        return (w - coeff * zk_t, b - coeff), s_q
    (wf, bf), scores = jax.lax.scan(step, (w1, b1), (zq1, zk1, c1, m1))
    return scores, wf, bf


def ttt_probe_ref(zq, zk, c, m, w0, b0, eta):
    """Batched inner-loop unroll. zq/zk (N,T,f) -> scores (N,T), wf, bf."""
    return jax.vmap(lambda a, b_, c_, d: _ttt_unroll_one(a, b_, c_, d, w0, b0,
                                                         eta))(zq, zk, c, m)


def ttt_probe_batched_ref(zq, zk, c, m, w0, b0, eta):
    """Vector-state unroll: each trajectory starts from its OWN (w0_i, b0_i).
    zq/zk (N,T,f), w0 (N,f), b0 (N,) -> scores (N,T), wf (N,f), bf (N,)."""
    return jax.vmap(functools.partial(_ttt_unroll_one, eta=eta))(
        zq, zk, c, m, w0, b0)


def serving_probe_step_ref(zq, zk, boundary, W, b, ring, n_scores,
                           stopped, stop_step, eta, lam, *, burn_in: int):
    """The PR-1 serving probe step, verbatim (``engine.probe_update``'s
    score/smooth/threshold/update math before the kernel unification) — the
    oracle the fused ``serving_probe_step`` kernel is held to.  Same
    signature/return as the kernel (``ttt_probe.ProbeStepOut``)."""
    from repro.core import probe as P
    from repro.kernels.ttt_probe import ProbeStepOut
    boundary = jnp.asarray(boundary, bool) & ~stopped
    # per-sequence fast weights: s_t = sigma(W_i . z_i + b_i), uses W_{t-1}
    s = jax.nn.sigmoid(jnp.sum(zq * W, axis=-1) + b)            # (B,)
    # rolling smoothing
    ring = jnp.where(boundary[:, None],
                     jnp.concatenate([ring[:, 1:], s[:, None]], axis=1),
                     ring)
    n_scores = n_scores + boundary.astype(jnp.int32)
    w = ring.shape[1]
    denom = jnp.minimum(n_scores, w).astype(jnp.float32)
    smoothed = jnp.where(n_scores > 0,
                         jnp.sum(ring, axis=1) / jnp.maximum(denom, 1.0),
                         0.0)
    # stopping decision (Algorithm 2 line 11), after the burn-in
    stop_now = boundary & (smoothed >= lam) & (n_scores > burn_in)
    stopped_new = stopped | stop_now
    stop_step = jnp.where(stop_now & (stop_step < 0), n_scores, stop_step)
    # inner-loop update with pseudo-target C_t = 0 (only while not stopped)
    gW, gb = jax.vmap(lambda fast, z: P.brier_grad(fast, z, 0.0),
                      in_axes=((0, 0), 0))((W, b), zk)
    upd = (boundary & ~stopped_new).astype(jnp.float32)
    W = W - eta * upd[:, None] * gW
    b = b - eta * upd * gb
    return ProbeStepOut(s=s, W=W, b=b, ring=ring, n_scores=n_scores,
                        smoothed=smoothed, stopped=stopped_new,
                        stop_step=stop_step)


def serving_probe_spec_step_ref(zq, zk, boundary, accept, W, b, ring,
                                n_scores, stopped, stop_step, eta, lam, *,
                                burn_in: int):
    """Chained-one-token oracle for ``serving_probe_spec_step``: token t of
    slot i participates iff ``t < accept[i]`` and its boundary flag is set;
    every participating token is EXACTLY one ``serving_probe_step_ref``
    call, so the masked multi-token verify step equals the sequential
    one-token procedure by construction — the spec-decode probe invariant
    stated as code."""
    from repro.kernels.ttt_probe import SpecProbeOut
    t_total = zq.shape[1]
    accept = jnp.asarray(accept, jnp.int32)
    boundary = jnp.asarray(boundary, bool)
    ss, sms, ns = [], [], []
    out = None
    for t in range(t_total):
        bnd = boundary[:, t] & (t < accept)
        out = serving_probe_step_ref(zq[:, t], zk[:, t], bnd, W, b, ring,
                                     n_scores, stopped, stop_step, eta, lam,
                                     burn_in=burn_in)
        W, b, ring, n_scores, stopped, stop_step = (
            out.W, out.b, out.ring, out.n_scores, out.stopped, out.stop_step)
        ss.append(out.s)
        sms.append(out.smoothed)
        ns.append(out.n_scores)
    return SpecProbeOut(
        s=jnp.stack(ss, axis=1), smoothed_seq=jnp.stack(sms, axis=1),
        n_seq=jnp.stack(ns, axis=1), W=W, b=b, ring=ring,
        n_scores=n_scores, smoothed=out.smoothed, stopped=stopped,
        stop_step=stop_step)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    return attn_prefill_einsum(q, k, v, causal=causal, window=window)


def flash_decode_ref(q, k, v, valid):
    b, h, d = q.shape
    n_kv = k.shape[1]
    qg = q.reshape(b, n_kv, h // n_kv, d).astype(jnp.float32)
    out = _decode_core(qg, k.astype(jnp.float32), v.astype(jnp.float32), valid)
    return out.reshape(b, h, d).astype(q.dtype)


def _gather_virtual_cache(k_pages, v_pages, block_tables,
                          k_scale_pages, v_scale_pages):
    """Pages -> per-row contiguous virtual caches (B, KV, nb*bs, d) f32,
    via the SAME gather the engine's jnp paged path uses
    (``attention.gather_page_rows``) — one page layout, one gather."""
    from repro.models.attention import gather_page_rows
    cache_l = {"k": k_pages, "v": v_pages}
    if k_scale_pages is not None:
        cache_l["k_scale"] = k_scale_pages
        cache_l["v_scale"] = v_scale_pages
    return gather_page_rows(cache_l, block_tables)


def paged_decode_ref(q, k_pages, v_pages, block_tables, valid,
                     k_scale_pages=None, v_scale_pages=None):
    """Oracle for ``paged_flash_decode``: gather every row's pages into a
    contiguous virtual cache via its block table, then dense decode.
    q (B,H,d); pages (P,KV,bs,d); block_tables (B,nb); valid (B, nb*bs)."""
    b, h, d = q.shape
    n_kv = k_pages.shape[1]
    k, v = _gather_virtual_cache(k_pages, v_pages, block_tables,
                                 k_scale_pages, v_scale_pages)
    qg = q.reshape(b, n_kv, h // n_kv, d).astype(jnp.float32)
    out = _decode_core(qg, k, v, valid)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_prefill_chunk_ref(q, k_pages, v_pages, block_tables, valid,
                            k_scale_pages=None, v_scale_pages=None):
    """Oracle for ``paged_flash_prefill_chunk``: gather every row's pages
    into a contiguous virtual cache, then compute the UNNORMALIZED online-
    softmax partials of the whole query chunk against it.

    q (B, C, H, d); pages (P, KV, bs, d); block_tables (B, nb);
    valid (B, nb*bs) -> (o (B,KV,G,C,d), l (B,KV,G,C), m (B,KV,G,C))."""
    from repro.models.attention import _decode_partial
    b, c, h, d = q.shape
    n_kv = k_pages.shape[1]
    k, v = _gather_virtual_cache(k_pages, v_pages, block_tables,
                                 k_scale_pages, v_scale_pages)
    g = h // n_kv
    # (B, C, H, d) -> (B, KV, G*C, d): _decode_partial is row-count
    # oblivious, exactly like the kernel body
    qg = q.reshape(b, c, n_kv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b, n_kv, g * c, d).astype(jnp.float32)
    o, l, m = _decode_partial(qg, k, v, valid)
    return (o.reshape(b, n_kv, g, c, d), l.reshape(b, n_kv, g, c),
            m.reshape(b, n_kv, g, c))


def packed_chunk_mask_ref(seg, valid_tok, ancestors=None):
    """Oracle for ``attention.packed_chunk_mask``: the within-chunk key
    mask spelled as an explicit per-token root-path walk.  Without
    ``ancestors`` this is block-diagonal causality; with ``ancestors``
    (C,) parent pointers (roots self-pointing) token i may attend chunk
    token j iff j is i or one of i's transitive ancestors — the tree
    speculative verify mask.  Pure python/jnp loop, held against the
    fori_loop closure in the model path."""
    seg = jnp.asarray(seg, jnp.int32)
    c = int(seg.shape[0])
    i = jnp.arange(c)
    base = ((seg[:, None] == seg[None, :])
            & jnp.asarray(valid_tok, bool)[None, :])
    if ancestors is None:
        return base & (i[None, :] <= i[:, None])
    anc = jnp.asarray(ancestors, jnp.int32)
    reach = i[:, None] == i[None, :]
    cur = i
    for _ in range(c):
        cur = anc[cur]
        reach = reach | (cur[:, None] == i[None, :])
    return base & reach


def paged_packed_chunk_ref(q, k_pages, v_pages, seg, seg_tables, seg_valid,
                           k_scale_pages=None, v_scale_pages=None):
    """Oracle for ``paged_flash_packed_chunk``: gather each SEGMENT's pages
    into a contiguous virtual cache via its block-table row, run the dense
    unnormalized online softmax of every chunk token against every
    segment's cache, then keep — per token — the partials of its own
    segment (the block-diagonal cross-request isolation the kernel gets
    from per-segment tables + validity prefixes).

    q (C, H, d); seg (C,); seg_tables (R, nb); seg_valid (R, nb*bs)
    -> (o (C,KV,G,d), l (C,KV,G), m (C,KV,G))."""
    from repro.models.attention import _decode_partial
    c, h, d = q.shape
    n_kv = k_pages.shape[1]
    g = h // n_kv
    k, v = _gather_virtual_cache(k_pages, v_pages, seg_tables,
                                 k_scale_pages, v_scale_pages)
    # every token against every segment's cache: (R*C, KV, G, d) queries
    r = seg_tables.shape[0]
    qg = q.reshape(c, n_kv, g, d).astype(jnp.float32)
    qr = jnp.broadcast_to(qg[None], (r, c, n_kv, g, d)).reshape(r * c, n_kv,
                                                               g, d)
    kr = jnp.repeat(k, c, axis=0)
    vr = jnp.repeat(v, c, axis=0)
    valid = jnp.repeat(seg_valid, c, axis=0)
    o, l, m = _decode_partial(qr, kr, vr, valid)
    o = o.reshape(r, c, n_kv, g, d)
    l = l.reshape(r, c, n_kv, g)
    m = m.reshape(r, c, n_kv, g)
    tok = jnp.arange(c)
    seg = jnp.asarray(seg, jnp.int32)
    return o[seg, tok], l[seg, tok], m[seg, tok]


def wkv_scan_ref(r, k, v, w, u, s0):
    return _rwkv6.wkv_scan(r, k, v, w, u, s0)
