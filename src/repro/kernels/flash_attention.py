"""Flash attention (prefill) — Pallas TPU kernel with BlockSpec VMEM tiling.

Online-softmax blockwise attention for the prefill path: grid =
(B, H, Sq/BQ, Sk/BK); the KV index is the innermost (sequential) grid dim so
the (m, l, acc) running statistics live in VMEM scratch across KV blocks.
GQA is handled by indexing the KV block with h // group_size.  Causal and
sliding-window masks are applied with position iota; out-of-range blocks are
masked (TPU grids are static).

q (B, Sq, H, d) ; k, v (B, Sk, KV, d) -> out (B, Sq, H, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            bq: int, bk: int, n_k: int, causal: bool, window, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0, :] * scale                      # (bq, d)
    k = k_ref[0, :, 0, :]                              # (bk, d)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_s[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        o_ref[0, :, 0, :] = (acc_s[...] /
                             jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    assert h % n_kv == 0
    g = h // n_kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_k=sk // bk,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
