from repro.kernels.ops import (default_interpret, flash_attention,
                               flash_decode, make_unroll_kernel, on_tpu,
                               ttt_probe_scan, wkv_scan)

__all__ = ["default_interpret", "flash_attention", "flash_decode",
           "make_unroll_kernel", "on_tpu", "ttt_probe_scan", "wkv_scan"]
