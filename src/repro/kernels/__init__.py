from repro.kernels.ops import (ProbeStepOut, default_interpret,
                               flash_attention, flash_decode,
                               make_unroll_kernel, on_tpu,
                               paged_flash_decode,
                               paged_flash_packed_chunk,
                               paged_flash_prefill_chunk,
                               serving_probe_step,
                               ttt_probe_batched, ttt_probe_scan, wkv_scan)

__all__ = ["ProbeStepOut", "default_interpret", "flash_attention",
           "flash_decode", "make_unroll_kernel", "on_tpu",
           "paged_flash_decode", "paged_flash_packed_chunk",
           "paged_flash_prefill_chunk",
           "serving_probe_step", "ttt_probe_batched",
           "ttt_probe_scan", "wkv_scan"]
