"""RWKV6 WKV recurrence — chunked Pallas TPU kernel.

Per head: S_t = diag(w_t) S_{t-1} + k_t v_t^T ; out_t = r_t (diag(u) k_t v_t^T
+ S_{t-1}).  The recurrence is sequential in T; the kernel streams
(r, k, v, w) chunks HBM->VMEM with grid = (B, H, T/CT) and carries the
(d, d) state in VMEM scratch across chunks of the same (batch, head) — the
TPU-native adaptation of the GPU chunked linear-attention formulation
(sequential grid instead of a block-parallel prefix scan; see DESIGN.md §3).
Inside a chunk the per-step rank-1 update runs on the VPU/MXU out of VMEM.

r,k,v,w: (B, T, H, d) f32 ; u: (H, d) ; s0: (B, H, d, d)
-> out (B, T, H, d), s_final (B, H, d, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref, s_s, *,
            ct: int, n_c: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_s[...] = s0_ref[0, 0]

    u = u_ref[0]                                       # (d,)

    def step(i, _):
        r = r_ref[0, i, 0, :]                          # (d,)
        k = k_ref[0, i, 0, :]
        v = v_ref[0, i, 0, :]
        w = w_ref[0, i, 0, :]
        kv = k[:, None] * v[None, :]                   # (d, d)
        att = u[:, None] * kv + s_s[...]
        o_ref[0, i, 0, :] = jnp.dot(r, att, preferred_element_type=jnp.float32)
        s_s[...] = w[:, None] * s_s[...] + kv
        return 0

    jax.lax.fori_loop(0, ct, step, 0)

    @pl.when(ci == n_c - 1)
    def _fin():
        sf_ref[0, 0] = s_s[...]


@functools.partial(jax.jit, static_argnames=("ct", "interpret"))
def wkv_scan(r, k, v, w, u, s0, *, ct: int = 64, interpret: bool = True):
    b, t, h, d = r.shape
    ct = min(ct, t)
    pad = (-t) % ct
    if pad:
        zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    t_pad = r.shape[1]
    n_c = t_pad // ct
    f32 = jnp.float32
    kernel = functools.partial(_kernel, ct=ct, n_c=n_c)
    out, sf = pl.pallas_call(
        kernel,
        grid=(b, h, n_c),
        in_specs=[
            pl.BlockSpec((1, ct, 1, d), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, ct, 1, d), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, ct, 1, d), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, ct, 1, d), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, ci: (h_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, 1, d), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_pad, h, d), f32),
            jax.ShapeDtypeStruct((b, h, d, d), f32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), f32)],
        interpret=interpret,
    )(r.astype(f32), k.astype(f32), v.astype(f32), w.astype(f32),
      u.astype(f32), s0.astype(f32))
    return out[:, :t], sf
