"""Parallel context: logical-axis sharding rules threaded through model code.

MaxText-style logical axes: model code annotates activations with *logical*
names ("batch", "kv_seq", ...); the launcher installs a ``ParallelContext``
mapping logical names to mesh axes.  Outside any context (unit tests, CPU
smoke runs) every annotation is a no-op, so model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# jax >= 0.5 promotes shard_map to the top level (kwarg ``check_vma``);
# 0.4.x keeps it experimental (kwarg ``check_rep``).  One shim for both.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, **kw):
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    rules: Mapping[str, Axes]            # logical axis -> mesh axes
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    ep_moe: bool = False                 # expert-parallel shard_map MoE path
    flash_decode: bool = False           # seq-sharded decode attention
    attn_impl: str = "einsum"            # einsum | blockwise | pallas
    remat: bool = False

    def spec(self, logical: Sequence[Optional[str]]) -> PartitionSpec:
        out = []
        for name in logical:
            out.append(None if name is None else self.rules.get(name))
        return PartitionSpec(*out)

    def sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


_CTX: contextvars.ContextVar[Optional[ParallelContext]] = \
    contextvars.ContextVar("repro_parallel_ctx", default=None)


def current_ctx() -> Optional[ParallelContext]:
    return _CTX.get()


@contextlib.contextmanager
def use_parallel(ctx: ParallelContext):
    token = _CTX.set(ctx)
    try:
        with ctx.mesh:
            yield ctx
    finally:
        _CTX.reset(token)


def constrain(x, *logical: Optional[str]):
    """Annotate ``x`` with the mesh axes the active rules map ``logical`` to."""
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))


def attn_impl() -> str:
    ctx = current_ctx()
    return "einsum" if ctx is None else ctx.attn_impl


def remat_enabled() -> bool:
    ctx = current_ctx()
    return bool(ctx and ctx.remat)
