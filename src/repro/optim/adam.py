"""Optimizers from scratch (no optax): Adam(W) + gradient clipping + schedules.

API mirrors optax minimally: ``opt.init(params)``, ``opt.update(grads, state,
params) -> (updates, state)`` where ``params + updates`` is the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: Any = 1e-3                    # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), z(), z())

    def update(self, grads, state: AdamState, params=None):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -(lr * (m / bc1) / (jnp.sqrt(v / bc2) + self.eps))
            if self.weight_decay and p is not None:
                u = u - lr * self.weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype if p is not None else u.dtype)

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step, mu, nu)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
