from repro.optim.adam import Adam, AdamState, cosine_schedule, global_norm

__all__ = ["Adam", "AdamState", "cosine_schedule", "global_norm"]
