"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.

Topology (TPU v5e): single pod = 16 x 16 = 256 chips (data x model);
multi-pod = 2 pods x 256 = 512 chips with the "pod" axis crossing DCN.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for multi-device unit tests (host platform, 4-8 devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n])
