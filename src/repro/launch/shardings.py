"""Sharding policies: logical axes -> mesh axes, per workload.

Logical axis names used by the model zoo's Param declarations and activation
constraints:

    batch             activations' leading batch dim
    vocab             embedding / lm-head vocab dim (padded to 256)
    embed / embed2    d_model dims of weight matrices
    qkv / kv_qkv      flattened (n_heads*d_head) / (n_kv*d_head) dims
    mlp               d_ff
    experts           MoE expert dim
    inner / inner2    mamba d_inner / 2*d_inner
    heads             per-head parameter tables (rwkv u) — kept replicated
    layers            stacked-layer dim — never sharded

Policies:
    train "fsdp_tp"   batch -> (pod,)data; weights 2D-sharded
                      (embed -> data, ffn/heads/vocab/experts -> model):
                      ZeRO-3-style — GSPMD all-gathers weights per layer.
    train "dp_tp"     weights replicated over data, TP over model (small
                      models where FSDP gather traffic isn't worth it).
    serve "tp"        weights TP over model only; batch -> data; KV cache
                      sequence-sharded over model for flash-decode.

Every full-scale divisibility requirement these policies rely on is asserted
in tests/test_arch_smoke.py::test_divisibility_for_model_axis.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import InputShape, ModelConfig
from repro.parallel import ParallelContext


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def train_rules(multi_pod: bool, fsdp: bool) -> Dict[str, Any]:
    rules: Dict[str, Any] = {
        "batch": data_axes(multi_pod),
        "vocab": "model",
        "mlp": "model",
        "qkv": "model",
        "kv_qkv": "model",
        "experts": "model",
        "inner": "model",
        "inner2": "model",
        "heads": None,
        "embed2": None,
        "layers": None,
    }
    if fsdp:
        rules["embed"] = "data"       # second weight dim -> ZeRO-3 style
    else:
        rules["embed"] = None
    return rules


def serve_rules(multi_pod: bool) -> Dict[str, Any]:
    rules = train_rules(multi_pod, fsdp=False)
    rules["batch"] = data_axes(multi_pod)
    # kv cache sequence dim for flash-decode partial-softmax sharding
    rules["kv_seq"] = "model"
    return rules


def use_fsdp(cfg: ModelConfig) -> bool:
    """FSDP pays off above a few B params (weight memory dominates)."""
    return cfg.param_count() > 4e9


# ---------------------------------------------------------------------------
# batch / state specs

def batch_spec_tree(batch_tree, rules) -> Any:
    """PartitionSpecs for a train/prefill/decode input batch."""
    b = rules["batch"]

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "pos":
            return P()
        nd = len(leaf.shape)
        return P(*((b,) + (None,) * (nd - 1))) if nd else P()

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def decode_state_specs(cfg: ModelConfig, state_tree, rules,
                       flash_decode: bool) -> Any:
    """PartitionSpecs for the decode state pytree, by arch family."""
    b = rules["batch"]
    seq_ax = rules.get("kv_seq") if flash_decode else None

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        if name in ("k", "v", "k_scale", "v_scale"):
            # (L, B, KV, S, d) — sequence-shard for flash decode
            return P(*((None, b, None, seq_ax, None)[:len(shape)]))
        if name in ("cross_k", "cross_v"):
            return P(None, b, None, None, None)
        if name == "wkv":                       # (L,B,H,dk,dv)
            return P(None, b, "model", None, None)
        if name in ("tm_x", "cm_x"):            # (L,B,d)
            return P(None, b, None)
        if name == "conv":                      # (L,B,w-1,di)
            return P(None, b, None, "model")
        if name == "ssm":                       # (L,B,di,ds)
            return P(None, b, "model", None)
        # probe state etc: batch-leading
        nd = len(shape)
        return P(*((b,) + (None,) * (nd - 1))) if nd else P()

    return jax.tree_util.tree_map_with_path(one, state_tree)


def probe_state_specs(state_tree, rules) -> Any:
    b = rules["batch"]

    def one(leaf):
        nd = len(leaf.shape)
        return P(*((b,) + (None,) * (nd - 1))) if nd else P()

    return jax.tree.map(one, state_tree)


def with_shardings(tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def one(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, tree, spec_tree)


def replicated(tree, mesh):
    return jax.tree.map(
        lambda sds: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                         sharding=NamedSharding(mesh, P())),
        tree)


def make_context(cfg: ModelConfig, mesh, shape: InputShape, *,
                 multi_pod: bool) -> ParallelContext:
    """The ParallelContext the launchers install while tracing."""
    is_train = shape.kind == "train"
    is_decode = shape.kind == "decode"
    fsdp = use_fsdp(cfg) and is_train
    rules = train_rules(multi_pod, fsdp) if is_train else serve_rules(multi_pod)
    if cfg.moe is not None:
        # expert weights are (E, d, f): experts already claim the model axis
        rules = dict(rules)
        rules["mlp"] = None
        if is_train and cfg.param_count() < 4e9:
            # §Perf B3: small fine-grained MoE — spend the model axis on
            # experts ONLY; attention runs data-parallel (no per-layer TP
            # all-reduce) and the now-replicated weights are FSDP-sharded
            # over data to keep optimizer state per-device small.
            rules["qkv"] = rules["kv_qkv"] = None
            rules["embed"] = "data"   # vocab stays on model (logits sharding)
    # batch must divide the data axes (long_500k has global_batch=1: the
    # whole data axis goes idle and all parallelism is sequence/model-side)
    n_data = 1
    for a in data_axes(multi_pod):
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if shape.global_batch % n_data:
        rules = dict(rules)
        rules["batch"] = None
    # flash-decode needs a sequence dim in the cache (not rwkv; hymba/dense ok)
    flash = is_decode and cfg.arch_type != "ssm"
    return ParallelContext(
        mesh=mesh, rules=rules, data_axes=data_axes(multi_pod),
        model_axis="model",
        ep_moe=cfg.moe is not None,
        flash_decode=flash,
        attn_impl="blockwise",
        remat=is_train,
    )
