"""Serving driver: batched requests through the ORCA-calibrated engine.

CPU demo (reduced config, synthetic prompts, freshly meta-trained probe):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 4 --max-new-tokens 96

The probe is meta-trained on trajectories extracted from THIS model
(repro.serving.extract_trajectories), LTT-calibrated at --delta, then the
engine serves with the calibrated threshold — the full Algorithm 2 loop.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import stopping as S
from repro.core.labels import consistent_labels
from repro.core.pipeline import train_ttt_probe
from repro.core.probe import ProbeConfig
from repro.models import build
from repro.serving import ServeConfig, ServingEngine, extract_trajectories
from repro.trajectories.synthetic import TrajectorySet, TrajectoryDistribution


def trajectories_from_model(model, params, n: int, prompt_len: int,
                            max_new: int, tokens_per_step: int, seed: int
                            ) -> TrajectorySet:
    """Harvest step embeddings + self-consistency answers from the model."""
    cfg = model.cfg
    rng = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(rng, (n, prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (n, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            rng, (n, cfg.frontend.n_tokens, cfg.d_model)) * 0.02
    phis, toks = extract_trajectories(model, params, batch, prompt_len,
                                      max_new, tokens_per_step)
    n_steps = phis.shape[1]
    # "answer" proxy per step: the last token of the step
    answers = toks[:, tokens_per_step - 1::tokens_per_step][:, :n_steps]
    mask = np.ones((n, n_steps), bool)
    labels = consistent_labels(answers, mask)
    tau = np.argmax(labels > 0.5, axis=1)
    tau = np.where(labels.max(1) > 0.5, tau, n_steps)
    return TrajectorySet(phis=phis.astype(np.float32), mask=mask,
                         correct=labels > 0.5, answers=answers, tau=tau,
                         lengths=np.full(n, n_steps),
                         dist=TrajectoryDistribution("model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=96)
    ap.add_argument("--tokens-per-step", type=int, default=8)
    ap.add_argument("--train-trajectories", type=int, default=24)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: harvesting {args.train_trajectories} "
          "calibration trajectories from the model")
    ts = trajectories_from_model(model, params, args.train_trajectories,
                                 args.prompt_len, args.max_new_tokens,
                                 args.tokens_per_step, args.seed)
    half = len(ts) // 2
    train, cal = ts.subset(np.arange(half)), ts.subset(np.arange(half, len(ts)))
    pc = ProbeConfig(d_phi=cfg.d_model, smooth_window=4)
    probe = train_ttt_probe(train, "consistent", pc, epochs=args.epochs,
                            epoch_select=False, seed=args.seed)
    s_cal = probe.scores(cal)
    lab = consistent_labels(cal.answers, cal.mask)
    res = S.calibrate_and_evaluate(s_cal, lab, cal.mask, s_cal, lab, cal.mask,
                                   delta=args.delta)
    lam = res.lam if np.isfinite(res.lam) else 0.99
    print(f"[serve] LTT-calibrated lambda* = {lam:.3f} "
          f"(cal savings {res.savings:.3f}, error {res.error:.3f})")

    scfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.max_new_tokens, lam=float(lam),
                       burn_in=2)
    eng = ServingEngine(model, params, pc, probe.theta, scfg)
    rng = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(rng, (args.requests, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (args.requests, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            rng, (args.requests, cfg.frontend.n_tokens, cfg.d_model)) * 0.02
    out = eng.serve(batch, prompt_len=args.prompt_len)
    print(f"[serve] {args.requests} requests: stop steps {out.stop_step.tolist()} "
          f"(-1 = budget), step savings {out.savings:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
