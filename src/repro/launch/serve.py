"""Serving driver: a request queue through the continuous-batching ORCA
scheduler, with the static-batch engine as the side-by-side baseline.

CPU demo (reduced config, synthetic prompts, freshly meta-trained probe):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 8 --slots 2 --max-new-tokens 96

The probe is meta-trained on trajectories extracted from THIS model
(repro.serving.extract_trajectories), LTT-calibrated at --delta through the
unified ``Calibrator`` protocol, then ``repro.api.engine`` serves the queue:
every ORCA stop evicts its slot, which is refilled from the queue on the
next step — the calibrated savings turn into requests/s.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro import api as orca
from repro.configs import get_config
from repro.core.labels import consistent_labels
from repro.models import build
from repro.serving import (ServeConfig, ServingEngine, extract_trajectories,
                           make_request, serve_queue_static)
from repro.trajectories.synthetic import TrajectorySet, TrajectoryDistribution


def model_inputs(cfg, rng, n: int, prompt_len: int):
    batch = {"tokens": jax.random.randint(rng, (n, prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (n, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            rng, (n, cfg.frontend.n_tokens, cfg.d_model)) * 0.02
    return batch


def trajectories_from_model(model, params, n: int, prompt_len: int,
                            max_new: int, tokens_per_step: int, seed: int
                            ) -> TrajectorySet:
    """Harvest step embeddings + self-consistency answers from the model."""
    cfg = model.cfg
    batch = model_inputs(cfg, jax.random.PRNGKey(seed), n, prompt_len)
    phis, toks = extract_trajectories(model, params, batch, prompt_len,
                                      max_new, tokens_per_step)
    n_steps = phis.shape[1]
    # "answer" proxy per step: the last token of the step
    answers = toks[:, tokens_per_step - 1::tokens_per_step][:, :n_steps]
    mask = np.ones((n, n_steps), bool)
    labels = consistent_labels(answers, mask)
    tau = np.argmax(labels > 0.5, axis=1)
    tau = np.where(labels.max(1) > 0.5, tau, n_steps)
    return TrajectorySet(phis=phis.astype(np.float32), mask=mask,
                         correct=labels > 0.5, answers=answers, tau=tau,
                         lengths=np.full(n, n_steps),
                         dist=TrajectoryDistribution("model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=96)
    ap.add_argument("--tokens-per-step", type=int, default=8)
    ap.add_argument("--train-trajectories", type=int, default=24)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--burn-in", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static-baseline", action="store_true",
                    help="also serve the same queue through the static-batch "
                         "engine and print the comparison")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV cache (block-pool "
                         "admission, prefix sharing, eviction reclaims "
                         "pages)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size (0 -> dense-equivalent HBM)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked prefill: schedule prompt prefill in "
                         "chunks of this many tokens through the unified "
                         "token-budget step (0 = admission-time prefill)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="max tokens per unified step (0 -> slots + chunk)")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative draft-verify decode: each running "
                         "slot proposes up to this many tokens per step "
                         "(current token + drafts), scored in one fused "
                         "verify pass; accepted prefix commits, rejects "
                         "roll back (0 = one-token decode)")
    ap.add_argument("--spec-tree", default="",
                    help="tree speculative decode as 'W.D': each running "
                         "slot proposes W branches x D tokens verified in "
                         "one pass under per-token ancestor masks; the "
                         "longest accepted root-to-leaf path commits "
                         "(exclusive with --spec-tokens; '' = off)")
    ap.add_argument("--draft-cache", type=int, default=4096,
                    help="capacity (n-gram keys) of the fleet-wide shared "
                         "draft cache that feeds speculation from "
                         "verifier-accepted continuations (0 = model "
                         "self-draft only)")
    ap.add_argument("--policy", default="fifo",
                    choices=("fifo", "priority", "edf", "ttft"),
                    help="scheduling policy: admission order, per-step "
                         "prefill share and victim selection (priority "
                         "classes come from --batch-every; edf ranks by "
                         "per-class deadline)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable involuntary preemption (spill/restore "
                         "of lower-priority residents when capacity fails "
                         "for a more urgent unit) — wait-only admission")
    ap.add_argument("--no-pack", action="store_true",
                    help="disable multi-request chunk packing (one request "
                         "per prefill chunk, the pre-packing composer)")
    ap.add_argument("--pack-max", type=int, default=4,
                    help="max requests fused into one packed chunk")
    ap.add_argument("--batch-every", type=int, default=0,
                    help="mark every Nth request as batch-class "
                         "(priority 1) to exercise the priority policy "
                         "(0 = all latency-class)")
    ap.add_argument("--group-size", type=int, default=1,
                    help="self-consistency samples per prompt: each request "
                         "becomes a gang-admitted group of N samples "
                         "sharing its prompt pages (1 = classic serving)")
    ap.add_argument("--no-consensus", action="store_true",
                    help="serve groups WITHOUT the consensus stop (every "
                         "sample runs to its own per-request ORCA stop)")
    ap.add_argument("--consensus-delta", type=float, default=0.0,
                    help="risk level for the group-consensus LTT "
                         "calibration (0 -> reuse --delta)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulated fleet hosts: >1 serves through a "
                         "FleetRouter (per-host engine/pool/policy, "
                         "pressure-balanced prefix-affine placement; "
                         "--num-blocks is the TOTAL page budget split "
                         "across hosts, --slots is PER HOST)")
    ap.add_argument("--placement", default="pressure",
                    choices=("pressure", "roundrobin"),
                    help="fleet placement policy (--hosts > 1): 'pressure' "
                         "= least-loaded with prefix affinity, "
                         "'roundrobin' = locality-blind rotation")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: harvesting {args.train_trajectories} "
          "calibration trajectories from the model")
    ts = trajectories_from_model(model, params, args.train_trajectories,
                                 args.prompt_len, args.max_new_tokens,
                                 args.tokens_per_step, args.seed)
    half = len(ts) // 2
    train, cal = ts.subset(np.arange(half)), ts.subset(np.arange(half, len(ts)))

    from repro.core.probe import ProbeConfig
    calib = orca.fit(train, mode="consistent", method="ttt",
                     pc=ProbeConfig(d_phi=cfg.d_model, smooth_window=4),
                     epochs=args.epochs, epoch_select=False, seed=args.seed)
    # demo fallback keeps eviction observable on tiny random-weight models
    lam = orca.calibrated_lambda(calib, cal, args.delta, fallback=0.99)
    print(f"[serve] LTT-calibrated lambda* = {lam:.3f}")

    # group consensus: LTT-calibrate the agreement threshold over groups
    # formed from the calibration split (group-level exchangeability),
    # with per-sample votes frozen at the deployed per-sample stop
    consensus = None
    if args.group_size > 1 and not args.no_consensus:
        c_delta = args.consensus_delta or args.delta
        g_cal = orca.GroupCalibrator(min_votes=2, burn_in=args.burn_in)
        traces = orca.groups_from_trajectories(cal, calib.scores(cal),
                                               args.group_size,
                                               seed=args.seed)
        g_cal.calibrate(traces, c_delta, per_sample_lam=lam,
                        per_sample_burn_in=args.burn_in)
        if not np.isfinite(g_cal.lam):
            # same demo fallback policy as calibrated_lambda: keep the
            # consensus observable on tiny random-weight models
            g_cal.lam = 0.95
        consensus = g_cal
        print(f"[serve] consensus threshold g* = {g_cal.lam:.3f} "
              f"(delta={c_delta}, {len(traces)} calibration groups)")

    # the ~20 CLI flags become ONE ServeConfig: from_args maps the flag
    # names (slots -> n_slots, no_pack -> pack_chunks, 0 -> None for the
    # optional ints); runtime-computed values ride in as overrides
    serve_cfg = ServeConfig.from_args(
        args, lam=float(lam), consensus=consensus,
        consensus_delta=(args.consensus_delta or None
                         if consensus is not None else None),
        placement=args.placement)
    if args.hosts > 1:
        sched = orca.fleet(model, params, calib, config=serve_cfg)
        print(f"[serve] fleet: {args.hosts} hosts x {args.slots} slots, "
              f"placement={args.placement}")
    else:
        sched = orca.engine(model, params, calib, config=serve_cfg)
    batch = model_inputs(cfg, jax.random.PRNGKey(args.seed + 1),
                         args.requests, args.prompt_len)
    extra_keys = [k for k in batch if k != "tokens"]
    if args.group_size > 1:
        from repro.serving import make_group
        reqs = [r for i in range(args.requests)
                for r in make_group(
                    batch["tokens"][i], args.group_size, group_id=i,
                    extra={k: batch[k][i:i + 1] for k in extra_keys},
                    priority=(1 if args.batch_every
                              and i % args.batch_every == 0 else 0))]
    else:
        reqs = [make_request(batch["tokens"][i],
                             extra={k: batch[k][i:i + 1] for k in extra_keys},
                             priority=(1 if args.batch_every
                                       and i % args.batch_every == 0 else 0))
                for i in range(args.requests)]
    done, fleet = sched.run(reqs)
    for r in done:
        print(f"[serve]   req {r.req_id}: {r.state.value:8s} "
              f"admitted@{r.admitted_step:3d} done@{r.completed_step:3d} "
              f"stop_step={r.stop_step:3d} tokens={len(r.tokens)}")
    print(f"[serve] fleet: {fleet.n_requests} requests / {fleet.n_slots} "
          f"slots in {fleet.engine_steps} engine steps "
          f"({fleet.wall_time_s:.2f}s) — {fleet.requests_per_s:.2f} req/s, "
          f"{fleet.tokens_per_s:.1f} tok/s, slot utilization "
          f"{fleet.slot_utilization:.2f}, mean step savings "
          f"{fleet.mean_step_savings:.3f}")
    if args.paged:
        print(f"[serve] pool: {fleet.pool_blocks} pages "
              f"(x{args.block_size} tokens), peak in use "
              f"{fleet.peak_blocks_in_use}, prefill skips "
              f"{fleet.prefill_skips}")
    if args.hosts > 1:
        print(f"[serve] routing: {fleet.n_hosts} hosts, "
              f"{fleet.routed_affine} prefix-affine placements")
    if args.group_size > 1:
        print(f"[serve] groups: {fleet.consensus_groups} consensus stops "
              f"(mean step {fleet.consensus_steps:.1f}), "
              f"{fleet.samples_cancelled} siblings cancelled, group savings "
              f"{fleet.group_savings:.0f} steps (mean "
              f"{fleet.group_savings_mean:.3f}), "
              f"{fleet.cancel_freed_blocks} pages freed at cancel")
    if args.spec_tokens or args.spec_tree:
        # the acceptance summary only means something when speculation is
        # actually on — one-token runs stay silent here
        print(f"[serve] speculative: {fleet.spec_tokens_accepted}/"
              f"{fleet.spec_tokens_proposed} drafts accepted "
              f"(rate {fleet.acceptance_rate:.2f}), accepted length "
              f"p50/p99 {fleet.accepted_len_p50:.1f}/"
              f"{fleet.accepted_len_p99:.1f}")
        if args.spec_tree:
            print(f"[serve] tree: {fleet.tree_nodes_proposed} nodes "
                  f"proposed, accepted path length p50/p99 "
                  f"{fleet.tree_path_accepted_p50:.1f}/"
                  f"{fleet.tree_path_accepted_p99:.1f}")
        if fleet.draft_cache_hits or fleet.draft_cache_misses:
            print(f"[serve] draft cache: {fleet.draft_cache_hits} hits / "
                  f"{fleet.draft_cache_misses} misses "
                  f"(rate {fleet.draft_cache_hit_rate:.2f})")
    if fleet.preemptions:
        print(f"[serve] preemption: {fleet.preemptions} spills / "
              f"{fleet.restores} restores ({fleet.spilled_blocks} pages "
              "copied to host)")
    print(f"[serve] latency: ttft p50/p99 {fleet.ttft_ms_p50:.1f}/"
          f"{fleet.ttft_ms_p99:.1f} ms, step stall p50/p99 "
          f"{fleet.stall_ms_p50:.1f}/{fleet.stall_ms_p99:.1f} ms"
          + (f", {fleet.prefill_chunks} prefill chunks "
             f"({fleet.packed_chunks} packed, peak "
             f"{fleet.peak_step_tokens} tok/step)"
             if args.chunk_tokens else " (admission-time prefill)"))
    for key in sorted(fleet.per_class):
        print(f"[serve]   {key}: {fleet.per_class[key]:.1f}")

    if args.static_baseline:
        pc, theta = calib.serving_params()
        scfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                           max_new_tokens=args.max_new_tokens,
                           lam=float(lam), burn_in=args.burn_in)
        eng = ServingEngine(model, params, pc, theta, scfg)
        base = serve_queue_static(eng, batch, args.prompt_len, args.slots)
        print(f"[serve] static-batch baseline: {base.engine_steps} engine "
              f"steps ({base.wall_time_s:.2f}s) — "
              f"{args.requests / base.wall_time_s:.2f} req/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
