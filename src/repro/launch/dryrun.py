import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, with NO device allocation (ShapeDtypeStruct inputs).
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
#         --shape train_4k [--multi-pod] [--json out.json]
#
# Success criteria (deliverable e): ``.lower().compile()`` succeeds on the
# 16x16 single-pod mesh and the 2x16x16 multi-pod mesh for every pair;
# ``compiled.memory_analysis()`` proves the per-device footprint and
# ``cost_analysis()`` + the optimized HLO feed the roofline report
# (EXPERIMENTS.md §Dry-run / §Roofline).

import argparse
import functools
import json
import sys
import time
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALIASES, INPUT_SHAPES, InputShape, get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim import Adam
from repro.parallel import use_parallel
from repro.roofline import build_report
from repro.serving import init_probe_state, make_serve_step


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _cast(tree, dtype):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def skip_reason(cfg, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k":
        if cfg.arch_type == "audio":
            return ("skipped: encoder-decoder audio head has an architecturally "
                    "bounded decoder context (DESIGN.md §Arch-applicability)")
        if not cfg.supports_long_context:
            return "skipped: full attention without a sub-quadratic variant"
    return None


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool,
               compile_: bool = True, microbatches: int = 1,
               donate_cache: bool = True, hlo_out: Optional[str] = None
               ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if reason:
        return {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = build(cfg)
    ctx = SH.make_context(cfg, mesh, shape, multi_pod=multi_pod)
    rules = ctx.rules
    t0 = time.time()

    with use_parallel(ctx):
        params_a = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = model.specs(rules)
        batch_a = model.batch_specs(shape)
        bspecs = SH.batch_spec_tree(batch_a, rules)

        if shape.kind == "train":
            params_s = SH.with_shardings(params_a, pspecs, mesh)
            opt = Adam(lr=1e-4)
            opt_a = jax.eval_shape(opt.init, params_a)
            from repro.optim.adam import AdamState
            ospecs = AdamState(step=P(), mu=pspecs, nu=pspecs)
            opt_s = SH.with_shardings(opt_a, ospecs, mesh)
            batch_s = SH.with_shardings(batch_a, bspecs, mesh)

            bspec_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), bspecs)

            def grads_of(params, batch):
                if microbatches <= 1:
                    (loss, _), grads = jax.value_and_grad(
                        lambda p: model.loss(p, batch), has_aux=True)(params)
                    return loss, grads
                # gradient accumulation: scan over microbatches; activation
                # working set scales by 1/microbatches (§Perf iteration A)
                mb = jax.tree.map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:]), batch)

                def acc(carry, mbatch):
                    loss_c, g_c = carry
                    mbatch = jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(x, s),
                        mbatch, bspec_shardings)
                    (loss, _), g = jax.value_and_grad(
                        lambda p: model.loss(p, mbatch), has_aux=True)(params)
                    g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_c, g)
                    return (loss_c + loss, g), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0), g0), mb)
                scale = 1.0 / microbatches
                return loss * scale, jax.tree.map(lambda g: g * scale, grads)

            def train_step(params, opt_state, batch):
                loss, grads = grads_of(params, batch)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                      params, updates)
                return params, opt_state, loss

            out_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                      jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs),
                      NamedSharding(mesh, P()))
            lowered = jax.jit(train_step, out_shardings=out_sh,
                              donate_argnums=(0, 1)).lower(
                params_s, opt_s, batch_s)

        elif shape.kind == "prefill":
            params_s = SH.with_shardings(_cast(params_a, jnp.bfloat16),
                                         pspecs, mesh)
            batch_s = SH.with_shardings(batch_a, bspecs, mesh)
            cache_len = shape.seq_len

            def prefill_step(params, batch):
                state, last_h, _ = model.prefill(cfg, params, batch, cache_len)
                return state, last_h

            state_a = jax.eval_shape(
                lambda: model.init_decode_state(shape.global_batch, cache_len))
            sspecs = SH.decode_state_specs(cfg, state_a, rules,
                                           flash_decode=cfg.arch_type != "ssm")
            out_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs),
                      NamedSharding(mesh, P(rules["batch"], None)))
            lowered = jax.jit(prefill_step, out_shardings=out_sh).lower(
                params_s, batch_s)

        else:  # decode
            cache_len, window = model.decode_geometry(shape)
            params_s = SH.with_shardings(_cast(params_a, jnp.bfloat16),
                                         pspecs, mesh)
            pc = ProbeConfig(d_phi=cfg.d_model)
            theta_a = jax.eval_shape(
                functools.partial(init_outer, pc), jax.random.PRNGKey(0))
            theta_s = SH.replicated(theta_a, mesh)
            from repro.serving import ServeConfig
            scfg = ServeConfig(tokens_per_step=16, lam=0.9)
            serve_step = make_serve_step(model, pc, scfg, window=window)
            state_a = jax.eval_shape(
                lambda: model.init_decode_state(shape.global_batch, cache_len))
            sspecs = SH.decode_state_specs(cfg, state_a, rules,
                                           flash_decode=ctx.flash_decode)
            state_s = SH.with_shardings(state_a, sspecs, mesh)
            probe_a = jax.eval_shape(
                lambda: init_probe_state(pc, jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), theta_a),
                    shape.global_batch, cfg.d_model))
            pspecs_probe = SH.probe_state_specs(probe_a, rules)
            probe_s = SH.with_shardings(probe_a, pspecs_probe, mesh)
            token_s = jax.ShapeDtypeStruct(
                (shape.global_batch,), jnp.int32,
                sharding=NamedSharding(mesh, P(rules["batch"])))
            pos_s = jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(mesh, P()))
            out_sh = (NamedSharding(mesh, P(rules["batch"])),
                      jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs),
                      jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   pspecs_probe))
            # donating the KV cache + probe state lets XLA update them in
            # place instead of double-buffering the whole cache (§Perf C)
            donate = (3, 5) if donate_cache else ()
            lowered = jax.jit(serve_step, out_shardings=out_sh,
                              donate_argnums=donate).lower(
                params_s, theta_s, token_s, state_s, pos_s, probe_s)

        t_lower = time.time() - t0
        result = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
                  "status": "lowered", "lower_s": round(t_lower, 1)}
        if not compile_:
            return result
        t0 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 1)
        result["status"] = "ok"
        # --- artifacts for the roofline
        mem = compiled.memory_analysis()
        memory_stats = None
        if mem is not None:
            memory_stats = {
                "bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
                "temp": getattr(mem, "temp_size_in_bytes", None),
                "args": getattr(mem, "argument_size_in_bytes", None),
                "output": getattr(mem, "output_size_in_bytes", None),
            }
            result["memory_analysis"] = memory_stats
        try:
            costs = compiled.cost_analysis()
            cost = costs if isinstance(costs, dict) else (
                costs[0] if costs else None)
        except Exception:
            cost = None
        hlo = compiled.as_text()
        if hlo_out:
            with open(hlo_out, "w") as f:
                f.write(hlo)
        report = build_report(cfg, shape, mesh_name, chips, hlo, cost=cost,
                              memory_stats=memory_stats)
        result["roofline"] = json.loads(report.to_json())
        result["options"] = {"microbatches": microbatches,
                             "donate_cache": donate_cache}
        return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {sorted(ALIASES)} or internal ids")
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true",
                    help="stop after .lower() (debugging)")
    ap.add_argument("--json", default=None, help="write result json here")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--hlo-out", default=None)
    args = ap.parse_args(argv)
    res = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                     compile_=not args.no_compile,
                     microbatches=args.microbatches,
                     donate_cache=not args.no_donate, hlo_out=args.hlo_out)
    print(json.dumps(res, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
    return 0 if res["status"] in ("ok", "skip", "lowered") else 1


if __name__ == "__main__":
    sys.exit(main())
