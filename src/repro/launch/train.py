"""Training driver.

CPU demo (reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 50 --batch 8 --seq 128

On a real cluster the same step function runs under the production mesh:
pass --mesh single|multi (requires 256/512 devices) and the full config.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save_pytree
from repro.configs import InputShape, get_config
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim import Adam, cosine_schedule
from repro.parallel import use_parallel


def make_train_step(model, opt):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, loss, metrics

    return jax.jit(train_step, donate_argnums=(0, 1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    shape = InputShape("cli", args.seq, args.batch, "train")

    ctx = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = SH.make_context(cfg, mesh, shape, multi_pod=args.mesh == "multi")

    opt = Adam(lr=cosine_schedule(args.lr, args.warmup, args.steps),
               clip_norm=1.0)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=model.text_len(shape),
        global_batch=args.batch, seed=args.seed))

    def run():
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            step = latest_step(args.ckpt_dir)
            params = restore(params, f"{args.ckpt_dir}/step_{step}")
            start = step
            print(f"[train] resumed from step {step}")
        step_fn = make_train_step(model, opt)
        n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
              f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")
        losses = []
        t0 = time.time()
        for i, batch in enumerate(pipe):
            step = start + i
            if step >= args.steps:
                break
            extra = {}
            if cfg.arch_type == "vlm":
                extra["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
                    jnp.dtype(cfg.dtype))
            if cfg.arch_type == "audio":
                extra["frames"] = jnp.zeros(
                    (args.batch, cfg.frontend.n_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            jb = {**{k: jnp.asarray(v) for k, v in batch.items()}, **extra}
            params, opt_state, loss, _ = step_fn(params, opt_state, jb)
            losses.append(float(loss))
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                print(f"[train] step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                      f"({dt/ (i+1):.2f}s/step)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_pytree(params, args.ckpt_dir, step=step + 1)
        if args.ckpt_dir:
            save_pytree(params, args.ckpt_dir, step=start + len(losses))
        print(f"[train] done: loss {losses[0]:.4f} -> {np.mean(losses[-5:]):.4f}")
        return losses

    if ctx is not None:
        with use_parallel(ctx):
            losses = run()
    else:
        losses = run()
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
