"""Pytree checkpointing: flattened-key npz + json metadata, atomic writes.

Layout: <dir>/step_<N>/arrays.npz + meta.json.  Restoration matches by
flattened key path against a template pytree (shape/dtype checked), so
params / optimizer states / probe states all round-trip.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "//"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _fmt(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(tree, directory: str, step: Optional[int] = None,
                meta: Optional[Dict[str, Any]] = None) -> str:
    sub = f"step_{step}" if step is not None else "final"
    target = os.path.join(directory, sub)
    tmp = tempfile.mkdtemp(dir=directory if os.path.isdir(directory)
                           else None, prefix=".ckpt_tmp_")
    try:
        os.makedirs(directory, exist_ok=True)
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        if os.path.isdir(target):
            shutil.rmtree(target)
        os.replace(tmp, target)           # atomic publish
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return target


def load_pytree(path: str) -> Dict[str, np.ndarray]:
    with np.load(os.path.join(path, "arrays.npz")) as z:
        return {k: z[k] for k in z.files}


def restore(template, path: str):
    """Restore arrays into the structure of ``template`` (strict match)."""
    flat = load_pytree(path)
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    out_leaves = []
    for pth, leaf in leaves_paths[0]:
        key = _SEP.join(_fmt(p) for p in pth)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: shape {arr.shape} != template {want}")
        out_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], out_leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
