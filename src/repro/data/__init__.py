from repro.data.tokens import TokenPipeline, TokenPipelineConfig, device_batch

__all__ = ["TokenPipeline", "TokenPipelineConfig", "device_batch"]
