"""Synthetic token pipeline for LM training.

Deterministic, seekable, shardable: batch ``i`` is a pure function of
(seed, i), so any host in a multi-pod job can materialize its shard without
coordination, and resuming from step N needs no state.  The generator is a
char-free Zipf-Markov process: a Zipfian unigram prior blended with a
first-order transition structure so the loss curve is non-trivial (a model
can actually learn something).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_blend: float = 0.5     # P = blend * markov + (1-blend) * zipf
    n_states: int = 64            # markov granularity (token % n_states)


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rs = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        zipf = ranks ** (-cfg.zipf_a)
        self._zipf = zipf / zipf.sum()
        # per-state preferred continuation distribution: a random permutation
        # of the zipf weights per state
        self._perms = np.stack([rs.permutation(v) for _ in range(cfg.n_states)])

    def _batch_np(self, index: int) -> np.ndarray:
        cfg = self.cfg
        rs = np.random.RandomState((cfg.seed * 1_000_003 + index) % (2**31 - 1))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        out = np.empty((b, s + 1), np.int32)
        out[:, 0] = rs.randint(0, v, size=b)
        # vectorized Markov-Zipf sampling over time
        for t in range(1, s + 1):
            state = out[:, t - 1] % cfg.n_states
            u = rs.rand(b)
            use_markov = u < cfg.markov_blend
            samp = rs.choice(v, size=b, p=self._zipf)
            permuted = self._perms[state, samp]
            out[:, t] = np.where(use_markov, permuted, samp)
        return out

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Global batch ``index``: {"tokens": (B,S), "targets": (B,S)}."""
        seq = self._batch_np(index)
        return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def device_batch(batch: Dict[str, np.ndarray], shardings=None):
    """Place a host batch on devices (with NamedShardings when provided)."""
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, shardings)
