from repro.trajectories.synthetic import (DISTRIBUTIONS, TrajectorySet,
                                          TrajectoryDistribution,
                                          corpus_splits, generate,
                                          ood_benchmark)

__all__ = ["DISTRIBUTIONS", "TrajectorySet", "TrajectoryDistribution",
           "corpus_splits", "generate", "ood_benchmark"]
