"""Synthetic reasoning-trajectory generator.

Offline stand-in for the paper's data substrate (DeepSeek-R1 rollouts +
teacher labels are unavailable in this container — see DESIGN.md §7).  The
generator reproduces the *structure* the paper's method exploits.  Writing
phi_t's component along the shared "breakthrough" direction u explicitly:

    u . phi_t = u_base_i + walk_i(t) + jump * ramp(t - tau_i) + noise

  * ``u_base_i``  — instance-specific offset (thought-pattern baseline);
    its population spread is what forces a *static* probe to run a
    conservative threshold.  The TTT inner loop (C_t = 0 updates) suppresses
    it within the first few steps of each instance.
  * ``walk_i(t)`` — slow within-trajectory drift (thought patterns change
    across stages of a long CoT — the sample-level shift of Section 1).
    Online adaptation tracks it; a static probe cannot.
  * ``jump``      — the reasoning breakthrough at latent time tau_i
    (some problems never transition).
  * dataset-level OOD shift moves the MEAN of u_base_i (``shift_u``):
    negative => a static threshold goes conservative (low savings, the
    paper's MATH-500 static pattern), positive => static fires prematurely
    (high error, the paper's GPQA static pattern).

Off-u dimensions carry instance baselines, smooth stage drift and iid noise
(what PCA/logreg must average over).  Labels are monotone cumulative
[0..0,1..1]; per-step answers churn before tau and are stable afterwards
(for the consistency-label mode).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrajectoryDistribution:
    """Parameters of the trajectory-generating process for one 'dataset'."""
    name: str
    d_phi: int = 256
    t_min: int = 40
    t_max: int = 120
    # u-direction (signal) components
    base_u_scale: float = 0.8        # spread of instance offsets along u
    shift_u: float = 0.0             # OOD: mean shift along u
    walk_step: float = 0.08          # per-step std of the within-traj walk
    signal_scale: float = 1.5        # breakthrough jump along u
    ramp_steps: int = 3
    # breakthrough rotation of the INSTANCE subspace: after tau the instance
    # baseline follows an AR(1) rotation (alpha per step), i.e. the
    # post-breakthrough phase is CONTINUALLY novel relative to the adapted
    # pre-transition representation.  A static probe cannot see this
    # (instance-specific); the TTT probe's accumulated suppression decays on
    # the rotating states so its score stays elevated (Appendix B's "no
    # longer well explained by the current adaptation").
    post_alpha: float = 0.7
    # off-u components (PER-DIM scales; |phi|^2 ~ O(d) as for real
    # mean-pooled hidden states — the regime where eta=0.01 inner updates
    # suppress instance offsets within a few steps, see DESIGN.md §7)
    baseline_scale: float = 1.5      # isotropic instance baseline, per-dim
    drift_scale: float = 0.25        # stage drift magnitude, per-dim
    noise_scale: float = 0.35        # per-step iid noise, per-dim
    # difficulty
    p_unsolved: float = 0.08         # problems with no transition
    tau_frac_lo: float = 0.15        # transition time ~ U[lo, hi] * T
    tau_frac_hi: float = 0.7
    answer_vocab: int = 50
    seed_offset: int = 0


# In-distribution corpus (paper's 5K: s1K + OpenR1 + DeepMath mix) and the
# five OOD benchmarks as distribution presets.  shift_u signs follow the
# paper's observed static-probe failure modes (conservative on MATH-500,
# premature on GPQA; AIME: longer traces, later transitions, more unsolved).
DISTRIBUTIONS: Dict[str, TrajectoryDistribution] = {
    "corpus5k": TrajectoryDistribution("corpus5k"),
    "math500": TrajectoryDistribution(
        "math500", shift_u=-1.2, t_min=30, t_max=90, signal_scale=3.0,
        tau_frac_lo=0.1, tau_frac_hi=0.45, p_unsolved=0.03, seed_offset=1),
    "gpqa": TrajectoryDistribution(
        "gpqa", shift_u=+1.0, base_u_scale=0.8, t_min=60, t_max=160,
        tau_frac_lo=0.15, tau_frac_hi=0.6, p_unsolved=0.25, seed_offset=2),
    "aime24": TrajectoryDistribution(
        "aime24", shift_u=-0.6, walk_step=0.08, t_min=80, t_max=200,
        tau_frac_lo=0.4, tau_frac_hi=0.9, p_unsolved=0.35, seed_offset=3),
    "aime25": TrajectoryDistribution(
        "aime25", shift_u=-0.8, walk_step=0.07, t_min=80, t_max=200,
        tau_frac_lo=0.45, tau_frac_hi=0.95, p_unsolved=0.4, seed_offset=4),
    "aime26": TrajectoryDistribution(
        "aime26", shift_u=-0.5, walk_step=0.09, signal_scale=2.4,
        t_min=90, t_max=220, tau_frac_lo=0.5, tau_frac_hi=0.95,
        p_unsolved=0.45, seed_offset=5),
}


@dataclasses.dataclass
class TrajectorySet:
    phis: np.ndarray         # (N, T_max, d_phi) float32
    mask: np.ndarray         # (N, T_max) bool
    correct: np.ndarray      # (N, T_max) bool — per-step correctness
    answers: np.ndarray      # (N, T_max) int — per-step answer ids
    tau: np.ndarray          # (N,) latent transition step (T_i if none)
    lengths: np.ndarray      # (N,)
    dist: TrajectoryDistribution

    def __len__(self):
        return self.phis.shape[0]

    def subset(self, idx) -> "TrajectorySet":
        return TrajectorySet(self.phis[idx], self.mask[idx], self.correct[idx],
                             self.answers[idx], self.tau[idx], self.lengths[idx],
                             self.dist)


def _shared_structure(d_phi: int):
    """Directions shared across ALL datasets (fixed seed): breakthrough u and
    the off-u stage-drift directions."""
    rs = np.random.RandomState(1234)
    u = rs.randn(d_phi)
    u /= np.linalg.norm(u)
    drift_dirs = rs.randn(4, d_phi)
    # orthogonalize drift dirs against u so scales stay interpretable
    drift_dirs -= np.outer(drift_dirs @ u, u)
    drift_dirs /= np.linalg.norm(drift_dirs, axis=1, keepdims=True)
    return u, drift_dirs


def generate(dist: TrajectoryDistribution, n: int, seed: int = 0
             ) -> TrajectorySet:
    rs = np.random.RandomState(seed * 1000 + 7 + dist.seed_offset)
    d = dist.d_phi
    u, drift_dirs = _shared_structure(d)
    t_max = dist.t_max
    lengths = rs.randint(dist.t_min, dist.t_max + 1, size=n)
    phis = np.zeros((n, t_max, d), np.float32)
    mask = np.zeros((n, t_max), bool)
    correct = np.zeros((n, t_max), bool)
    answers = np.zeros((n, t_max), np.int64)
    tau = np.zeros((n,), np.int64)
    for i in range(n):
        T = lengths[i]
        mask[i, :T] = True
        unsolved = rs.rand() < dist.p_unsolved
        if unsolved:
            ti = T
        else:
            ti = int(T * rs.uniform(dist.tau_frac_lo, dist.tau_frac_hi))
            ti = min(max(ti, 1), T - 1)
        tau[i] = ti
        # --- u-direction: base offset + slow walk + breakthrough jump
        u_base = dist.shift_u + rs.randn() * dist.base_u_scale
        walk = np.cumsum(rs.randn(T) * dist.walk_step)
        ramp = np.clip((np.arange(T) - ti + 1) / max(dist.ramp_steps, 1), 0.0, 1.0)
        if unsolved:
            ramp[:] = 0.0
        u_coef = u_base + walk + dist.signal_scale * ramp
        # --- off-u: instance baseline (pre / rotated-post), stage drift, noise
        b = rs.randn(d) * dist.baseline_scale
        b -= (b @ u) * u
        t_ax = np.arange(T)[:, None] / max(T - 1, 1)
        freqs = rs.uniform(0.5, 2.0, size=(1, 4))
        phases = rs.uniform(0, 2 * np.pi, size=(1, 4))
        stages = np.sin(2 * np.pi * freqs * t_ax + phases)          # (T,4)
        drift = stages @ drift_dirs * dist.drift_scale
        noise = rs.randn(T, d) * dist.noise_scale
        # AR(1) rotation of the instance baseline after the breakthrough
        base_t = np.empty((T, d))
        bt = b.copy()
        al = dist.post_alpha
        for tstep in range(T):
            if tstep >= ti and not unsolved:
                xi = rs.randn(d) * dist.baseline_scale
                xi -= (xi @ u) * u
                bt = al * bt + np.sqrt(max(1.0 - al * al, 0.0)) * xi
            base_t[tstep] = bt
        phis[i, :T] = (base_t + drift + noise
                       + u_coef[:, None] * u[None])
        correct[i, :T] = (np.arange(T) >= ti) if not unsolved else False
        # --- answers: churn before the transition, stable afterwards
        final = rs.randint(1, dist.answer_vocab)
        churn = rs.randint(1, dist.answer_vocab, size=T)
        for tstep in range(1, T):
            if rs.rand() < 0.5:
                churn[tstep] = churn[tstep - 1]
        ans = np.where(np.arange(T) >= ti, final, churn)
        if unsolved:
            ans = churn
            ans[-1] = rs.randint(1, dist.answer_vocab)
        answers[i, :T] = ans
    return TrajectorySet(phis, mask, correct, answers, tau, lengths, dist)


def corpus_splits(n_train: int = 600, n_cal: int = 200, n_test: int = 200,
                  d_phi: int = 256, seed: int = 0
                  ) -> Tuple[TrajectorySet, TrajectorySet, TrajectorySet]:
    """The paper's 3:1:1 split of the training corpus."""
    dist = dataclasses.replace(DISTRIBUTIONS["corpus5k"], d_phi=d_phi)
    full = generate(dist, n_train + n_cal + n_test, seed=seed)
    idx = np.random.RandomState(seed).permutation(len(full))
    return (full.subset(idx[:n_train]),
            full.subset(idx[n_train:n_train + n_cal]),
            full.subset(idx[n_train + n_cal:]))


def ood_benchmark(name: str, n: int, d_phi: int = 256, seed: int = 17
                  ) -> TrajectorySet:
    dist = dataclasses.replace(DISTRIBUTIONS[name], d_phi=d_phi)
    return generate(dist, n, seed=seed)
