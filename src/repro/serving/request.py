"""Request-centric serving: lifecycle + per-request / fleet metrics.

A ``Request`` is one user sequence moving through the ORCA server:

    WAITING -> PREFILL -> RUNNING -> STOPPED | FINISHED | CANCELLED
                  ^          |
                  +- SWAPPED +   (involuntary preemption: spilled to host,
                                  re-admitted before WAITING)

``STOPPED`` means the calibrated ORCA threshold test fired (the paper's
early stop — the request's remaining step budget is *returned to the
fleet* by evicting its slot); ``FINISHED`` means the token budget ran out
without a stop; ``CANCELLED`` means a *voluntary* mid-flight release — the
request's self-consistency group reached its calibrated consensus and the
scheduler evicted the still-running sibling (no per-request stop fired:
``stop_step`` stays -1).  ``SWAPPED`` is *involuntary* and *temporary*: the
scheduler preempted the request to make room for a strictly-higher-priority
admission, spilling its KV pages AND its probe fast-weight state to host
RAM (``engine.Spill``); it re-enters PREFILL or RUNNING via ``restore``
with byte-identical state, so its eventual stop decision is unchanged.
Metrics use the shared savings helper
(``repro.core.stopping.step_savings``) so served savings are directly
comparable with offline-evaluated savings; a cancelled sample's *unspent*
budget is counted as group savings (``FleetMetrics.group_savings`` — the
TOTAL unspent reasoning steps across groups; ``group_savings_mean`` is the
per-group mean fraction), and CANCELLED requests are excluded from the
TTFT / queue-wait percentiles so by-design cancellations don't pollute the
latency tails.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import stopping as S


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"      # RESIDENT: owns a slot; prompt prefills in
    #                          token-budget chunks (or one admission shot)
    RUNNING = "running"
    STOPPED = "stopped"      # ORCA threshold fired -> slot evicted
    FINISHED = "finished"    # token budget exhausted without a stop
    CANCELLED = "cancelled"  # voluntary release: group consensus fired and
    #                          the scheduler evicted this still-running
    #                          sibling mid-flight (stop_step stays -1)
    SWAPPED = "swapped"      # involuntarily preempted: KV + probe state
    #                          spilled to host RAM, queued for restore
    #                          ahead of WAITING admissions


_req_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One sequence request plus everything observed while serving it."""
    inputs: Dict[str, jnp.ndarray]        # batch-1 model inputs (prompt)
    prompt_len: int
    max_new_tokens: Optional[int] = None  # None -> engine default
    # priority class for the scheduling policy: lower = more
    # latency-sensitive (0 = interactive, 1 = batch by convention); FIFO
    # policies ignore it, PriorityPolicy admits lower classes first
    priority: int = 0
    # optional per-request latency deadline for the EDF policy (ms from
    # submission); None -> the policy falls back to the class SLO
    deadline_ms: Optional[float] = None
    # self-consistency group membership: samples sharing a group_id are
    # gang-admitted atomically and consensus-stopped together (None = the
    # classic independent request; group code is then completely inert)
    group_id: Optional[int] = None
    sample_idx: int = 0                   # position within the group
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))

    # lifecycle (owned by the scheduler)
    state: RequestState = RequestState.WAITING
    slot: int = -1
    host: int = -1                        # fleet host that served it (-1 =
    #                                       single-host / not yet placed)
    submitted_step: int = 0               # engine step at enqueue
    admitted_step: int = -1               # engine step at slot admission
    completed_step: int = -1              # engine step at stop/finish

    # chunked prefill (PREFILL is a RESIDENT phase: the request owns a slot
    # and its prompt is processed in token-budget chunks by the unified step)
    prefill_progress: int = 0             # prompt tokens already prefilled
    first_token_step: int = -1            # engine step of the first decode token
    ttft_s: float = -1.0                  # wall-clock time to first token
    queue_wait_s: float = -1.0            # wall-clock WAITING -> PREFILL

    # observations
    tokens: List[int] = dataclasses.field(default_factory=list)
    scores: List[float] = dataclasses.field(default_factory=list)
    # per-reasoning-step answer hash (the token decoded at each probe
    # boundary) — the vote the group consensus aggregates
    answers: List[int] = dataclasses.field(default_factory=list)
    stop_step: int = -1                   # reasoning step at ORCA stop (-1 budget)
    steps_run: int = 0                    # reasoning steps actually executed

    # paged-KV bookkeeping (owned by the scheduler's BlockPool)
    block_ids: List[int] = dataclasses.field(default_factory=list)
    n_shared_blocks: int = 0              # prefix pages shared with a donor
    prefill_skipped: bool = False         # prompt was resident: no prefill

    # preemption bookkeeping (owned by the scheduler)
    n_preempted: int = 0                  # times spilled to host RAM
    restored_step: int = -1               # engine step of the last restore

    # speculative decode (owned by the scheduler; stay 0/empty without it)
    spec_proposed: int = 0                # draft tokens proposed (excl. the
    #                                       current token of each block)
    spec_accepted: int = 0                # draft tokens the verifier kept
    accepted_lens: List[int] = dataclasses.field(default_factory=list)
    #                                       per-step accepted length g (incl.
    #                                       the current token; g in [0, k])
    # tree speculative decode (PR 10; stay 0/empty without spec_tree)
    tree_nodes: int = 0                   # tree nodes proposed (excl. roots)
    tree_path_lens: List[int] = dataclasses.field(default_factory=list)
    #                                       per-step accepted root-to-leaf
    #                                       path length (== accepted_lens
    #                                       entries, kept separate so linear
    #                                       and tree runs aggregate apart)
    draft_hits: int = 0                   # shared draft-cache lookups that hit
    draft_misses: int = 0                 # ... that missed (self-draft fallback)

    @property
    def done(self) -> bool:
        return self.state in (RequestState.STOPPED, RequestState.FINISHED,
                              RequestState.CANCELLED)

    @property
    def queue_steps(self) -> int:
        """Engine steps spent waiting for a slot."""
        return max(self.admitted_step - self.submitted_step, 0)

    def savings(self, tokens_per_step: int, default_max_new: int) -> float:
        """Fraction of the reasoning-step budget returned to the fleet."""
        budget = max((self.max_new_tokens or default_max_new)
                     // tokens_per_step, 1)
        return float(S.step_savings(self.steps_run, budget))


def make_request(tokens: np.ndarray, *, extra: Optional[Dict] = None,
                 max_new_tokens: Optional[int] = None,
                 priority: int = 0, group_id: Optional[int] = None,
                 sample_idx: int = 0) -> Request:
    """Build a Request from a 1-D prompt token array (+ optional extra
    modalities, e.g. ``patch_embeds`` / ``frames`` with a leading batch-1
    axis).  ``priority`` is the scheduling class (lower = more
    latency-sensitive); ``group_id``/``sample_idx`` mark a self-consistency
    sample (see ``repro.serving.groups.make_group``)."""
    tokens = jnp.asarray(tokens, jnp.int32)
    assert tokens.ndim == 1, "one request = one unbatched prompt"
    inputs: Dict[str, jnp.ndarray] = {"tokens": tokens[None]}
    if extra:
        inputs.update({k: jnp.asarray(v) for k, v in extra.items()})
    return Request(inputs=inputs, prompt_len=int(tokens.shape[0]),
                   max_new_tokens=max_new_tokens, priority=int(priority),
                   group_id=group_id, sample_idx=int(sample_idx))


@dataclasses.dataclass
class FleetMetrics:
    """Aggregate serving metrics over one scheduler run."""
    n_requests: int
    n_slots: int
    engine_steps: int            # fused decode steps executed
    active_slot_steps: int       # slot-steps spent on live requests
    wall_time_s: float
    requests_per_s: float
    tokens_per_s: float
    slot_utilization: float      # active_slot_steps / (engine_steps * n_slots)
    mean_step_savings: float     # mean over requests (shared metric)
    mean_queue_steps: float
    # paged-KV pool stats (zero when serving from the dense cache)
    pool_blocks: int = 0         # usable pages in the pool
    peak_blocks_in_use: int = 0  # high-water mark across the run
    prefill_skips: int = 0       # admissions served from a resident prefix
    # latency distribution (chunked-prefill tentpole: stall-free serving).
    # A "stall" is one scheduler iteration's wall time — the latency every
    # resident decode slot pays before its next token.  Admission-time
    # prefill spikes the tail (one batch-1 full-prompt prefill blocks the
    # fleet); the chunked unified step bounds every iteration by the token
    # budget, so p99 stall collapses toward p50.
    ttft_ms_p50: float = 0.0     # wall-clock time-to-first-token percentiles
    ttft_ms_p99: float = 0.0
    stall_ms_p50: float = 0.0    # per-step decode-stall percentiles
    stall_ms_p99: float = 0.0
    prefill_chunks: int = 0      # chunk launches (0 = admission-time prefill)
    # packed-chunk composer stats (tentpole: multi-request chunks)
    packed_chunks: int = 0       # chunk launches carrying >= 2 requests
    peak_step_tokens: int = 0    # max decode+prefill tokens in one step
    # per-priority-class latency: {"c<priority>_<metric>": value} for
    # ttft_ms_p50/p99 and queue_wait_ms_p50/p99 (WAITING -> PREFILL wall
    # time) — the observable the priority/TTFT policies tune
    per_class: Dict[str, float] = dataclasses.field(default_factory=dict)
    # group serving (self-consistency tentpole): consensus + cancellation
    samples_cancelled: int = 0   # siblings evicted by consensus
    consensus_groups: int = 0    # groups whose consensus fired
    consensus_steps: float = 0.0  # mean reasoning-step index of consensus
    group_savings: float = 0.0   # TOTAL unspent reasoning steps across all
    #                              groups — cancelled samples' UNSPENT budget
    #                              (what the fleet actually got back)
    group_savings_mean: float = 0.0  # mean over groups of 1 - spent/budget
    cancel_freed_blocks: int = 0  # KV pages that died at cancellation
    # preemption (overload-safe serving tentpole)
    preemptions: int = 0         # victims spilled to host RAM
    restores: int = 0            # spilled requests resumed
    spilled_blocks: int = 0      # KV pages copied out across all spills
    # fleet serving (multi-host tentpole): n_slots above is PER HOST
    n_hosts: int = 1
    routed_affine: int = 0       # placements that followed prefix affinity
    # speculative decode (draft-verify tentpole): acceptance accounting
    # over non-CANCELLED requests (consensus kills say nothing about the
    # drafter, same exclusion as the TTFT tails)
    spec_tokens_proposed: int = 0   # draft tokens proposed fleet-wide
    spec_tokens_accepted: int = 0   # draft tokens the verifier kept
    acceptance_rate: float = 0.0    # accepted / proposed (0 when disabled)
    accepted_len_p50: float = 0.0   # per-step accepted length percentiles
    accepted_len_p99: float = 0.0   # (incl. the block's current token)
    # tree speculative decode (PR 10): tree-shape + shared-draft-cache
    # accounting, same CANCELLED exclusion as the linear spec stats
    tree_nodes_proposed: int = 0    # candidate tree nodes verified (excl.
    #                                 roots; 0 for linear/disabled runs)
    tree_path_accepted_p50: float = 0.0  # accepted root-to-leaf path length
    tree_path_accepted_p99: float = 0.0  # percentiles (incl. the root)
    draft_cache_hits: int = 0       # shared draft-cache lookups that hit
    draft_cache_misses: int = 0     # ... that missed (self-draft fallback)
    draft_cache_hit_rate: float = 0.0    # hits / lookups (0 when disabled)

    def row(self) -> Dict[str, float]:
        return {
            **self.per_class,
            "spec_tokens_proposed": self.spec_tokens_proposed,
            "spec_tokens_accepted": self.spec_tokens_accepted,
            "acceptance_rate": self.acceptance_rate,
            "accepted_len_p50": self.accepted_len_p50,
            "accepted_len_p99": self.accepted_len_p99,
            "tree_nodes_proposed": self.tree_nodes_proposed,
            "tree_path_accepted_p50": self.tree_path_accepted_p50,
            "tree_path_accepted_p99": self.tree_path_accepted_p99,
            "draft_cache_hits": self.draft_cache_hits,
            "draft_cache_misses": self.draft_cache_misses,
            "draft_cache_hit_rate": self.draft_cache_hit_rate,
            "n_hosts": self.n_hosts,
            "routed_affine": self.routed_affine,
            "samples_cancelled": self.samples_cancelled,
            "consensus_groups": self.consensus_groups,
            "consensus_steps": self.consensus_steps,
            "group_savings": self.group_savings,
            "group_savings_mean": self.group_savings_mean,
            "cancel_freed_blocks": self.cancel_freed_blocks,
            "preemptions": self.preemptions,
            "restores": self.restores,
            "spilled_blocks": self.spilled_blocks,
            "packed_chunks": self.packed_chunks,
            "peak_step_tokens": self.peak_step_tokens,
            "requests": self.n_requests, "slots": self.n_slots,
            "engine_steps": self.engine_steps,
            "requests_per_s": self.requests_per_s,
            "tokens_per_s": self.tokens_per_s,
            "slot_utilization": self.slot_utilization,
            "mean_step_savings": self.mean_step_savings,
            "mean_queue_steps": self.mean_queue_steps,
            "pool_blocks": self.pool_blocks,
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "prefill_skips": self.prefill_skips,
            "ttft_ms_p50": self.ttft_ms_p50,
            "ttft_ms_p99": self.ttft_ms_p99,
            "stall_ms_p50": self.stall_ms_p50,
            "stall_ms_p99": self.stall_ms_p99,
            "prefill_chunks": self.prefill_chunks,
        }


def latency_stats(requests: List[Request]
                  ) -> "tuple[float, float, Dict[str, float]]":
    """TTFT percentiles + per-priority-class latency tails for a served
    population: ``(ttft_ms_p50, ttft_ms_p99, per_class)``.

    Shared by ``OrcaScheduler._metrics`` and the ``FleetRouter``'s
    aggregation, so fleet-level percentiles are recomputed over the union
    of requests rather than averaged across per-host percentiles (which
    would be wrong for tails).  CANCELLED samples are excluded: a
    consensus cancellation is a by-design eviction, not a latency event,
    and would otherwise pollute the tails the policies tune.
    """
    kept = [r for r in requests if r.state is not RequestState.CANCELLED]
    ttft = np.array([r.ttft_s for r in kept if r.ttft_s >= 0]) * 1e3
    per_class: Dict[str, float] = {}
    for cls in sorted({r.priority for r in kept}):
        in_cls = [r for r in kept if r.priority == cls]
        c_ttft = np.array([r.ttft_s for r in in_cls
                           if r.ttft_s >= 0]) * 1e3
        c_wait = np.array([r.queue_wait_s for r in in_cls
                           if r.queue_wait_s >= 0]) * 1e3
        for key, arr in (("ttft_ms", c_ttft), ("queue_wait_ms", c_wait)):
            if arr.size:
                per_class[f"c{cls}_{key}_p50"] = \
                    float(np.percentile(arr, 50))
                per_class[f"c{cls}_{key}_p99"] = \
                    float(np.percentile(arr, 99))
    return (float(np.percentile(ttft, 50)) if ttft.size else 0.0,
            float(np.percentile(ttft, 99)) if ttft.size else 0.0,
            per_class)


def spec_stats(requests: List[Request]) -> Dict[str, float]:
    """Speculative-decode aggregation over a served population, as
    ``FleetMetrics`` keyword arguments: linear acceptance accounting,
    tree-path percentiles and shared draft-cache hit rates, all computed
    purely from per-request counters.

    The ONE home for this math: ``OrcaScheduler._metrics`` and the
    ``FleetRouter``'s cross-host aggregation both call it (the router over
    the union of every host's requests), so fleet-level percentiles are
    recomputed over the union rather than averaged across per-host
    percentiles, and the two layers can never drift apart.  CANCELLED
    samples are excluded throughout — a consensus kill says nothing about
    the drafter (the same exclusion the TTFT tails use).
    """
    live = [r for r in requests if r.state is not RequestState.CANCELLED]
    sp = sum(r.spec_proposed for r in live)
    sa = sum(r.spec_accepted for r in live)
    alens = np.asarray([g for r in live for g in r.accepted_lens],
                       np.float64)
    plens = np.asarray([g for r in live for g in r.tree_path_lens],
                       np.float64)
    hits = sum(r.draft_hits for r in live)
    misses = sum(r.draft_misses for r in live)
    return {
        "spec_tokens_proposed": int(sp),
        "spec_tokens_accepted": int(sa),
        "acceptance_rate": float(sa / sp) if sp else 0.0,
        "accepted_len_p50": (float(np.percentile(alens, 50))
                             if alens.size else 0.0),
        "accepted_len_p99": (float(np.percentile(alens, 99))
                             if alens.size else 0.0),
        "tree_nodes_proposed": int(sum(r.tree_nodes for r in live)),
        "tree_path_accepted_p50": (float(np.percentile(plens, 50))
                                   if plens.size else 0.0),
        "tree_path_accepted_p99": (float(np.percentile(plens, 99))
                                   if plens.size else 0.0),
        "draft_cache_hits": int(hits),
        "draft_cache_misses": int(misses),
        "draft_cache_hit_rate": (float(hits / (hits + misses))
                                 if (hits + misses) else 0.0),
    }
