"""Self-consistency group serving: the gang-scheduled request unit.

ORCA's self-consistency traffic arrives as *groups* of N samples of one
prompt.  ``RequestGroup`` makes the group a first-class scheduling unit:

* **gang admission** — all N samples are admitted atomically (slots AND
  pages reserved all-or-nothing), so a group is never half-resident and
  its samples advance in lockstep;
* **shared prompt pages** — the group's prompt K/V is reserved once; the
  siblings share the donor's full prompt pages by refcount exactly like
  the prefix registry path (``kv_pool``), without waiting for a prior
  request to populate the registry;
* **consensus stop** — per step, the ``GroupCalibrator``
  (``repro.core.calibrator``) aggregates the samples' latest probe scores
  into a confidence-weighted answer vote; the moment the vote clears the
  LTT-calibrated threshold, every still-running sibling is CANCELLED
  mid-flight (slot + pages + probe state returned to the fleet) and the
  samples' unspent budget becomes ``FleetMetrics.group_savings``.

With ``group_id=None`` requests (or consensus disabled) the whole layer is
inert: stop decisions are byte-identical to the ungrouped engine under
every policy/packing/paging configuration (asserted in
``tests/test_group_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request, RequestState, make_request


@dataclasses.dataclass
class RequestGroup:
    """One self-consistency group: N samples of one prompt, gang-scheduled
    and consensus-stopped as a unit."""
    group_id: int
    requests: List[Request]
    # consensus outcome (set by the scheduler when the vote fires)
    consensus_step: int = -1        # ENGINE step the decision fired (-1: no)
    consensus_index: int = -1       # reasoning-step index of the decision
    consensus_answer: int = -1      # the winning answer hash
    consensus_agreement: float = 0.0

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def decided(self) -> bool:
        return self.consensus_step >= 0

    @property
    def done(self) -> bool:
        return all(r.done for r in self.requests)

    @property
    def n_cancelled(self) -> int:
        return sum(r.state is RequestState.CANCELLED for r in self.requests)

    def budget_steps(self, tokens_per_step: int, default_max_new: int) -> int:
        """Total reasoning-step budget across the group's samples."""
        return sum(max((r.max_new_tokens or default_max_new)
                       // tokens_per_step, 1) for r in self.requests)

    def steps_spent(self) -> int:
        return sum(r.steps_run for r in self.requests)

    def savings(self, tokens_per_step: int, default_max_new: int) -> float:
        """Group-level savings 1 - spent/budget: unlike the per-request
        metric this COUNTS a cancelled sample's unspent budget (the whole
        point of consensus cancellation) instead of dropping it."""
        budget = self.budget_steps(tokens_per_step, default_max_new)
        return max(1.0 - self.steps_spent() / max(budget, 1), 0.0)


def make_group(tokens: np.ndarray, n_samples: int, *, group_id: int,
               extra: Optional[Dict] = None,
               max_new_tokens: Optional[int] = None,
               priority: int = 0) -> List[Request]:
    """Build N sample Requests of one prompt sharing a ``group_id``."""
    if n_samples < 1:
        raise ValueError(f"n_samples must be >= 1, got {n_samples}")
    return [make_request(tokens, extra=extra, max_new_tokens=max_new_tokens,
                         priority=priority, group_id=group_id, sample_idx=j)
            for j in range(n_samples)]


def group_requests(requests: Sequence[Request]
                   ) -> Tuple[List[List[Request]], List[RequestGroup]]:
    """Partition a request sequence into gang-admission units.

    A unit is the atomic thing the admission loop schedules: a singleton
    for an ungrouped request, the whole group otherwise.  Units keep
    arrival order (a group sits at its FIRST member's position); within a
    group, samples are ordered by ``sample_idx`` (normalized to arrival
    order when callers left them all at the default 0).  Returns
    ``(units, groups)``; with no grouped requests ``units`` is exactly the
    one-request-per-unit sequence, so the grouped admission loop reduces
    to the classic one byte-for-byte.
    """
    units: List[List[Request]] = []
    by_group: Dict[int, List[Request]] = {}
    for req in requests:
        if req.group_id is None:
            units.append([req])
            continue
        members = by_group.get(req.group_id)
        if members is None:
            members = by_group[req.group_id] = [req]
            units.append(members)
        else:
            members.append(req)
    groups = []
    for gid, members in by_group.items():
        if len({r.sample_idx for r in members}) != len(members):
            for j, r in enumerate(members):      # normalize duplicate idxs
                r.sample_idx = j
        members.sort(key=lambda r: (r.sample_idx, r.req_id))
        groups.append(RequestGroup(group_id=gid, requests=list(members)))
    return units, groups
