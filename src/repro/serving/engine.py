"""Batched serving engine with ORCA risk-controlled early stopping.

The paper's technique is a first-class serving feature here: ``serve_step``
fuses one decode step of the base model with the ORCA probe — step-embedding
accumulation (mean-pooled hidden states over ``tokens_per_step`` tokens),
score-then-update fast-weight dynamics (Algorithm 2 lines 8-16), rolling
smoothing and the calibrated threshold test.

Two execution modes share the fused step:

* ``ServingEngine.serve`` — the legacy static batch: prefill once, run until
  every sequence stops.  Stopped sequences freeze in place and burn their
  slot as no-op compute.  Kept as the baseline (and a deprecation shim) for
  ``repro.serving.scheduler.OrcaScheduler``.
* ``ContinuousServingEngine`` — slot-level admit / release / step: each batch
  row ("slot") carries its own request at its own sequence position (vector
  ``pos``), its own per-slot prefill-injected KV cache and its own freshly
  reset probe fast-weight state.  The moment ORCA stops a sequence its slot
  is evicted and refilled from the waiting queue — calibrated early stopping
  becomes the capacity mechanism, not just shorter trajectories.

With ``chunk_tokens=N`` the continuous engine's fused step becomes the
UNIFIED token-budget step (Sarathi-style chunked prefill): prompt prefill is
no longer an admission-time, batch-1, per-prompt-length-compiled event but
schedulable work — each iteration decodes every slot AND processes up to N
prompt tokens of one mid-prefill request (``begin_prefill`` -> per-step
``ChunkWork`` -> ``finish_prefill``), all inside ONE fixed-shape executable.
Mid-prefill slots ride along as parked no-op rows: the probe's boundary gate
never touches their state and their no-op K/V write is masked (dense) or
NULL-paged (paged), so chunking changes *when* prefill work happens, never
*what* the probe sees.

This same ``serve_step`` is what the decode-shape dry-runs lower to the
production mesh: the deployed procedure (model + adaptation + stopping) is
exactly what gets calibrated, per the paper's validity argument.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import probe as P
from repro.core import stopping as S
from repro.core.probe import ProbeConfig
from repro.kernels import ops as K
from repro.kernels import ref as KR
from repro.kernels.ttt_probe import ProbeStepOut as KernelOut
from repro.kernels.ttt_probe import SpecProbeOut, serving_probe_step
from repro.models import attention as A
from repro.models.registry import Model
from repro.serving.config import ServeConfig
from repro.serving.kv_pool import NULL_BLOCK, blocks_needed, pad_row


class ProbeState(NamedTuple):
    """Vectorized fast-weight + smoothing state for a batch of sequences."""
    W: jnp.ndarray          # (B, f)
    b: jnp.ndarray          # (B,)
    hid_sum: jnp.ndarray    # (B, d_phi) accumulating the current step
    tok_count: jnp.ndarray  # (B,) tokens into the current step
    ring: jnp.ndarray       # (B, window) last raw scores
    n_scores: jnp.ndarray   # (B,) number of scores emitted
    smoothed: jnp.ndarray   # (B,) current smoothed score
    stopped: jnp.ndarray    # (B,) bool
    stop_step: jnp.ndarray  # (B,) reasoning step at which stopped (-1 active)


def init_probe_state(pc: ProbeConfig, theta, batch: int,
                     d_phi: int) -> ProbeState:
    f = pc.feat_dim
    return ProbeState(
        W=jnp.broadcast_to(theta["W0"], (batch, f)).astype(jnp.float32),
        b=jnp.broadcast_to(theta["b0"], (batch,)).astype(jnp.float32),
        hid_sum=jnp.zeros((batch, d_phi), jnp.float32),
        tok_count=jnp.zeros((batch,), jnp.int32),
        ring=jnp.zeros((batch, pc.smooth_window), jnp.float32),
        n_scores=jnp.zeros((batch,), jnp.int32),
        smoothed=jnp.zeros((batch,), jnp.float32),
        stopped=jnp.zeros((batch,), bool),
        stop_step=jnp.full((batch,), -1, jnp.int32),
    )


def reset_probe_slot(pc: ProbeConfig, theta, st: ProbeState, slot,
                     active: bool = True) -> ProbeState:
    """Reset ONE row of a batched ProbeState.

    ``active=True`` (admission): the slot gets a fresh single-request state —
    fast weights back to (W0, b0), empty smoothing ring, zero counters — so
    its score trajectory is identical to a fresh single-request run.
    ``active=False`` (eviction / empty slot): same reset but parked with
    ``stopped=True``, which makes the fused step treat the row as no-op
    compute (no fast-weight updates, token held constant).
    """
    one = init_probe_state(pc, theta, 1, st.hid_sum.shape[-1])
    if not active:
        one = one._replace(stopped=jnp.ones((1,), bool))
    slot = jnp.asarray(slot, jnp.int32)
    return ProbeState(*[
        jax.lax.dynamic_update_slice_in_dim(full, part.astype(full.dtype),
                                            slot, axis=0)
        for full, part in zip(st, one)])


def write_probe_slot(st: ProbeState, slot, rows: ProbeState) -> ProbeState:
    """Write ONE row of a batched ProbeState from saved per-leaf rows.

    The restore half of preemption: ``rows`` holds one batch-axis-free row
    per leaf (exactly what ``Spill`` captured at preempt time), written back
    with the same dynamic-update-slice the reset path uses — so a restored
    slot's probe state is bit-identical to the moment it was spilled.
    """
    slot = jnp.asarray(slot, jnp.int32)
    return ProbeState(*[
        jax.lax.dynamic_update_slice_in_dim(
            full, part[None].astype(full.dtype), slot, axis=0)
        for full, part in zip(st, rows)])


def inject_prefill(model: Model, params, state, batch_one: Dict[str, jnp.ndarray],
                   slot, cache_len: int):
    """Prefill ONE request (batch 1) and write its decode state into batch
    row ``slot`` of a running engine state.

    Every decode-state leaf across the model zoo is (L, B, ...) — stacked
    layers first, batch second — so the injection is a uniform
    dynamic-update-slice on axis 1.  Stale KV from the slot's previous
    occupant beyond the new prompt is never readable: the per-slot ``valid``
    mask only exposes [0, pos) and each position is overwritten before pos
    reaches it.
    """
    sub, _, _ = model.prefill(model.cfg, params, batch_one, cache_len)
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=1),
        state, sub)


class ChunkSeg(NamedTuple):
    """One request's contribution to a (possibly packed) prefill chunk:
    prompt positions [start, start + length) of the request resident in
    batch row ``slot``."""
    slot: int
    tokens: np.ndarray               # (S,) the FULL prompt token ids
    start: int
    length: int
    row: Optional[np.ndarray] = None  # paged: the request's physical pages


class ChunkWork(NamedTuple):
    """Host-side descriptor of one fused prefill chunk for the unified
    step: up to ``engine.max_pack`` segments of DIFFERENT requests packed
    back to back (Sarathi-style piggybacking — the tail of one prompt
    rides with the head of the next), block-diagonally isolated on device.
    A single-segment chunk is exactly the unpacked PR-4 chunk."""
    segs: Tuple[ChunkSeg, ...]

    @classmethod
    def single(cls, slot: int, tokens: np.ndarray, start: int, length: int,
               row: Optional[np.ndarray] = None) -> "ChunkWork":
        """One-request chunk (the unpacked composer shape)."""
        return cls(segs=(ChunkSeg(slot, tokens, start, length, row),))

    @property
    def total_tokens(self) -> int:
        return sum(s.length for s in self.segs)


def chunk_supported(model: Model, inputs: Dict[str, jnp.ndarray]) -> bool:
    """A prompt can be prefilled in chunks iff the family exposes
    ``prefill_chunk`` and the prompt is pure text with no hidden prefix —
    vlm patches, learned meta tokens and audio frontends prefill their
    non-token prefix in one shot, so those requests keep the admission-time
    ``model.prefill`` path."""
    mcfg = model.cfg
    return (model.prefill_chunk is not None
            and set(inputs) == {"tokens"}
            and mcfg.arch_type != "audio"
            and not (getattr(mcfg, "n_meta_tokens", 0) or 0))


@functools.lru_cache(maxsize=None)
def _chunk_prefill_fn(prefill_chunk, mcfg):
    """One jitted chunk executable per (family, config) — repeated
    ``serve()``/``extract_trajectories`` calls must not recompile, the same
    contract as the engines' step functions."""
    return jax.jit(functools.partial(prefill_chunk, mcfg),
                   donate_argnums=2)     # the state is rebuilt in place


def chunked_prefill(model: Model, params, batch: Dict[str, jnp.ndarray],
                    cache_len: int, *, chunk_tokens: Optional[int] = None):
    """Build a decode state for ``batch`` — the ONE prompt-prefill helper
    behind ``ServingEngine.serve``, ``extract_trajectories`` and the
    offline shims.

    ``chunk_tokens=None`` (or unsupported inputs) runs one full-prompt
    ``model.prefill`` — the legacy path, bit-identical to before.
    Otherwise the prompt runs through fixed-shape ``chunk_tokens``-wide
    ``model.prefill_chunk`` calls with traced start/length, so ONE compiled
    executable covers every prompt length (the unbounded per-length compile
    cache was §ISSUE-4's satellite fix).  Returns the decode state."""
    mcfg = model.cfg
    if not chunk_tokens or not chunk_supported(model, batch):
        state, _, _ = model.prefill(mcfg, params, batch, cache_len)
        return state
    tokens = np.asarray(batch["tokens"])
    b, s = tokens.shape
    c = int(chunk_tokens)
    state = model.init_decode_state(b, cache_len)
    rows = jnp.arange(b, dtype=jnp.int32)
    fn = _chunk_prefill_fn(model.prefill_chunk, mcfg)
    for start in range(0, s, c):
        n = min(c, s - start)
        buf = np.zeros((b, c), np.int32)
        buf[:, :n] = tokens[:, start:start + n]
        state = fn(params, jnp.asarray(buf), state, rows,
                   jnp.asarray(start, jnp.int32), jnp.asarray(n, jnp.int32))
    return state


def probe_update(pc: ProbeConfig, theta, st: ProbeState, hidden: jnp.ndarray,
                 lam: float, tokens_per_step: int, burn_in: int, *,
                 probe_impl: str = "kernel",
                 interpret: Optional[bool] = None) -> ProbeState:
    """Accumulate one token's hidden state; at step boundaries run the fused
    score-then-update + smoothing + threshold step.

    The probe math itself lives in ONE place — the Pallas kernel module
    (``repro.kernels.ttt_probe.serving_probe_step``) — so the served
    procedure is the same code the calibration path exercises.
    ``probe_impl="ref"`` swaps in the pre-refactor jnp oracle
    (``repro.kernels.ref.serving_probe_step_ref``) for parity tests and the
    before/after throughput benchmark.
    """
    hid_sum = st.hid_sum + hidden.astype(jnp.float32)
    tok_count = st.tok_count + 1
    boundary = (tok_count >= tokens_per_step) & ~st.stopped
    eta = P.inner_lr(pc, theta)
    lam = jnp.asarray(lam, jnp.float32)

    def _features():
        # step-embedding pooling: running mean of the step's hidden states
        phi = hid_sum / jnp.maximum(tok_count, 1)[:, None]
        return P.features(pc, theta, phi)

    if probe_impl == "kernel":
        interp = K.default_interpret() if interpret is None else interpret

        def _probe(_):
            zq, zk = _features()
            return serving_probe_step(zq, zk, boundary, st.W, st.b, st.ring,
                                      st.n_scores, st.stopped,
                                      st.stop_step, eta, lam,
                                      burn_in=int(burn_in), interpret=interp)

        def _skip(_):
            return KernelOut(jnp.zeros_like(st.b), st.W, st.b, st.ring,
                             st.n_scores, st.smoothed, st.stopped,
                             st.stop_step)

        # mid-step tokens (and fully-frozen batches) provably don't change
        # probe state — only pooling runs, the kernel dispatch is skipped
        out = jax.lax.cond(jnp.any(boundary), _probe, _skip, None)
    elif probe_impl == "ref":
        # the PR-1 path, faithfully: full probe math on every token
        zq, zk = _features()
        out = KR.serving_probe_step_ref(zq, zk, boundary, st.W, st.b, st.ring,
                                        st.n_scores, st.stopped,
                                        st.stop_step, eta, lam,
                                        burn_in=int(burn_in))
    else:
        raise ValueError(f"unknown probe_impl {probe_impl!r} "
                         "(expected 'kernel' or 'ref')")
    # reset accumulators at boundaries
    hid_sum = jnp.where(boundary[:, None], 0.0, hid_sum)
    tok_count = jnp.where(boundary, 0, tok_count)
    return ProbeState(out.W, out.b, hid_sum, tok_count, out.ring,
                      out.n_scores, out.smoothed, out.stopped, out.stop_step)


def probe_update_spec(pc: ProbeConfig, theta, st: ProbeState,
                      hidden_seq: jnp.ndarray, accept: jnp.ndarray,
                      lam: float, tokens_per_step: int, burn_in: int, *,
                      probe_impl: str = "kernel",
                      interpret: Optional[bool] = None
                      ) -> Tuple[ProbeState, jnp.ndarray, jnp.ndarray]:
    """Multi-token probe advance for speculative decode: consume the T
    verify positions' hidden states of every slot, but let only the first
    ``accept[i]`` tokens of slot i touch probe state — the chain is
    bit-identical to ``accept[i]`` sequential ``probe_update`` calls.

    The per-token pooling (hid_sum / tok_count accumulate-and-reset) is
    unrolled here at trace time — T is the static ``spec_tokens`` knob —
    producing the (B, T) feature/boundary sequences; the stateful
    score-then-update / smoothing / threshold chain then runs in ONE fused
    masked kernel (``serving_probe_spec_step``), dispatched under the same
    any-boundary ``lax.cond`` gate as the one-token path.  Tokens past an
    IN-CHAIN stop are suppressed inside the kernel by its carried stopped
    flag (the scheduler truncates collection at the stop and releases the
    slot, so the pooled accumulators' post-stop drift is unobservable).

    Returns (ProbeState, smoothed_seq (B, T), n_seq (B, T)) — per-token
    smoothed score and cumulative score count for multi-score collection:
    token t of slot i emitted a score iff n_seq[i, t] exceeds the count
    before it.
    """
    t_total = hidden_seq.shape[1]
    eta = P.inner_lr(pc, theta)
    lam_ = jnp.asarray(lam, jnp.float32)
    accept = jnp.asarray(accept, jnp.int32)
    hid_sum, tok_count = st.hid_sum, st.tok_count
    zqs, zks, bnds = [], [], []
    for t in range(t_total):
        m = t < accept
        hid_sum = jnp.where(m[:, None],
                            hid_sum + hidden_seq[:, t].astype(jnp.float32),
                            hid_sum)
        tok_count = jnp.where(m, tok_count + 1, tok_count)
        bnd = m & (tok_count >= tokens_per_step)
        phi = hid_sum / jnp.maximum(tok_count, 1)[:, None]
        zq, zk = P.features(pc, theta, phi)
        zqs.append(zq)
        zks.append(zk)
        bnds.append(bnd)
        hid_sum = jnp.where(bnd[:, None], 0.0, hid_sum)
        tok_count = jnp.where(bnd, 0, tok_count)
    zq = jnp.stack(zqs, axis=1)
    zk = jnp.stack(zks, axis=1)
    boundary = jnp.stack(bnds, axis=1)
    if probe_impl == "kernel":
        interp = K.default_interpret() if interpret is None else interpret

        def _probe(_):
            return K.serving_probe_spec_step(
                zq, zk, boundary, accept, st.W, st.b, st.ring, st.n_scores,
                st.stopped, st.stop_step, eta, lam_, burn_in=int(burn_in),
                interpret=interp)

        def _skip(_):
            rep = lambda a: jnp.repeat(a[:, None], t_total, axis=1)
            return SpecProbeOut(s=rep(jnp.zeros_like(st.b)),
                                smoothed_seq=rep(st.smoothed),
                                n_seq=rep(st.n_scores), W=st.W, b=st.b,
                                ring=st.ring, n_scores=st.n_scores,
                                smoothed=st.smoothed, stopped=st.stopped,
                                stop_step=st.stop_step)

        out = jax.lax.cond(jnp.any(boundary), _probe, _skip, None)
    elif probe_impl == "ref":
        out = KR.serving_probe_spec_step_ref(
            zq, zk, boundary, accept, st.W, st.b, st.ring, st.n_scores,
            st.stopped, st.stop_step, eta, lam_, burn_in=int(burn_in))
    else:
        raise ValueError(f"unknown probe_impl {probe_impl!r} "
                         "(expected 'kernel' or 'ref')")
    new_st = ProbeState(out.W, out.b, hid_sum, tok_count, out.ring,
                        out.n_scores, out.smoothed, out.stopped,
                        out.stop_step)
    return new_st, out.smoothed_seq, out.n_seq


# The unified ServeConfig (repro.serving.config) replaced the step-level
# dataclass that lived here through PR 7; re-exported so every existing
# ``from repro.serving.engine import ServeConfig`` keeps working.  The
# engines below read only the fused-step fields (tokens_per_step,
# max_new_tokens, lam, burn_in, greedy).


def make_serve_step(model: Model, pc: ProbeConfig, cfg: ServeConfig,
                    window: Optional[int] = None, *,
                    probe_impl: str = "kernel",
                    interpret: Optional[bool] = None,
                    chunk_tokens: int = 0,
                    mask_stopped_writes: bool = False,
                    spec_tokens: int = 0,
                    spec_tree: Optional[Tuple[int, int]] = None):
    """Build the fused decode+ORCA step:
    (params, theta, token, cache, pos, probe_state) ->
    (next_token, cache, probe_state).

    One jitted step fuses decode attention, step-embedding pooling, the
    Pallas probe score-then-update, smoothing and the threshold test for all
    slots; engines jit it with the KV cache and probe state donated so XLA
    updates them in place.

    With ``chunk_tokens > 0`` the step becomes the UNIFIED token-budget
    step (Sarathi-style chunked prefill): it takes a 7th argument ``chunk``
    — a fixed-shape descriptor of up to ``chunk_tokens`` pending prompt
    tokens belonging to up to ``max_pack`` mid-prefill requests (a PACKED
    chunk: the tail of one prompt piggybacked with the head of the next,
    block-diagonally isolated) — and runs ``model.prefill_packed`` for
    them before the decode of every slot, all in one executable whatever
    the prompt lengths or packing.  A single-segment chunk is the unpacked
    PR-4 path; segment count, lengths and positions are all traced data,
    so packed and unpacked serving share ONE executable.  Mid-prefill
    slots ride the decode as parked no-op rows (probe ``stopped=True`` —
    the boundary gate already keeps the probe kernel off them) and, with
    ``mask_stopped_writes``, their dense no-op K/V write is dropped so it
    can never clobber chunk-written prompt K/V (paged parked rows already
    write the NULL page).

    With ``spec_tokens = k > 0`` the decode half becomes DRAFT-VERIFY
    speculative decode riding the same packed-chunk machinery: the step
    takes a trailing ``spec`` descriptor — ``{"lens": (n_slots,)}``, each
    RUNNING slot's verify-block length in [0, k], drawn by the scheduler
    from the same token budget the prefill share uses — drafts k-1
    continuations per slot via ``model.draft``, runs one packed verify
    chunk (``model.verify_packed`` — ``prefill_packed`` with the LM head
    kept) whose segment r is slot r's [current token, drafts...] at
    positions pos..pos+len-1, computes each slot's accepted prefix, and
    advances that slot by ``gen`` in [1, len] committed tokens per step
    (0 for parked rows).  Rejected K/V writes need no undo: validity masks
    expose only [0, pos) and the next verify block overwrites them before
    ``pos`` reaches them.  The probe consumes ONLY accepted tokens through
    ``probe_update_spec``.  ``lens`` is traced data, so every draft-length
    mix shares the ONE executable; the step returns a 4th element —
    {"gen", "seq", "seq_scores", "seq_n"} for multi-token collection.
    The ``spec`` descriptor additionally carries host drafts —
    ``drafts`` (n_slots, k-1) plus a per-slot ``have`` mask — supplied by
    the scheduler's shared draft cache; slots with ``have=False`` fall
    back to ``model.draft`` (all-False is bit-identical to PR 9).

    With ``spec_tree = (W, D)`` the verify segment generalizes from a
    chain to a token TREE: W independent draft chains of depth D hang off
    the root (BFS comb layout — node ``1 + j*W + b`` is branch b at depth
    j+1, parent ``i - W`` or the root), so ``k = 1 + W*D`` nodes per slot
    claim the same token budget.  The packed forward swaps block-causal
    for the per-token ANCESTOR mask (``model.verify_tree``), K/V writes
    are DEFERRED (same-depth siblings share a position), acceptance picks
    the longest root-to-leaf path whose every node matches the model's
    output after its parent — a linear chain by construction, so the SAME
    masked probe kernel consumes it and stops stay byte-identical to
    one-token decode — and only that path's K/V lands via
    ``model.commit_kv``.  ``drafts`` becomes (n_slots, W, D); per-slot
    ``lens`` in [0, k] count-truncates the tree breadth-first (prefix-
    closed: a truncated tree is still a tree).  ``spec_tree`` overrides
    ``spec_tokens``; W = 1 reproduces the linear path bit-for-bit."""
    mcfg = model.cfg

    def decode_probe(params, theta, token, cache, pos, st: ProbeState):
        write_mask = ~st.stopped if mask_stopped_writes else None
        logits, hidden, cache = model.decode_step(mcfg, params, token, cache,
                                                  pos, window=window,
                                                  write_mask=write_mask)
        prev_stopped = st.stopped
        st = probe_update(pc, theta, st, hidden, cfg.lam,
                          cfg.tokens_per_step, cfg.burn_in,
                          probe_impl=probe_impl, interpret=interpret)
        nxt = jnp.argmax(logits[:, :mcfg.vocab_size], axis=-1).astype(jnp.int32)
        # the step on which the stop FIRES still emits its genuinely decoded
        # token; only already-frozen sequences repeat (no-op compute slot)
        nxt = jnp.where(prev_stopped, token, nxt)
        return nxt, cache, st

    if spec_tree is not None:
        tw, td = int(spec_tree[0]), int(spec_tree[1])
        assert tw >= 1 and td >= 1, spec_tree
        assert model.supports_tree, \
            f"{mcfg.name}: no tree speculative decode for this family"
        assert window is None, "speculative decode has no SWA ring buffer"
        kk = 1 + tw * td
        # static BFS comb tables: node 0 = root; node 1 + j*W + b = branch
        # b at depth j+1, parent one level up on the SAME branch (the root
        # for j = 0).  Index order == BFS order, so per-slot count
        # truncation by ``lens`` keeps parents (prefix-closed).
        par_np = np.zeros((kk,), np.int32)
        dep_np = np.zeros((kk,), np.int32)
        for j in range(td):
            for b_ in range(tw):
                i = 1 + j * tw + b_
                dep_np[i] = j + 1
                par_np[i] = 0 if j == 0 else i - tw
        par_l = jnp.asarray(par_np)
        dep_l = jnp.asarray(dep_np)

        def tree_verify(params, theta, token, cache, pos, st: ProbeState,
                        lens, drafts_in, have):
            bsz = token.shape[0]
            c = bsz * kk
            lens = jnp.where(st.stopped, 0, jnp.asarray(lens, jnp.int32))
            pos = jnp.asarray(pos, jnp.int32)
            # drafts: shared-cache hits from the host where available, the
            # model family's own tree drafter elsewhere — traced data, one
            # executable across every hit/miss mix
            dev = model.draft_tree(mcfg, params, cache, token, pos, tw, td)
            drafts = jnp.where(jnp.asarray(have, bool)[:, None, None],
                               jnp.asarray(drafts_in, jnp.int32),
                               jnp.asarray(dev, jnp.int32))
            # BFS layout: blk[:, 1 + j*W + b] = drafts[:, b, j]
            blk = jnp.concatenate(
                [token[:, None],
                 drafts.transpose(0, 2, 1).reshape(bsz, tw * td)], axis=1)
            offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                    jnp.cumsum(lens)[:-1]])
            jj = jnp.arange(kk, dtype=jnp.int32)[None, :]
            dst = jnp.where(jj < lens[:, None], offs[:, None] + jj, c)
            flat = dst.reshape(-1)

            def scat(src):
                return jnp.zeros((c,), jnp.int32).at[flat].set(
                    src.reshape(-1), mode="drop")

            toks_c = scat(blk)
            seg_c = scat(jnp.broadcast_to(
                jnp.arange(bsz, dtype=jnp.int32)[:, None], (bsz, kk)))
            dep_c = scat(jnp.broadcast_to(dep_l[None, :], (bsz, kk)))
            # global parent pointers: the root points at ITSELF (par 0 ->
            # offs + 0); dropped tail tokens default to 0, masked by length
            anc_c = scat(offs[:, None] + par_l[None, :])
            rows_arg = cache["block_tables"] if "block_tables" in cache \
                else None
            logits, hidden, ks, vs = model.verify_tree(
                mcfg, params, toks_c, cache, seg_c,
                jnp.arange(bsz, dtype=jnp.int32), pos, lens, dep_c, anc_c,
                rows_arg)
            out_c = jnp.argmax(logits[:, :mcfg.vocab_size],
                               axis=-1).astype(jnp.int32)
            gdx = jnp.clip(dst, 0, c - 1)
            out_blk = out_c[gdx]                          # (B, kk)
            # per-node acceptance, rooted: node i survives iff its parent
            # did AND it equals the model's output after its parent —
            # unrolled over the static kk at trace time
            accs = [lens > 0]
            for i in range(1, kk):
                p = int(par_np[i])
                accs.append(accs[p] & (i < lens)
                            & (blk[:, i] == out_blk[:, p]))
            acc_m = jnp.stack(accs, axis=1)               # (B, kk) bool
            plen = jnp.where(acc_m, dep_l[None, :] + 1, 0)
            g = jnp.max(plen, axis=1)                     # path len incl root
            best = jnp.argmax(plen, axis=1).astype(jnp.int32)
            # root-first path via the ancestor walk from ``best``: entry d
            # is best's ancestor at distance dep[best] - d (clamped — the
            # tail repeats ``best``, masked by d < g everywhere below)
            curs = [best]
            for _ in range(td):
                curs.append(par_l[curs[-1]])
            curs = jnp.stack(curs, axis=1)                # (B, D+1)
            dd = jnp.arange(td + 1, dtype=jnp.int32)
            walk = jnp.clip(dep_l[best][:, None] - dd[None, :], 0, td)
            path = jnp.take_along_axis(curs, walk, axis=1)
            pdx = jnp.clip(offs[:, None] + path, 0, c - 1)
            seq = out_c[pdx]                              # (B, D+1)
            hid_path = hidden[pdx]                        # (B, D+1, d)
            # the accepted path IS a linear chain: the PR-9 masked spec
            # probe consumes it unchanged, so stops are byte-identical to
            # sequential one-token decode
            st, sm_seq, n_seq = probe_update_spec(
                pc, theta, st, hid_path, g, cfg.lam, cfg.tokens_per_step,
                cfg.burn_in, probe_impl=probe_impl, interpret=interpret)
            # commit ONLY the accepted path's deferred K/V — one node per
            # depth, unique (lane, position) targets, race-free scatter
            on_path = jnp.any(
                (path[:, :, None] == jnp.arange(kk)[None, None, :])
                & (dd[None, :, None] < g[:, None, None]), axis=1)
            valid_c = jnp.zeros((c,), bool).at[flat].set(
                on_path.reshape(-1), mode="drop")
            pos_c = scat(pos[:, None] + dep_l[None, :])
            cache = model.commit_kv(mcfg, cache, ks, vs,
                                    jnp.arange(bsz, dtype=jnp.int32),
                                    seg_c, pos_c, valid_c, rows_arg)
            nxt = jnp.where(
                g > 0,
                jnp.take_along_axis(
                    seq, jnp.clip(g - 1, 0, td)[:, None], axis=1)[:, 0],
                token)
            extras = {"gen": g, "seq": seq, "seq_scores": sm_seq,
                      "seq_n": n_seq}
            return nxt, cache, st, extras

        if not chunk_tokens:
            def tree_step(params, theta, token, cache, pos, st: ProbeState,
                          spec: Dict[str, jnp.ndarray]):
                return tree_verify(params, theta, token, cache, pos, st,
                                   spec["lens"], spec["drafts"],
                                   spec["have"])
            return tree_step

        def unified_tree_step(params, theta, token, cache, pos,
                              st: ProbeState, chunk: Dict[str, jnp.ndarray],
                              spec: Dict[str, jnp.ndarray]):
            def run_chunk(cache):
                return model.prefill_packed(mcfg, params, chunk["tokens"],
                                            cache, chunk["seg"],
                                            chunk["slots"], chunk["starts"],
                                            chunk["lengths"],
                                            chunk.get("rows"))

            cache = jax.lax.cond(chunk["active"], run_chunk,
                                 lambda cch: cch, cache)
            return tree_verify(params, theta, token, cache, pos, st,
                               spec["lens"], spec["drafts"], spec["have"])

        return unified_tree_step

    if spec_tokens:
        assert spec_tokens >= 2, "spec_tokens < 2 is one-token decode"
        assert model.supports_spec, \
            f"{mcfg.name}: no speculative decode for this family"
        assert window is None, "speculative decode has no SWA ring buffer"
        kk = int(spec_tokens)

        def spec_verify(params, theta, token, cache, pos,
                        st: ProbeState, lens, drafts_in, have):
            bsz = token.shape[0]
            c = bsz * kk
            # parked rows contribute nothing: no writes (the one-token
            # path's mask_stopped_writes contract), no probe, no advance
            lens = jnp.where(st.stopped, 0, jnp.asarray(lens, jnp.int32))
            pos = jnp.asarray(pos, jnp.int32)
            # host drafts (shared draft cache) where ``have``, the model
            # family's own drafter elsewhere — all-False is bit-identical
            # to the pre-cache path
            dev = model.draft(mcfg, params, cache, token, pos, kk)
            drafts = jnp.where(jnp.asarray(have, bool)[:, None],
                               jnp.asarray(drafts_in, jnp.int32),
                               jnp.asarray(dev, jnp.int32))
            blk = jnp.concatenate([token[:, None], drafts], axis=1)
            # segments laid out contiguously in slot order (the packed-chunk
            # layout contract); slots past their length scatter to the
            # dropped tail and tail tokens keep seg 0, invalid by length
            offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                    jnp.cumsum(lens)[:-1]])
            jj = jnp.arange(kk, dtype=jnp.int32)[None, :]
            dst = jnp.where(jj < lens[:, None], offs[:, None] + jj, c)
            flat = dst.reshape(-1)
            toks_c = jnp.zeros((c,), jnp.int32).at[flat].set(
                blk.reshape(-1), mode="drop")
            seg_src = jnp.broadcast_to(
                jnp.arange(bsz, dtype=jnp.int32)[:, None], (bsz, kk))
            seg_c = jnp.zeros((c,), jnp.int32).at[flat].set(
                seg_src.reshape(-1), mode="drop")
            rows_arg = cache["block_tables"] if "block_tables" in cache \
                else None
            logits, hidden, cache = model.verify_packed(
                mcfg, params, toks_c, cache, seg_c,
                jnp.arange(bsz, dtype=jnp.int32), pos, lens, rows_arg)
            out_c = jnp.argmax(logits[:, :mcfg.vocab_size],
                               axis=-1).astype(jnp.int32)
            gdx = jnp.clip(dst, 0, c - 1)
            out_blk = out_c[gdx]                          # (B, kk)
            hid_blk = hidden[gdx]                         # (B, kk, d)
            # accepted prefix: draft j+1 survives iff it equals the model's
            # output after consuming draft j; the first miss is replaced by
            # the model's own token, so gen = accepted drafts + 1
            ok = (blk[:, 1:] == out_blk[:, :-1]) \
                & (jj[:, :kk - 1] + 1 < lens[:, None])
            n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
            g = jnp.where(lens > 0, n_acc + 1, 0)
            st, sm_seq, n_seq = probe_update_spec(
                pc, theta, st, hid_blk, g, cfg.lam, cfg.tokens_per_step,
                cfg.burn_in, probe_impl=probe_impl, interpret=interpret)
            nxt = jnp.where(
                g > 0,
                jnp.take_along_axis(
                    out_blk, jnp.clip(g - 1, 0, kk - 1)[:, None],
                    axis=1)[:, 0],
                token)
            extras = {"gen": g, "seq": out_blk, "seq_scores": sm_seq,
                      "seq_n": n_seq}
            return nxt, cache, st, extras

        if not chunk_tokens:
            def spec_step(params, theta, token, cache, pos, st: ProbeState,
                          spec: Dict[str, jnp.ndarray]):
                return spec_verify(params, theta, token, cache, pos, st,
                                   spec["lens"], spec["drafts"],
                                   spec["have"])
            return spec_step

        def unified_spec_step(params, theta, token, cache, pos,
                              st: ProbeState, chunk: Dict[str, jnp.ndarray],
                              spec: Dict[str, jnp.ndarray]):
            def run_chunk(cache):
                return model.prefill_packed(mcfg, params, chunk["tokens"],
                                            cache, chunk["seg"],
                                            chunk["slots"], chunk["starts"],
                                            chunk["lengths"],
                                            chunk.get("rows"))

            cache = jax.lax.cond(chunk["active"], run_chunk,
                                 lambda cch: cch, cache)
            return spec_verify(params, theta, token, cache, pos, st,
                               spec["lens"], spec["drafts"], spec["have"])

        return unified_spec_step

    if not chunk_tokens:
        def serve_step(params, theta, token, cache, pos, st: ProbeState):
            return decode_probe(params, theta, token, cache, pos, st)
        return serve_step

    assert model.prefill_packed is not None, \
        f"{mcfg.name}: no packed chunked prefill for this family"

    def unified_step(params, theta, token, cache, pos, st: ProbeState,
                     chunk: Dict[str, jnp.ndarray]):
        def run_chunk(cache):
            return model.prefill_packed(mcfg, params, chunk["tokens"], cache,
                                        chunk["seg"], chunk["slots"],
                                        chunk["starts"], chunk["lengths"],
                                        chunk.get("rows"))

        # prefill work first, decode after: order is immaterial (the chunk
        # slot is parked, other slots never read its lane) but keeps the
        # trace linear
        cache = jax.lax.cond(chunk["active"], run_chunk, lambda c: c, cache)
        return decode_probe(params, theta, token, cache, pos, st)

    return unified_step


# serve_step arg indices donated by the engines' jitted hot loop: the KV
# cache (3) and the probe state (5) are consumed and re-emitted every step
_SERVE_STEP_DONATE = (3, 5)


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray        # (B, n_decode_iters) tokens actually decoded
    stop_step: np.ndarray     # (B,) reasoning step at stop (-1 = budget)
    steps_run: np.ndarray     # (B,) reasoning steps actually executed
    savings: float
    scores: np.ndarray        # (B, n_steps) smoothed score at each step


class ServingEngine:
    """Minimal batched server: prefill once, loop the fused serve_step.

    DEPRECATED as a serving path: stopped sequences keep occupying their
    batch slot as no-op compute until the slowest sequence finishes.  Use
    ``repro.serving.OrcaScheduler`` (continuous batching with ORCA-stop
    eviction) for throughput; this class remains as the static-batch
    baseline it is benchmarked against (``benchmarks/serving_throughput``)
    and for callers that bring a pre-built batch."""

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig, *, probe_impl: str = "kernel",
                 interpret: Optional[bool] = None,
                 chunk_tokens: Optional[int] = None):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg
        # prompt prefill routes through the shared chunked helper: None ->
        # one full model.prefill call (legacy, bit-identical); an int ->
        # fixed-shape chunks, one executable across prompt lengths
        self.chunk_tokens = chunk_tokens
        # one jitted step for the engine's lifetime: repeated serve() calls
        # (e.g. group loops in the throughput benchmark) must not recompile
        self._step_fn = jax.jit(
            make_serve_step(model, pc, cfg, probe_impl=probe_impl,
                            interpret=interpret),
            donate_argnums=_SERVE_STEP_DONATE)

    def serve(self, batch: Dict[str, jnp.ndarray], prompt_len: int,
              cache_len: Optional[int] = None) -> ServeResult:
        warnings.warn(
            "ServingEngine.serve is deprecated as a serving path (stopped "
            "sequences occupy their slot as no-op compute until the slowest "
            "finishes); serve through repro.serving.OrcaScheduler / "
            "repro.api.engine for continuous batching — this class remains "
            "only as the static-batch baseline",
            DeprecationWarning, stacklevel=2)
        model, cfg = self.model, self.cfg
        mcfg = model.cfg
        B = next(iter(batch.values())).shape[0]
        pre = prefix_len(mcfg, batch, prompt_len)
        cache_len = cache_len or (pre + cfg.max_new_tokens)
        state = chunked_prefill(model, self.params, batch, cache_len,
                                chunk_tokens=self.chunk_tokens)
        step_fn = self._step_fn
        st = init_probe_state(self.pc, self.theta, B, mcfg.d_model)
        token = jnp.zeros((B,), jnp.int32)
        toks, scores, phis = [], [], []
        pos0 = pre if mcfg.arch_type != "audio" else 0
        # host-side watermark (st's buffers are donated to the next step)
        last_max_n = 0
        for i in range(cfg.max_new_tokens):
            pos = jnp.asarray(pos0 + i, jnp.int32)
            token, state, st = step_fn(self.params, self.theta, token, state,
                                       pos, st)
            toks.append(np.asarray(token))
            max_n = int(np.asarray(jnp.max(st.n_scores)))
            if max_n > last_max_n:
                scores.append(np.asarray(st.smoothed))
                last_max_n = max_n
            if bool(np.asarray(jnp.all(st.stopped))):
                break
        stop_step = np.asarray(st.stop_step)
        steps_run = np.where(stop_step >= 0, stop_step,
                             np.asarray(st.n_scores))
        total = max(cfg.max_new_tokens // cfg.tokens_per_step, 1)
        savings = float(np.mean(S.step_savings(steps_run, total)))
        return ServeResult(
            tokens=np.stack(toks, axis=1) if toks else np.zeros((B, 0), np.int32),
            stop_step=stop_step, steps_run=steps_run, savings=savings,
            scores=np.stack(scores, axis=1) if scores else np.zeros((B, 0)))


@dataclasses.dataclass
class StaticQueueResult:
    """Aggregate of serving a request queue in fixed static-batch groups."""
    stop_step: np.ndarray        # (N,) per request
    steps_run: np.ndarray        # (N,)
    scores: List[np.ndarray]     # per request, (n_steps,)
    engine_steps: int            # total fused decode steps across groups
    active_slot_steps: int       # slot-steps before each sequence stopped
    total_slot_steps: int        # engine_steps x group width
    wall_time_s: float


def serve_queue_static(engine: ServingEngine, batch: Dict[str, jnp.ndarray],
                       prompt_len: int, n_slots: int) -> StaticQueueResult:
    """Serve a queue in fixed groups of ``n_slots`` through the deprecated
    static-batch path (no eviction: each group runs until its slowest
    member finishes).  The baseline both ``launch/serve.py`` and
    ``benchmarks/serving_throughput.py`` compare the scheduler against."""
    import time
    n = next(iter(batch.values())).shape[0]
    stop_steps, steps_run, scores = [], [], []
    engine_steps = active = total = 0
    t0 = time.perf_counter()
    for lo in range(0, n, n_slots):
        group = {k: v[lo:lo + n_slots] for k, v in batch.items()}
        with warnings.catch_warnings():
            # this helper IS the sanctioned baseline use of the deprecated
            # path — don't spam its own deprecation per group
            warnings.simplefilter("ignore", DeprecationWarning)
            res = engine.serve(group, prompt_len=prompt_len)
        iters = res.tokens.shape[1]
        b = group["tokens"].shape[0] if "tokens" in group else \
            next(iter(group.values())).shape[0]
        engine_steps += iters
        total += iters * b
        # a slot is useful until its sequence stops; frozen after
        active += int(np.minimum(
            res.steps_run * engine.cfg.tokens_per_step, iters).sum())
        stop_steps.extend(res.stop_step.tolist())
        steps_run.extend(res.steps_run.tolist())
        scores.extend(res.scores[i] for i in range(res.scores.shape[0]))
    return StaticQueueResult(
        stop_step=np.array(stop_steps), steps_run=np.array(steps_run),
        scores=scores, engine_steps=engine_steps, active_slot_steps=active,
        total_slot_steps=total, wall_time_s=time.perf_counter() - t0)


def extract_trajectories(model: Model, params, batch, prompt_len: int,
                         max_new_tokens: int, tokens_per_step: int,
                         cache_len: Optional[int] = None,
                         chunk_tokens: Optional[int] = None):
    """Run the model WITHOUT stopping and harvest step embeddings phi_t —
    the trajectory source for meta-training probes on a real model.
    Prompt prefill routes through the shared ``chunked_prefill`` helper
    (``chunk_tokens=None`` keeps the legacy one-shot prefill)."""
    mcfg = model.cfg
    B = next(iter(batch.values())).shape[0]
    pre = prefix_len(mcfg, batch, prompt_len)
    cache_len = cache_len or (pre + max_new_tokens)
    state = chunked_prefill(model, params, batch, cache_len,
                            chunk_tokens=chunk_tokens)
    token = jnp.zeros((B,), jnp.int32)
    step_fn = jax.jit(functools.partial(model.decode_step, mcfg))
    pos0 = pre if mcfg.arch_type != "audio" else 0
    phis, acc, cnt = [], jnp.zeros((B, mcfg.d_model), jnp.float32), 0
    tokens = []
    for i in range(max_new_tokens):
        pos = jnp.asarray(pos0 + i, jnp.int32)
        logits, hidden, state = step_fn(params, token, state, pos)
        token = jnp.argmax(logits[:, :mcfg.vocab_size], -1).astype(jnp.int32)
        tokens.append(np.asarray(token))
        acc = acc + hidden.astype(jnp.float32)
        cnt += 1
        if cnt == tokens_per_step:
            phis.append(np.asarray(acc / cnt))
            acc, cnt = jnp.zeros_like(acc), 0
    return (np.stack(phis, axis=1) if phis else np.zeros((B, 0, mcfg.d_model)),
            np.stack(tokens, axis=1))


# ---------------------------------------------------------------------------
# Continuous batching: slot-level engine


class SlotStepView(NamedTuple):
    """Host-visible per-slot observation after one fused engine step.

    The four trailing fields are ONLY populated by speculative steps
    (``spec_tokens > 0``); one-token steps leave them None, keeping the
    non-spec view byte-identical to before."""
    tokens: np.ndarray      # (n_slots,) token decoded this step
    stopped: np.ndarray     # (n_slots,) bool — ORCA threshold crossed
    stop_step: np.ndarray   # (n_slots,) reasoning step at stop (-1 active)
    n_scores: np.ndarray    # (n_slots,) scores emitted since admission
    smoothed: np.ndarray    # (n_slots,) current smoothed score
    gen: Optional[np.ndarray] = None         # (n_slots,) tokens committed
    seq: Optional[np.ndarray] = None         # (n_slots, k) committed tokens
    seq_scores: Optional[np.ndarray] = None  # (n_slots, k) smoothed / token
    seq_n: Optional[np.ndarray] = None       # (n_slots, k) n_scores / token


def prefix_len(mcfg, batch_one: Dict[str, jnp.ndarray],
               prompt_len: int) -> int:
    """Sequence length ``model.prefill`` will actually run for one request
    (text prompt + vlm patch prefix + learned meta tokens)."""
    n = prompt_len
    if mcfg.arch_type == "vlm" and "patch_embeds" in batch_one:
        n += mcfg.frontend.n_tokens
    n += getattr(mcfg, "n_meta_tokens", 0) or 0
    return n


@dataclasses.dataclass
class Spill:
    """Everything a preempted request needs to resume byte-identically,
    copied to host RAM (the tiered-offload target: HBM pages -> host).

    The per-request TTT calibrator (W_i, b_i, smoothing ring, counters)
    *is* the request's identity — restoring it exactly, together with the
    KV it conditions on and the position it decodes from, is what makes a
    preempted-then-resumed request stop on the same reasoning step as an
    undisturbed one.
    """
    probe: Tuple[np.ndarray, ...]   # one batch-axis-free row per ProbeState leaf
    token: int                      # last decoded token (decode input)
    pos: int                        # sequence position to resume from
    armed: bool                     # True: was RUNNING; False: mid-prefill
    prompt_len: int = 0             # prefill progress bookkeeping (host side)
    # paged: host copies of the victim's pages, (L, max_blocks, ...) per leaf
    pages: Optional[Dict[str, np.ndarray]] = None
    n_blocks: int = 0               # physical blocks the pages cover
    # dense: host copy of the slot's full decode-state lane (axis-1 slice)
    lane: Optional[object] = None

    @property
    def nbytes(self) -> int:
        """Host RAM this spill occupies (KV payload only)."""
        leaves = (list(self.pages.values()) if self.pages is not None
                  else jax.tree.leaves(self.lane) if self.lane is not None
                  else [])
        return int(sum(np.asarray(x).nbytes for x in leaves))


class ContinuousServingEngine:
    """Fixed-shape batch of ``n_slots`` whose rows live independent lives.

    The jax surgery behind continuous batching, kept deliberately small:

    * ``pos`` is a per-slot vector — every model family's ``decode_step``
      accepts (B,) positions (per-row valid masks + per-row cache scatter).
    * ``admit`` prefills ONE request (batch 1) and dynamic-update-slices its
      decode state into the slot (batch axis 1 in every leaf), then resets
      that slot's probe fast weights to (W0, b0) — the slot's score
      trajectory is exactly a fresh single-request run.
    * ``release`` parks the slot (probe ``stopped=True``): the fused step
      treats it as no-op until the scheduler refills it.

    With ``paged=True`` (model families exposing ``init_paged_state``) the
    KV cache is a pool of fixed-size pages instead of one max-length lane
    per slot: ``admit`` takes the request's physical block row (reserved by
    the scheduler from ``repro.serving.kv_pool.BlockPool``), writes prefill
    K/V page-by-page through it — or, on a prefix hit, skips prefill
    entirely and just copies the donor's partial tail page — and
    ``release`` points the slot's table row at the NULL page so a parked
    slot's no-op write can never corrupt a reallocated page.

    The scheduler (``repro.serving.scheduler.OrcaScheduler``) owns queues,
    request lifecycles, the block pool and metrics; this class owns device
    state only.
    """

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig, n_slots: int, cache_len: int,
                 window: Optional[int] = None, *, probe_impl: str = "kernel",
                 interpret: Optional[bool] = None, paged: bool = False,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 pack_max: int = 4, spec_tokens: Optional[int] = None,
                 spec_tree: Optional[Tuple[int, int]] = None):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg
        mcfg = model.cfg
        self.paged = bool(paged)
        if self.paged:
            assert model.supports_paged, \
                f"{mcfg.name}: no paged cache layout for this family"
            assert window is None, "paged serving has no SWA ring buffer"
            self.block_size = int(block_size)
            self.max_blocks = blocks_needed(cache_len, block_size)
            cache_len = self.max_blocks * self.block_size
            self.num_blocks = int(num_blocks or
                                  (n_slots * self.max_blocks + 1))
            self.state = model.init_paged_state(
                n_slots, self.num_blocks, self.block_size, self.max_blocks)
        else:
            self.state = model.init_decode_state(n_slots, cache_len)
        self.n_slots, self.cache_len = n_slots, cache_len
        # chunked prefill: the fused step becomes the unified token-budget
        # step (decode every slot + up to chunk_tokens of prompt work,
        # PACKED across up to max_pack mid-prefill requests) — ONE
        # executable regardless of prompt length or packing shape
        self.chunk_tokens = int(chunk_tokens or 0)
        self.max_pack = max(min(int(pack_max), self.chunk_tokens), 1) \
            if self.chunk_tokens else 0
        if self.chunk_tokens:
            assert window is None, "chunked prefill has no SWA ring buffer"
            assert model.supports_chunked, \
                f"{mcfg.name}: no chunked prefill for this family"
        # speculative draft-verify decode: every RUNNING slot may ride the
        # packed verify chunk with up to spec_tokens tokens per step; lens
        # are traced per-step data, so ONE executable covers every mix.
        # spec_tree=(W,D) is the TREE generalization: 1 + W*D candidate
        # NODES per slot claim the budget (``spec_tokens`` becomes that
        # node count — the scheduler's per-slot unit either way)
        self.spec_tree = (tuple(int(x) for x in spec_tree) if spec_tree
                          else None)
        self.spec_tokens = int(spec_tokens or 0)
        if self.spec_tree:
            assert not self.spec_tokens, \
                "spec_tree and spec_tokens are mutually exclusive"
            assert model.supports_tree, \
                f"{mcfg.name}: no tree speculative decode for this family"
            self.spec_tokens = 1 + self.spec_tree[0] * self.spec_tree[1]
        elif self.spec_tokens:
            assert model.supports_spec, \
                f"{mcfg.name}: no speculative decode for this family"
        st = init_probe_state(pc, theta, n_slots, mcfg.d_model)
        self.st = st._replace(stopped=jnp.ones((n_slots,), bool))
        self.token = jnp.zeros((n_slots,), jnp.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self._step_fn = jax.jit(
            make_serve_step(model, pc, cfg, window=window,
                            probe_impl=probe_impl, interpret=interpret,
                            chunk_tokens=self.chunk_tokens,
                            mask_stopped_writes=bool(self.chunk_tokens),
                            spec_tokens=(0 if self.spec_tree
                                         else self.spec_tokens),
                            spec_tree=self.spec_tree),
            donate_argnums=_SERVE_STEP_DONATE)
        if self.spec_tokens:
            if self.spec_tree:
                w_, d_ = self.spec_tree
                zero_drafts = jnp.zeros((n_slots, w_, d_), jnp.int32)
            else:
                zero_drafts = jnp.zeros((n_slots, self.spec_tokens - 1),
                                        jnp.int32)
            self._null_spec = {"lens": jnp.zeros((n_slots,), jnp.int32),
                               "drafts": zero_drafts,
                               "have": jnp.zeros((n_slots,), bool)}
        if self.chunk_tokens:
            r = self.max_pack
            null = {"tokens": jnp.zeros((self.chunk_tokens,), jnp.int32),
                    "seg": jnp.zeros((self.chunk_tokens,), jnp.int32),
                    "slots": jnp.zeros((r,), jnp.int32),
                    "starts": jnp.zeros((r,), jnp.int32),
                    "lengths": jnp.zeros((r,), jnp.int32),
                    "active": jnp.zeros((), bool)}
            if self.paged:
                null["rows"] = jnp.full((r, self.max_blocks), NULL_BLOCK,
                                        jnp.int32)
            self._null_chunk = null
        if self.paged:
            # the page pool is the largest serving buffer: donate it through
            # every admit/release op so XLA updates it in place instead of
            # copying the whole pool per call
            self._set_row = jax.jit(self._set_row_impl, donate_argnums=0)
            self._copy = jax.jit(self._copy_impl, donate_argnums=0)
            self._prefill_pages = jax.jit(self._prefill_pages_impl,
                                          static_argnames=("s_pad",),
                                          donate_argnums=1)
            # preemption: gather copies pages OUT (no donation — the pool
            # keeps serving), scatter writes them back in place
            self._gather_pages = jax.jit(self._gather_pages_impl)
            self._scatter_pages = jax.jit(self._scatter_pages_impl,
                                          donate_argnums=0)
        else:
            self._inject = jax.jit(functools.partial(
                inject_prefill, model, cache_len=cache_len))
            self._take_lane = jax.jit(self._take_lane_impl)
            self._write_lane = jax.jit(self._write_lane_impl,
                                       donate_argnums=0)
        self._reset = jax.jit(functools.partial(reset_probe_slot, pc),
                              static_argnames=("active",))
        self._write_probe = jax.jit(write_probe_slot)

    # ------------------------------------------------------------------
    # paged device ops (jitted in __init__)
    @staticmethod
    def _set_row_impl(state, slot, row):
        return dict(state, block_tables=state["block_tables"].at[slot].set(row))

    @staticmethod
    def _copy_impl(state, src, dst):
        pages = {k: v for k, v in state.items() if k != "block_tables"}
        return dict(A.copy_pages(pages, src, dst),
                    block_tables=state["block_tables"])

    def _prefill_pages_impl(self, params, state, batch_one, row, *,
                            s_pad: int):
        sub, _, _ = self.model.prefill(self.model.cfg, params, batch_one,
                                       s_pad)
        pages = {k: v for k, v in state.items() if k != "block_tables"}
        pages = A.prefill_to_pages(pages, sub, row,
                                   s_pad // self.block_size)
        return dict(pages, block_tables=state["block_tables"])

    @staticmethod
    def _gather_pages_impl(state, row):
        # page axis is 1 in every page leaf; NULL tail rows clamp to page 0
        # (their content is garbage but the scatter drops them — old and
        # new rows share the same n_blocks, hence the same NULL tail)
        src = jnp.where(row == NULL_BLOCK, 0, row)
        return {k: v[:, src] for k, v in state.items()
                if k != "block_tables"}

    @staticmethod
    def _scatter_pages_impl(state, pages, row):
        out = {"block_tables": state["block_tables"]}
        for k, v in state.items():
            if k == "block_tables":
                continue
            # NULL rows are redirected past the pool and dropped — the
            # copy-back can never touch the NULL page or a live page
            dst = jnp.where(row == NULL_BLOCK, v.shape[1], row)
            out[k] = v.at[:, dst].set(pages[k].astype(v.dtype), mode="drop")
        return out

    @staticmethod
    def _take_lane_impl(state, slot):
        return jax.tree.map(lambda x: x[:, slot], state)

    @staticmethod
    def _write_lane_impl(state, lane, slot):
        slot = jnp.asarray(slot, jnp.int32)
        return jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, jnp.expand_dims(one, 1).astype(full.dtype), slot,
                axis=1),
            state, lane)

    # ------------------------------------------------------------------
    def admit(self, slot: int, batch_one: Dict[str, jnp.ndarray],
              prompt_len: int, *, block_row=None, skip_prefill: bool = False,
              copy_tail=None) -> None:
        """Prefill + inject one request into ``slot`` and arm its probe.

        Paged mode additionally takes the request's reserved physical block
        ids (``block_row``); ``skip_prefill`` marks a prefix hit (the shared
        full pages already hold the prompt K/V) and ``copy_tail`` is the
        (src, dst) page pair for the donor's partial tail page, copied
        before this slot starts writing its own decode tokens into it."""
        if self.paged:
            assert block_row is not None, "paged admit needs a block row"
            row = jnp.asarray(pad_row(block_row, self.max_blocks))
            self.state = self._set_row(self.state,
                                       jnp.asarray(slot, jnp.int32), row)
            if copy_tail is not None:
                src, dst = copy_tail
                self.state = self._copy(self.state,
                                        jnp.asarray([src], jnp.int32),
                                        jnp.asarray([dst], jnp.int32))
            if not skip_prefill:
                pre = prefix_len(self.model.cfg, batch_one, prompt_len)
                s_pad = blocks_needed(pre, self.block_size) * self.block_size
                assert s_pad // self.block_size <= len(block_row), \
                    "block row shorter than the prefill prefix"
                self.state = self._prefill_pages(self.params, self.state,
                                                 batch_one, row, s_pad=s_pad)
        else:
            assert block_row is None and copy_tail is None and not skip_prefill
            self.state = self._inject(self.params, self.state, batch_one,
                                      jnp.asarray(slot, jnp.int32))
        self.st = self._reset(self.theta, self.st,
                              jnp.asarray(slot, jnp.int32), active=True)
        self.token = self.token.at[slot].set(0)
        # decode resumes AFTER the whole prefill prefix (vlm patches / meta
        # tokens included) — starting at prompt_len would clobber prefix
        # K/V and leave the prompt's own K/V forever behind the valid mask
        self.pos[slot] = 0 if self.model.cfg.arch_type == "audio" else \
            prefix_len(self.model.cfg, batch_one, prompt_len)

    def release(self, slot: int) -> None:
        """Evict the slot's request: park the probe row as no-op compute.
        Paged: the slot's table row is pointed at the NULL page so its
        parked write can't touch pages the pool hands to someone else."""
        self.st = self._reset(self.theta, self.st,
                              jnp.asarray(slot, jnp.int32), active=False)
        if self.paged:
            null_row = jnp.full((self.max_blocks,), NULL_BLOCK, jnp.int32)
            self.state = self._set_row(self.state,
                                       jnp.asarray(slot, jnp.int32), null_row)
        self.pos[slot] = 0

    def cancel(self, slot: int) -> None:
        """Voluntary mid-flight release (group-consensus sibling
        cancellation).  Distinct from an ORCA stop (no stop decision fired
        for this request) and from FINISHED (budget not exhausted), but the
        device-side mechanics are the release path: park the probe row,
        NULL the table row, zero the position.  Safe MID-PREFILL too: a
        resident PREFILL row already sits parked at the NULL page for the
        whole prefill (``begin_prefill``), so cancelling it simply never
        arms the row — the reserved pages are the scheduler/pool's to
        reclaim."""
        self.release(slot)

    def preempt(self, slot: int, *, block_row=None,
                armed: bool = True, prompt_len: int = 0) -> Spill:
        """INVOLUNTARY eviction: copy the slot's complete request identity
        to host RAM, then release the slot.  Unlike ``cancel`` the request
        is not dead — ``restore`` resumes it byte-identically later.

        Paged mode takes the victim's physical block ids (``block_row`` —
        the SCHEDULER's view, because a mid-prefill victim's device table
        row is still NULL while chunks write through explicit rows) and
        copies those pages out; dense mode copies the slot's whole
        decode-state lane.  ``armed=False`` marks a mid-prefill victim:
        its probe row is parked and its restore re-parks it (the table row
        stays NULL until ``finish_prefill`` arms it)."""
        probe = tuple(np.asarray(leaf[slot]) for leaf in self.st)
        token = int(np.asarray(self.token[slot]))
        pos = int(self.pos[slot])
        pages = lane = None
        n_blocks = 0
        if self.paged:
            assert block_row is not None, "paged preempt needs the block row"
            n_blocks = len(block_row)
            row = jnp.asarray(pad_row(block_row, self.max_blocks))
            pages = {k: np.asarray(v) for k, v in
                     self._gather_pages(self.state, row).items()}
        else:
            assert block_row is None
            lane = jax.tree.map(
                np.asarray,
                self._take_lane(self.state, jnp.asarray(slot, jnp.int32)))
        self.release(slot)
        return Spill(probe=probe, token=token, pos=pos, armed=bool(armed),
                     prompt_len=int(prompt_len), pages=pages,
                     n_blocks=n_blocks, lane=lane)

    def restore(self, slot: int, spill: Spill, *, block_row=None) -> None:
        """Resume a spilled request in ``slot``: page copy-back (or dense
        lane write), block-table rewrite, probe rows reloaded exactly,
        token and position restored.  The new ``block_row`` need not be the
        victim's original blocks — only the table indirection changes, the
        virtual sequence the model sees is identical."""
        if self.paged:
            assert block_row is not None, "paged restore needs a block row"
            assert len(block_row) == spill.n_blocks, \
                (len(block_row), spill.n_blocks)
            row = jnp.asarray(pad_row(block_row, self.max_blocks))
            pages = {k: jnp.asarray(v) for k, v in spill.pages.items()}
            self.state = self._scatter_pages(self.state, pages, row)
            # mid-prefill rows stay parked at NULL — remaining chunks write
            # through the explicit row and finish_prefill arms the table
            table = row if spill.armed else \
                jnp.full((self.max_blocks,), NULL_BLOCK, jnp.int32)
            self.state = self._set_row(self.state,
                                       jnp.asarray(slot, jnp.int32), table)
        else:
            assert block_row is None
            lane = jax.tree.map(jnp.asarray, spill.lane)
            self.state = self._write_lane(self.state, lane,
                                          jnp.asarray(slot, jnp.int32))
        rows = ProbeState(*[jnp.asarray(p) for p in spill.probe])
        self.st = self._write_probe(self.st, jnp.asarray(slot, jnp.int32),
                                    rows)
        self.token = self.token.at[slot].set(spill.token)
        self.pos[slot] = spill.pos

    # ------------------------------------------------------------------
    # chunked prefill: PREFILL is a resident phase, not an admission event
    def begin_prefill(self, slot: int) -> None:
        """Make ``slot`` a resident PREFILL row.  The probe is parked
        (``stopped=True``): the unified step treats the row as no-op decode
        — the probe kernel's boundary gate never touches its state and its
        dense K/V write is dropped by the write mask.  Paged: the slot's
        table row STAYS at NULL for the whole prefill (chunks write through
        their explicit block row), so the parked decode write can't corrupt
        the reserved pages."""
        assert self.chunk_tokens, "engine built without chunk_tokens"
        self.st = self._reset(self.theta, self.st,
                              jnp.asarray(slot, jnp.int32), active=False)
        if self.paged:
            null_row = jnp.full((self.max_blocks,), NULL_BLOCK, jnp.int32)
            self.state = self._set_row(self.state,
                                       jnp.asarray(slot, jnp.int32), null_row)
        self.token = self.token.at[slot].set(0)
        self.pos[slot] = 0

    def finish_prefill(self, slot: int, batch_one: Dict[str, jnp.ndarray],
                       prompt_len: int, *, block_row=None) -> None:
        """Arm ``slot`` after its last prefill chunk: point its table row at
        the now-filled pages (paged), reset the probe to (W0, b0) and resume
        decode at the prompt length — byte-identical slot state to a
        full-prefill ``admit``."""
        assert self.chunk_tokens, "engine built without chunk_tokens"
        if self.paged:
            assert block_row is not None, "paged finish_prefill needs a row"
            self.state = self._set_row(
                self.state, jnp.asarray(slot, jnp.int32),
                jnp.asarray(pad_row(block_row, self.max_blocks)))
        self.st = self._reset(self.theta, self.st,
                              jnp.asarray(slot, jnp.int32), active=True)
        self.token = self.token.at[slot].set(0)
        self.pos[slot] = prefix_len(self.model.cfg, batch_one, prompt_len)

    def _chunk_to_device(self, chunk: ChunkWork) -> Dict[str, jnp.ndarray]:
        """Lower a (possibly packed) ChunkWork to the fixed-shape device
        descriptor: segments laid out back to back in ``tokens``/``seg``,
        per-segment (slot, start, length, pages) arrays padded to
        ``max_pack`` rows with zero-length segments.  Trailing token
        padding keeps the LAST segment's id, which places it past that
        segment's length — invalid by construction, dropped at the
        write."""
        c, r = self.chunk_tokens, self.max_pack
        segs = chunk.segs
        assert 1 <= len(segs) <= r, (len(segs), r)
        toks = np.zeros((c,), np.int32)
        seg = np.full((c,), max(len(segs) - 1, 0), np.int32)
        slots = np.zeros((r,), np.int32)
        starts = np.zeros((r,), np.int32)
        lengths = np.zeros((r,), np.int32)
        rows = (np.full((r, self.max_blocks), NULL_BLOCK, np.int32)
                if self.paged else None)
        off = 0
        for si, s in enumerate(segs):
            assert off + s.length <= c, "packed segments exceed the chunk"
            toks[off:off + s.length] = np.asarray(
                s.tokens[s.start:s.start + s.length])
            seg[off:off + s.length] = si
            slots[si], starts[si], lengths[si] = s.slot, s.start, s.length
            if rows is not None and s.row is not None:
                rows[si, :len(s.row)] = np.asarray(s.row, np.int32)
            off += s.length
        out = {"tokens": jnp.asarray(toks), "seg": jnp.asarray(seg),
               "slots": jnp.asarray(slots), "starts": jnp.asarray(starts),
               "lengths": jnp.asarray(lengths), "active": jnp.asarray(True)}
        if rows is not None:
            out["rows"] = jnp.asarray(rows)
        return out

    def compile_counts(self) -> Dict[str, int]:
        """Executables behind each jitted engine entry point — the compile-
        cache regression surface.  The unified chunked step keeps ``step``
        at 1 however many distinct prompt lengths are admitted;
        ``admission_prefill`` counts the legacy per-length prefill
        executables (one per distinct prompt length / pad size)."""
        out = {"step": self._step_fn._cache_size()}
        if self.paged:
            out["admission_prefill"] = self._prefill_pages._cache_size()
        else:
            out["admission_prefill"] = self._inject._cache_size()
        return out

    # ------------------------------------------------------------------
    def step(self, chunk: Optional[ChunkWork] = None,
             spec_lens=None, spec_drafts=None,
             spec_have=None) -> SlotStepView:
        """One fused step for every slot (vector pos): decode + probe — and,
        in chunked mode, up to ``chunk_tokens`` prompt tokens of up to
        ``max_pack`` mid-prefill requests packed into ``chunk`` (None =
        decode-only, the same executable runs with an inactive chunk);
        several residents may finish their prefill in one step.

        A spec engine additionally takes ``spec_lens`` — per-slot verify
        lengths in [0, spec_tokens] (None = 0 everywhere) — and advances
        each slot's ``pos`` by its ACCEPTED length instead of 1; the view's
        spec fields carry the committed multi-token sequences.
        ``spec_drafts``/``spec_have`` inject host-side drafts (the shared
        draft cache): slots with ``have=False`` fall back to the model
        family's own drafter.  Tree engines (``spec_tree``) take drafts
        shaped (n_slots, W, D) and lens counts NODES in [0, 1 + W*D]."""
        pos = jnp.asarray(self.pos, jnp.int32)
        args = [self.params, self.theta, self.token, self.state, pos,
                self.st]
        if self.chunk_tokens:
            args.append(self._null_chunk if chunk is None
                        else self._chunk_to_device(chunk))
        else:
            assert chunk is None, "engine built without chunk_tokens"
        if self.spec_tokens:
            spec = dict(self._null_spec)
            if spec_lens is not None:
                spec["lens"] = jnp.asarray(np.asarray(spec_lens, np.int32))
            if spec_drafts is not None:
                assert spec_have is not None, \
                    "spec_drafts needs its per-slot have mask"
                spec["drafts"] = jnp.asarray(np.asarray(spec_drafts,
                                                        np.int32))
                spec["have"] = jnp.asarray(np.asarray(spec_have, bool))
            self.token, self.state, self.st, extras = self._step_fn(
                *args, spec)
            gen = np.asarray(extras["gen"])
            self.pos = self.pos + gen
            return SlotStepView(tokens=np.asarray(self.token),
                                stopped=np.asarray(self.st.stopped),
                                stop_step=np.asarray(self.st.stop_step),
                                n_scores=np.asarray(self.st.n_scores),
                                smoothed=np.asarray(self.st.smoothed),
                                gen=gen, seq=np.asarray(extras["seq"]),
                                seq_scores=np.asarray(extras["seq_scores"]),
                                seq_n=np.asarray(extras["seq_n"]))
        assert spec_lens is None and spec_drafts is None, \
            "engine built without spec_tokens"
        self.token, self.state, self.st = self._step_fn(*args)
        self.pos = self.pos + 1
        return SlotStepView(tokens=np.asarray(self.token),
                            stopped=np.asarray(self.st.stopped),
                            stop_step=np.asarray(self.st.stop_step),
                            n_scores=np.asarray(self.st.n_scores),
                            smoothed=np.asarray(self.st.smoothed))
