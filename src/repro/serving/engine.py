"""Batched serving engine with ORCA risk-controlled early stopping.

The paper's technique is a first-class serving feature here: ``serve_step``
fuses one decode step of the base model with the ORCA probe — step-embedding
accumulation (mean-pooled hidden states over ``tokens_per_step`` tokens),
score-then-update fast-weight dynamics (Algorithm 2 lines 8-16), rolling
smoothing and the calibrated threshold test.  Sequences freeze once stopped
(their compute is saved; in a production continuous-batching server they
would be evicted and replaced — here the batch simply runs until all stop).

This same ``serve_step`` is what the decode-shape dry-runs lower to the
production mesh: the deployed procedure (model + adaptation + stopping) is
exactly what gets calibrated, per the paper's validity argument.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import probe as P
from repro.core.probe import ProbeConfig
from repro.models.registry import Model


class ProbeState(NamedTuple):
    """Vectorized fast-weight + smoothing state for a batch of sequences."""
    W: jnp.ndarray          # (B, f)
    b: jnp.ndarray          # (B,)
    hid_sum: jnp.ndarray    # (B, d_phi) accumulating the current step
    tok_count: jnp.ndarray  # (B,) tokens into the current step
    ring: jnp.ndarray       # (B, window) last raw scores
    n_scores: jnp.ndarray   # (B,) number of scores emitted
    smoothed: jnp.ndarray   # (B,) current smoothed score
    stopped: jnp.ndarray    # (B,) bool
    stop_step: jnp.ndarray  # (B,) reasoning step at which stopped (-1 active)


def init_probe_state(pc: ProbeConfig, theta, batch: int,
                     d_phi: int) -> ProbeState:
    f = pc.feat_dim
    return ProbeState(
        W=jnp.broadcast_to(theta["W0"], (batch, f)).astype(jnp.float32),
        b=jnp.broadcast_to(theta["b0"], (batch,)).astype(jnp.float32),
        hid_sum=jnp.zeros((batch, d_phi), jnp.float32),
        tok_count=jnp.zeros((batch,), jnp.int32),
        ring=jnp.zeros((batch, pc.smooth_window), jnp.float32),
        n_scores=jnp.zeros((batch,), jnp.int32),
        smoothed=jnp.zeros((batch,), jnp.float32),
        stopped=jnp.zeros((batch,), bool),
        stop_step=jnp.full((batch,), -1, jnp.int32),
    )


def probe_update(pc: ProbeConfig, theta, st: ProbeState, hidden: jnp.ndarray,
                 lam: float, tokens_per_step: int, burn_in: int) -> ProbeState:
    """Accumulate one token's hidden state; at step boundaries run the
    score-then-update protocol and the threshold stopping test."""
    hid_sum = st.hid_sum + hidden.astype(jnp.float32)
    tok_count = st.tok_count + 1
    boundary = (tok_count >= tokens_per_step) & ~st.stopped

    phi = hid_sum / jnp.maximum(tok_count, 1)[:, None]
    zq, zk = P.features(pc, theta, phi)
    # per-sequence fast weights: s_t = sigma(W_i . z_i + b_i), uses W_{t-1}
    s = jax.nn.sigmoid(jnp.sum(zq * st.W, axis=-1) + st.b)      # (B,)
    # rolling smoothing
    ring = jnp.where(boundary[:, None],
                     jnp.concatenate([st.ring[:, 1:], s[:, None]], axis=1),
                     st.ring)
    n_scores = st.n_scores + boundary.astype(jnp.int32)
    w = pc.smooth_window
    denom = jnp.minimum(n_scores, w).astype(jnp.float32)
    smoothed = jnp.where(n_scores > 0,
                         jnp.sum(ring, axis=1) / jnp.maximum(denom, 1.0),
                         0.0)
    # stopping decision (Algorithm 2 line 11), after the burn-in
    stop_now = boundary & (smoothed >= lam) & (n_scores > burn_in)
    stopped = st.stopped | stop_now
    stop_step = jnp.where(stop_now & (st.stop_step < 0), n_scores, st.stop_step)
    # inner-loop update with pseudo-target C_t = 0 (only while not stopped)
    gW, gb = jax.vmap(lambda fast, z: P.brier_grad(fast, z, 0.0),
                      in_axes=((0, 0), 0))((st.W, st.b), zk)
    eta = P.inner_lr(pc, theta)
    upd = (boundary & ~stopped).astype(jnp.float32)
    W = st.W - eta * upd[:, None] * gW
    b = st.b - eta * upd * gb
    # reset accumulators at boundaries
    hid_sum = jnp.where(boundary[:, None], 0.0, hid_sum)
    tok_count = jnp.where(boundary, 0, tok_count)
    return ProbeState(W, b, hid_sum, tok_count, ring, n_scores, smoothed,
                      stopped, stop_step)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    tokens_per_step: int = 16     # tokens per "reasoning step" for phi_t
    max_new_tokens: int = 256
    lam: float = 0.9              # LTT-calibrated threshold lambda*
    burn_in: int = 10             # steps before stopping is allowed
    greedy: bool = True


def make_serve_step(model: Model, pc: ProbeConfig, cfg: ServeConfig,
                    window: Optional[int] = None):
    """Build the fused decode+ORCA step:
    (params, theta, token, cache, pos, probe_state) ->
    (next_token, cache, probe_state)."""
    mcfg = model.cfg

    def serve_step(params, theta, token, cache, pos, st: ProbeState):
        logits, hidden, cache = model.decode_step(mcfg, params, token, cache,
                                                  pos, window=window)
        st = probe_update(pc, theta, st, hidden, cfg.lam,
                          cfg.tokens_per_step, cfg.burn_in)
        nxt = jnp.argmax(logits[:, :mcfg.vocab_size], axis=-1).astype(jnp.int32)
        # frozen sequences keep emitting their last token (no-op compute slot)
        nxt = jnp.where(st.stopped, token, nxt)
        return nxt, cache, st

    return serve_step


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray        # (B, max_new_tokens)
    stop_step: np.ndarray     # (B,) reasoning step at stop (-1 = budget)
    steps_run: np.ndarray     # (B,) reasoning steps actually executed
    savings: float
    scores: np.ndarray        # (B, n_steps) smoothed score at each step
    phis: np.ndarray          # (B, n_steps, d_phi) step embeddings


class ServingEngine:
    """Minimal batched server: prefill once, loop the fused serve_step."""

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg

    def serve(self, batch: Dict[str, jnp.ndarray], prompt_len: int,
              cache_len: Optional[int] = None) -> ServeResult:
        model, cfg = self.model, self.cfg
        mcfg = model.cfg
        B = next(iter(batch.values())).shape[0]
        n_total = prompt_len + cfg.max_new_tokens
        cache_len = cache_len or n_total
        state, last_h, _ = model.prefill(mcfg, self.params, batch, cache_len)
        step_fn = jax.jit(make_serve_step(model, self.pc, cfg))
        st = init_probe_state(self.pc, self.theta, B, mcfg.d_model)
        token = jnp.zeros((B,), jnp.int32)
        toks, scores, phis = [], [], []
        pos0 = prompt_len if mcfg.arch_type != "audio" else 0
        for i in range(cfg.max_new_tokens):
            pos = jnp.asarray(pos0 + i, jnp.int32)
            prev_n = st.n_scores
            token, state, st = step_fn(self.params, self.theta, token, state,
                                       pos, st)
            toks.append(np.asarray(token))
            if int(np.asarray(jnp.max(st.n_scores))) > int(np.asarray(jnp.max(prev_n))):
                scores.append(np.asarray(st.smoothed))
            if bool(np.asarray(jnp.all(st.stopped))):
                break
        stop_step = np.asarray(st.stop_step)
        n_steps = int(np.asarray(jnp.max(st.n_scores)))
        steps_run = np.where(stop_step >= 0, stop_step,
                             np.asarray(st.n_scores))
        total = max(cfg.max_new_tokens // cfg.tokens_per_step, 1)
        savings = float(np.mean(1.0 - steps_run / total))
        return ServeResult(
            tokens=np.stack(toks, axis=1) if toks else np.zeros((B, 0), np.int32),
            stop_step=stop_step, steps_run=steps_run, savings=savings,
            scores=np.stack(scores, axis=1) if scores else np.zeros((B, 0)),
            phis=np.zeros((B, 0, mcfg.d_model)))


def extract_trajectories(model: Model, params, batch, prompt_len: int,
                         max_new_tokens: int, tokens_per_step: int,
                         cache_len: Optional[int] = None):
    """Run the model WITHOUT stopping and harvest step embeddings phi_t —
    the trajectory source for meta-training probes on a real model."""
    mcfg = model.cfg
    B = next(iter(batch.values())).shape[0]
    cache_len = cache_len or (prompt_len + max_new_tokens)
    state, _, _ = model.prefill(mcfg, params, batch, cache_len)
    token = jnp.zeros((B,), jnp.int32)
    step_fn = jax.jit(functools.partial(model.decode_step, mcfg))
    pos0 = prompt_len if mcfg.arch_type != "audio" else 0
    phis, acc, cnt = [], jnp.zeros((B, mcfg.d_model), jnp.float32), 0
    tokens = []
    for i in range(max_new_tokens):
        pos = jnp.asarray(pos0 + i, jnp.int32)
        logits, hidden, state = step_fn(params, token, state, pos)
        token = jnp.argmax(logits[:, :mcfg.vocab_size], -1).astype(jnp.int32)
        tokens.append(np.asarray(token))
        acc = acc + hidden.astype(jnp.float32)
        cnt += 1
        if cnt == tokens_per_step:
            phis.append(np.asarray(acc / cnt))
            acc, cnt = jnp.zeros_like(acc), 0
    return (np.stack(phis, axis=1) if phis else np.zeros((B, 0, mcfg.d_model)),
            np.stack(tokens, axis=1))
