"""Pluggable scheduling policies for the unified token-budget step.

``OrcaScheduler``'s batch composer asks its policy three questions every
iteration:

* **whom to admit** (``select_admit``) — which WAITING request takes the
  next free slot.  FIFO takes the queue head; the priority policy serves
  latency-sensitive requests first with an anti-starvation aging guard for
  the batch class; the EDF policy ranks by per-request deadline (falling
  back to per-class SLOs, which ``EDFPolicy.from_metrics`` derives from a
  previous run's ``c<class>_ttft_ms_p99`` fleet metrics).
* **whom to preempt** (``select_victim``) — when a reservation fails for a
  strictly-higher-priority unit, which resident is spilled to host RAM to
  make room.  Default: least-important class first, newest admission first
  (its KV investment is smallest).  Only strictly-lower-priority residents
  are ever eligible, so the preemption relation is a DAG and a restored
  victim can never preempt its preemptor (no livelock).
* **how much prefill** (``prefill_share``) — how many of the step's budget
  tokens go to mid-prefill residents (the composer then packs them across
  up to ``max_pack`` requests).  FIFO gives prefill whatever the decode
  fleet leaves; the TTFT-aware policy widens the share when decode slots
  are idle and throttles it when the fleet is full, tuning the
  TTFT-vs-stall trade the committed benchmark measures.

All policies share one anti-starvation aging clock (``max_head_skips``):
a unit passed over — by a priority queue-jump OR because it is a gang
needing more slots than are free while a smaller unit admits past it —
ages toward a PIN, after which nothing may be admitted past it.

Every policy also carries the **probe-aware chunk sizing** knob
(``probe_margin``): when at least half the running residents are within
``probe_margin`` decoded tokens of their next probe boundary (the step
where a stop decision can fire), the prefill share is halved so the
boundary step — and the page reclaim an ORCA stop triggers — lands sooner
in wall-clock.  Policies only ever move WHEN work happens, never what the
probe sees: per-request stop decisions are schedule-invariant by the
eviction-invariance argument (asserted across policies in
``tests/test_packed_chunks.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class ComposeView:
    """What a policy may observe when sizing the step's prefill share."""
    n_running: int        # resident decode rows this step
    n_slots: int
    n_prefilling: int     # resident mid-prefill rows
    n_waiting: int
    token_budget: int
    chunk_tokens: int
    near_boundary: int    # running residents within probe_margin of a boundary


class SchedulingPolicy:
    """Base policy: FIFO admission, greedy prefill share, lowest-class /
    newest-first victim selection.

    ``probe_margin`` (tokens) enables probe-aware chunk sizing; None
    disables it.  ``max_head_skips`` bounds how many times any unit may be
    passed over (priority queue-jump or oversized-gang skip) before it is
    pinned."""

    name = "fifo"

    def __init__(self, *, probe_margin: Optional[int] = None,
                 max_head_skips: int = 8):
        self.probe_margin = probe_margin
        assert max_head_skips >= 1
        self.max_head_skips = int(max_head_skips)
        self._head_skips: Dict[int, int] = {}

    # -- admission -----------------------------------------------------
    def select_admit(self, waiting: Sequence[Request], step: int) -> int:
        """Index into ``waiting`` of the request to admit next.  Must be
        side-effect free: the scheduler may select without admitting (a
        paged reservation can fail and leave the queue untouched)."""
        return 0

    def on_admitted(self, waiting: Sequence[Request], idx: int) -> None:
        """Called AFTER the request at ``idx`` was actually admitted
        (reservation succeeded, slot assigned) and before it leaves the
        queue — the place for aging/fairness bookkeeping, so pool-full
        iterations that admit nobody never advance fairness clocks."""
        self._head_skips.pop(waiting[idx].req_id, None)

    def on_skipped_unit(self, units: Sequence[Sequence[Request]],
                        idx: int) -> bool:
        """The scheduler wants to pass over the SELECTED unit at ``idx``
        (e.g. a gang needing more slots than are free) and admit a smaller
        unit this iteration.  Returns True to allow the skip (and advances
        the unit's aging clock); False when the unit has already been
        skipped ``max_head_skips`` times and is now PINNED — the scheduler
        must stop admitting past it and wait for capacity, so a blocked
        gang always makes progress under a sustained singleton stream."""
        rid = units[idx][0].req_id
        n = self._head_skips.get(rid, 0)
        if n >= self.max_head_skips:
            return False
        self._head_skips[rid] = n + 1
        return True

    # -- preemption ----------------------------------------------------
    def select_victim(self, residents: Sequence[Request],
                      for_priority: int) -> Optional[int]:
        """Index into ``residents`` of the request to preempt (spill to
        host RAM) to make room for an admission of priority class
        ``for_priority``, or None to refuse.  Only strictly-LOWER-priority
        residents (``priority > for_priority``: larger number = less
        urgent) are eligible — the preemption relation is then a DAG, so a
        restored victim can never preempt its preemptor and the scheduler
        cannot livelock.  Must be side-effect free (the scheduler runs a
        feasibility simulation before executing any spill).  Default:
        least-important class first, newest admission first within a class
        (its KV investment is smallest, vLLM's recompute-cheapest rule)."""
        eligible = [i for i, r in enumerate(residents)
                    if r.priority > for_priority]
        if not eligible:
            return None
        return max(eligible, key=lambda i: (residents[i].priority,
                                            residents[i].admitted_step,
                                            residents[i].req_id))

    # -- gang admission (self-consistency groups) ----------------------
    def select_admit_unit(self, units: Sequence[Sequence[Request]],
                          step: int) -> int:
        """Index of the WAITING unit to gang-admit next.  A unit is a
        whole self-consistency group (admitted atomically: all samples or
        none) or a singleton for an ungrouped request.  Default: delegate
        to ``select_admit`` over the unit heads, so FIFO/priority/TTFT
        semantics lift to groups unchanged — and an all-singleton queue
        behaves exactly like the classic per-request path."""
        return self.select_admit([u[0] for u in units], step)

    def on_admitted_unit(self, units: Sequence[Sequence[Request]],
                         idx: int) -> None:
        """Unit-level ``on_admitted`` (same delegation contract)."""
        self.on_admitted([u[0] for u in units], idx)

    # -- composition ---------------------------------------------------
    def prefill_share(self, view: ComposeView) -> int:
        """Budget tokens this step's packed prefill chunk may spend."""
        share = min(view.chunk_tokens, view.token_budget - view.n_running)
        return self._probe_shrink(share, view)

    def _probe_shrink(self, share: int, view: ComposeView) -> int:
        """Probe-aware chunk sizing: when at least half the running
        residents are about to hit a probe boundary, halve the prefill
        share so their stop decisions (and the page reclaim a stop
        triggers) land sooner in wall-clock."""
        if (self.probe_margin is None or view.n_running == 0
                or share <= 1):
            return share
        if 2 * view.near_boundary >= view.n_running:
            return max(share // 2, 1)
        return share

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(probe_margin={self.probe_margin})"


class FIFOPolicy(SchedulingPolicy):
    """Strict arrival order, greedy prefill share — PR-4's composer."""

    name = "fifo"


class PriorityPolicy(SchedulingPolicy):
    """Priority-class admission: latency-sensitive requests (lower
    ``Request.priority``) are admitted before batch traffic, FIFO within a
    class.  Anti-starvation aging: the queue head is never skipped more
    than ``max_head_skips`` times — after that it is admitted regardless
    of class, so the batch class always makes progress under a sustained
    latency-class stream (asserted in ``tests/test_packed_chunks.py``)."""

    name = "priority"

    def __init__(self, *, max_head_skips: int = 8,
                 probe_margin: Optional[int] = None):
        super().__init__(probe_margin=probe_margin,
                         max_head_skips=max_head_skips)

    def select_admit(self, waiting: Sequence[Request], step: int) -> int:
        if self._head_skips.get(waiting[0].req_id, 0) >= self.max_head_skips:
            return 0
        return min(range(len(waiting)), key=lambda i: waiting[i].priority)

    def on_admitted(self, waiting: Sequence[Request], idx: int) -> None:
        # the aging clock counts ACTUAL queue-jumps only: a selection whose
        # reservation failed admitted nobody and must not age the head
        head = waiting[0]
        if idx != 0:
            self._head_skips[head.req_id] = \
                self._head_skips.get(head.req_id, 0) + 1
        self._head_skips.pop(waiting[idx].req_id, None)


class EDFPolicy(PriorityPolicy):
    """Earliest-deadline-first admission: rank WAITING units by deadline
    instead of raw class.  A request's deadline is its own ``deadline_ms``
    when set, else the SLO of its priority class (``class_slo_ms``), else
    ``default_slo_ms * (priority + 1)`` — so with no configuration at all
    EDF degrades gracefully to priority order.  ``from_metrics`` closes
    the loop with the fleet's own observability: per-class SLOs are seeded
    from a previous run's ``c<class>_ttft_ms_p99`` metrics (what each
    class ACTUALLY achieves, scaled by ``slack``), so the ranking adapts
    to the serving configuration rather than hand-tuned constants.
    Deadlines are measured from the shared submission epoch;
    ``submitted_step`` breaks ties for staggered arrivals.  Inherits the
    priority policy's head-pin aging and the base victim selection."""

    name = "edf"

    def __init__(self, *, class_slo_ms: Optional[Dict[int, float]] = None,
                 default_slo_ms: float = 1000.0, max_head_skips: int = 8,
                 probe_margin: Optional[int] = None):
        super().__init__(max_head_skips=max_head_skips,
                         probe_margin=probe_margin)
        self.class_slo_ms = {int(k): float(v)
                             for k, v in (class_slo_ms or {}).items()}
        self.default_slo_ms = float(default_slo_ms)

    @classmethod
    def from_metrics(cls, per_class: Dict[str, float], *,
                     slack: float = 1.0, **kwargs) -> "EDFPolicy":
        """Build an EDF policy whose class SLOs are a previous run's
        observed ``c<class>_ttft_ms_p99`` (``FleetMetrics.per_class``),
        scaled by ``slack`` (>1 loosens, <1 tightens)."""
        import re
        slo = {}
        for key, val in (per_class or {}).items():
            m = re.fullmatch(r"c(\d+)_ttft_ms_p99", key)
            if m:
                slo[int(m.group(1))] = float(val) * float(slack)
        return cls(class_slo_ms=slo, **kwargs)

    def _deadline(self, r: Request) -> float:
        if r.deadline_ms is not None:
            return float(r.deadline_ms)
        return self.class_slo_ms.get(
            r.priority, self.default_slo_ms * (r.priority + 1))

    def select_admit(self, waiting: Sequence[Request], step: int) -> int:
        if self._head_skips.get(waiting[0].req_id, 0) >= self.max_head_skips:
            return 0
        return min(range(len(waiting)),
                   key=lambda i: (self._deadline(waiting[i]),
                                  waiting[i].submitted_step,
                                  waiting[i].req_id))


class TTFTAwarePolicy(SchedulingPolicy):
    """TTFT-aware prefill sizing: while the fleet has FREE slots the
    policy widens the prefill share to everything the budget allows (new
    prompts reach their first token fastest exactly when there is idle
    capacity to spare); once every slot is occupied it throttles prefill
    to ``busy_share`` tokens per step, bounding the per-step stall each
    decoding resident pays.  Admission stays FIFO."""

    name = "ttft"

    def __init__(self, *, busy_share: Optional[int] = None,
                 probe_margin: Optional[int] = None):
        super().__init__(probe_margin=probe_margin)
        self.busy_share = busy_share

    def prefill_share(self, view: ComposeView) -> int:
        share = min(view.chunk_tokens, view.token_budget - view.n_running)
        # saturated = no free slots (running and mid-prefill residents
        # partition the fleet; prefill_share is only consulted while at
        # least one resident is mid-prefill, so n_running alone can never
        # reach n_slots here)
        if view.n_running + view.n_prefilling >= view.n_slots:
            busy = self.busy_share
            if busy is None:
                busy = max(view.chunk_tokens // 2, 1)
            share = min(share, busy)
        return self._probe_shrink(share, view)


_POLICIES = {
    "fifo": FIFOPolicy,
    "priority": PriorityPolicy,
    "edf": EDFPolicy,
    "ttft": TTFTAwarePolicy,
}


def make_policy(policy: Union[str, SchedulingPolicy, None]
                ) -> SchedulingPolicy:
    """Resolve a policy spec: an instance passes through, a name builds
    the registered class with defaults, None means FIFO."""
    if policy is None:
        return FIFOPolicy()
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r} "
                         f"(expected one of {sorted(_POLICIES)})") from None


# ---------------------------------------------------------------------------
# fleet-level placement (PR 8): the router-side half of the policy split.
# ``select_admit`` semantics move UP a level — the FleetRouter picks which
# HOST admits a unit, fed by per-host HostPressure snapshots; each host's
# own SchedulingPolicy instance then runs the unchanged single-host
# admission, so stop decisions stay byte-identical under every placement.

@dataclasses.dataclass(frozen=True)
class HostPressure:
    """One host's scheduler pressure, gossiped to the router each step.

    A ``ComposeView``-style snapshot exported by ``OrcaScheduler.pressure()``
    (scheduler occupancy + kv_pool page counts); the placement policy sees
    one of these per host and nothing else — the same information a real
    fleet's gossip/heartbeat protocol would carry.
    """

    host: int
    n_slots: int
    n_running: int
    n_prefilling: int
    n_swapped: int
    n_waiting: int            # queued admission units (gangs count once)
    queued_samples: int       # queued individual requests (gang members)
    free_slots: int
    pool_blocks: int          # usable pages (0 when the host is not paged)
    free_blocks: int
    blocks_in_use: int
    max_resident_priority: Optional[int] = None

    @property
    def outstanding(self) -> int:
        """Samples this host still owes work: queued + resident + swapped."""
        return (self.queued_samples + self.n_running
                + self.n_prefilling + self.n_swapped)


class PlacementPolicy:
    """Chooses the host a gang-admission unit is routed to.

    The fleet analogue of ``SchedulingPolicy.select_admit``: same
    priority/aging/gang semantics (the ROUTER's SchedulingPolicy still
    orders the queue; this class only places the unit it selected).
    Stateless by default so one instance may serve many routers.
    """

    def select_host(self, unit: Sequence[Request],
                    pressures: Sequence[HostPressure], *,
                    need_slots: int, need_pages: int,
                    affine_host: Optional[int] = None) -> Optional[int]:
        """Return the host index for ``unit``, or None if NO host can ever
        fit it (total capacity, not current load — the router raises on
        None rather than queueing forever).

        ``affine_host`` is the prefix-registry hint: the host already
        holding donor pages for this unit's prompt hash, or None.
        """
        feasible = [p for p in pressures
                    if p.n_slots >= need_slots
                    and (need_pages == 0 or p.pool_blocks >= need_pages)]
        if not feasible:
            return None
        # prefix affinity wins whenever the donor host can fit the unit at
        # all: landing on the donor turns the whole prompt prefill into a
        # page-table copy (prefill_skipped), worth far more than a
        # marginally shorter queue elsewhere
        if affine_host is not None:
            for p in feasible:
                if p.host == affine_host:
                    return p.host
        return self.rank(unit, feasible)

    def rank(self, unit: Sequence[Request],
             feasible: Sequence[HostPressure]) -> int:
        """Pick among feasible hosts (affinity already handled).

        Default: least-loaded by outstanding samples, pages in use
        breaking ties, host index last so placement is deterministic.
        """
        best = min(feasible, key=lambda p: (p.outstanding,
                                            p.blocks_in_use, p.host))
        return best.host


class PressurePlacement(PlacementPolicy):
    """Least-outstanding-samples placement with prefix affinity (default)."""


class RoundRobinPlacement(PlacementPolicy):
    """Rotate placements across feasible hosts, ignoring pressure AND
    prefix affinity — the placement-invariance probe: stop decisions must
    be byte-identical even under this deliberately locality-blind policy.
    """

    def __init__(self) -> None:
        self._next = 0

    def select_host(self, unit: Sequence[Request],
                    pressures: Sequence[HostPressure], *,
                    need_slots: int, need_pages: int,
                    affine_host: Optional[int] = None) -> Optional[int]:
        feasible = [p for p in pressures
                    if p.n_slots >= need_slots
                    and (need_pages == 0 or p.pool_blocks >= need_pages)]
        if not feasible:
            return None
        pick = feasible[self._next % len(feasible)]
        self._next += 1
        return pick.host


_PLACEMENTS = {
    "pressure": PressurePlacement,
    "roundrobin": RoundRobinPlacement,
}


def make_placement(placement: Union[str, PlacementPolicy, None]
                   ) -> PlacementPolicy:
    """Resolve a placement spec: an instance passes through, a name builds
    the registered class, None means pressure-balanced with prefix
    affinity (the default that makes prefix sharing a fleet-level win)."""
    if placement is None:
        return PressurePlacement()
    if isinstance(placement, PlacementPolicy):
        return placement
    try:
        return _PLACEMENTS[placement]()
    except KeyError:
        raise ValueError(
            f"unknown placement policy {placement!r} (expected one of "
            f"{sorted(_PLACEMENTS)}); fix by passing 'pressure' "
            "(load-balanced + prefix-affine) or 'roundrobin', or a "
            "PlacementPolicy instance") from None
