"""Pluggable scheduling policies for the unified token-budget step.

``OrcaScheduler``'s batch composer asks its policy two questions every
iteration:

* **whom to admit** (``select_admit``) — which WAITING request takes the
  next free slot.  FIFO takes the queue head; the priority policy serves
  latency-sensitive requests first with an anti-starvation aging guard for
  the batch class.
* **how much prefill** (``prefill_share``) — how many of the step's budget
  tokens go to mid-prefill residents (the composer then packs them across
  up to ``max_pack`` requests).  FIFO gives prefill whatever the decode
  fleet leaves; the TTFT-aware policy widens the share when decode slots
  are idle and throttles it when the fleet is full, tuning the
  TTFT-vs-stall trade the committed benchmark measures.

Every policy also carries the **probe-aware chunk sizing** knob
(``probe_margin``): when at least half the running residents are within
``probe_margin`` decoded tokens of their next probe boundary (the step
where a stop decision can fire), the prefill share is halved so the
boundary step — and the page reclaim an ORCA stop triggers — lands sooner
in wall-clock.  Policies only ever move WHEN work happens, never what the
probe sees: per-request stop decisions are schedule-invariant by the
eviction-invariance argument (asserted across policies in
``tests/test_packed_chunks.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class ComposeView:
    """What a policy may observe when sizing the step's prefill share."""
    n_running: int        # resident decode rows this step
    n_slots: int
    n_prefilling: int     # resident mid-prefill rows
    n_waiting: int
    token_budget: int
    chunk_tokens: int
    near_boundary: int    # running residents within probe_margin of a boundary


class SchedulingPolicy:
    """Base policy: FIFO admission, greedy prefill share.

    ``probe_margin`` (tokens) enables probe-aware chunk sizing; None
    disables it."""

    name = "fifo"

    def __init__(self, *, probe_margin: Optional[int] = None):
        self.probe_margin = probe_margin

    # -- admission -----------------------------------------------------
    def select_admit(self, waiting: Sequence[Request], step: int) -> int:
        """Index into ``waiting`` of the request to admit next.  Must be
        side-effect free: the scheduler may select without admitting (a
        paged reservation can fail and leave the queue untouched)."""
        return 0

    def on_admitted(self, waiting: Sequence[Request], idx: int) -> None:
        """Called AFTER the request at ``idx`` was actually admitted
        (reservation succeeded, slot assigned) and before it leaves the
        queue — the place for aging/fairness bookkeeping, so pool-full
        iterations that admit nobody never advance fairness clocks."""

    # -- gang admission (self-consistency groups) ----------------------
    def select_admit_unit(self, units: Sequence[Sequence[Request]],
                          step: int) -> int:
        """Index of the WAITING unit to gang-admit next.  A unit is a
        whole self-consistency group (admitted atomically: all samples or
        none) or a singleton for an ungrouped request.  Default: delegate
        to ``select_admit`` over the unit heads, so FIFO/priority/TTFT
        semantics lift to groups unchanged — and an all-singleton queue
        behaves exactly like the classic per-request path."""
        return self.select_admit([u[0] for u in units], step)

    def on_admitted_unit(self, units: Sequence[Sequence[Request]],
                         idx: int) -> None:
        """Unit-level ``on_admitted`` (same delegation contract)."""
        self.on_admitted([u[0] for u in units], idx)

    # -- composition ---------------------------------------------------
    def prefill_share(self, view: ComposeView) -> int:
        """Budget tokens this step's packed prefill chunk may spend."""
        share = min(view.chunk_tokens, view.token_budget - view.n_running)
        return self._probe_shrink(share, view)

    def _probe_shrink(self, share: int, view: ComposeView) -> int:
        """Probe-aware chunk sizing: when at least half the running
        residents are about to hit a probe boundary, halve the prefill
        share so their stop decisions (and the page reclaim a stop
        triggers) land sooner in wall-clock."""
        if (self.probe_margin is None or view.n_running == 0
                or share <= 1):
            return share
        if 2 * view.near_boundary >= view.n_running:
            return max(share // 2, 1)
        return share

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(probe_margin={self.probe_margin})"


class FIFOPolicy(SchedulingPolicy):
    """Strict arrival order, greedy prefill share — PR-4's composer."""

    name = "fifo"


class PriorityPolicy(SchedulingPolicy):
    """Priority-class admission: latency-sensitive requests (lower
    ``Request.priority``) are admitted before batch traffic, FIFO within a
    class.  Anti-starvation aging: the queue head is never skipped more
    than ``max_head_skips`` times — after that it is admitted regardless
    of class, so the batch class always makes progress under a sustained
    latency-class stream (asserted in ``tests/test_packed_chunks.py``)."""

    name = "priority"

    def __init__(self, *, max_head_skips: int = 8,
                 probe_margin: Optional[int] = None):
        super().__init__(probe_margin=probe_margin)
        assert max_head_skips >= 1
        self.max_head_skips = int(max_head_skips)
        self._head_skips: Dict[int, int] = {}

    def select_admit(self, waiting: Sequence[Request], step: int) -> int:
        if self._head_skips.get(waiting[0].req_id, 0) >= self.max_head_skips:
            return 0
        return min(range(len(waiting)), key=lambda i: waiting[i].priority)

    def on_admitted(self, waiting: Sequence[Request], idx: int) -> None:
        # the aging clock counts ACTUAL queue-jumps only: a selection whose
        # reservation failed admitted nobody and must not age the head
        head = waiting[0]
        if idx != 0:
            self._head_skips[head.req_id] = \
                self._head_skips.get(head.req_id, 0) + 1
        else:
            self._head_skips.pop(head.req_id, None)


class TTFTAwarePolicy(SchedulingPolicy):
    """TTFT-aware prefill sizing: while the fleet has FREE slots the
    policy widens the prefill share to everything the budget allows (new
    prompts reach their first token fastest exactly when there is idle
    capacity to spare); once every slot is occupied it throttles prefill
    to ``busy_share`` tokens per step, bounding the per-step stall each
    decoding resident pays.  Admission stays FIFO."""

    name = "ttft"

    def __init__(self, *, busy_share: Optional[int] = None,
                 probe_margin: Optional[int] = None):
        super().__init__(probe_margin=probe_margin)
        self.busy_share = busy_share

    def prefill_share(self, view: ComposeView) -> int:
        share = min(view.chunk_tokens, view.token_budget - view.n_running)
        # saturated = no free slots (running and mid-prefill residents
        # partition the fleet; prefill_share is only consulted while at
        # least one resident is mid-prefill, so n_running alone can never
        # reach n_slots here)
        if view.n_running + view.n_prefilling >= view.n_slots:
            busy = self.busy_share
            if busy is None:
                busy = max(view.chunk_tokens // 2, 1)
            share = min(share, busy)
        return self._probe_shrink(share, view)


_POLICIES = {
    "fifo": FIFOPolicy,
    "priority": PriorityPolicy,
    "ttft": TTFTAwarePolicy,
}


def make_policy(policy: Union[str, SchedulingPolicy, None]
                ) -> SchedulingPolicy:
    """Resolve a policy spec: an instance passes through, a name builds
    the registered class with defaults, None means FIFO."""
    if policy is None:
        return FIFOPolicy()
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r} "
                         f"(expected one of {sorted(_POLICIES)})") from None
