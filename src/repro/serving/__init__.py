from repro.serving.engine import (ProbeState, ServeConfig, ServeResult,
                                  ServingEngine, extract_trajectories,
                                  init_probe_state, make_serve_step,
                                  probe_update)

__all__ = ["ProbeState", "ServeConfig", "ServeResult", "ServingEngine",
           "extract_trajectories", "init_probe_state", "make_serve_step",
           "probe_update"]
