from repro.serving.engine import (ContinuousServingEngine, ProbeState,
                                  ServeConfig, ServeResult, ServingEngine,
                                  SlotStepView, StaticQueueResult,
                                  extract_trajectories, init_probe_state,
                                  inject_prefill, make_serve_step,
                                  probe_update, reset_probe_slot,
                                  serve_queue_static)
from repro.serving.replay import (replay_model, replay_params,
                                  replay_requests, served_stop_times)
from repro.serving.request import (FleetMetrics, Request, RequestState,
                                   make_request)
from repro.serving.scheduler import OrcaScheduler

__all__ = ["ContinuousServingEngine", "FleetMetrics", "OrcaScheduler",
           "ProbeState", "Request", "RequestState", "ServeConfig",
           "ServeResult", "ServingEngine", "SlotStepView",
           "StaticQueueResult", "extract_trajectories", "init_probe_state",
           "inject_prefill", "make_request", "make_serve_step",
           "probe_update", "replay_model", "replay_params",
           "replay_requests", "reset_probe_slot", "serve_queue_static",
           "served_stop_times"]
