from repro.serving.config import ServeConfig
from repro.serving.draft_cache import DraftCache
from repro.serving.engine import (ChunkSeg, ChunkWork,
                                  ContinuousServingEngine,
                                  ProbeState, ServeResult,
                                  ServingEngine, SlotStepView, Spill,
                                  StaticQueueResult, chunk_supported,
                                  chunked_prefill, extract_trajectories,
                                  init_probe_state, inject_prefill,
                                  make_serve_step, prefix_len, probe_update,
                                  reset_probe_slot, serve_queue_static,
                                  write_probe_slot)
from repro.serving.groups import (RequestGroup, group_requests, make_group)
from repro.serving.kv_pool import (NULL_BLOCK, BlockPool, PrefixEntry,
                                   blocks_needed, pad_row, prompt_key)
from repro.serving.policy import (ComposeView, EDFPolicy, FIFOPolicy,
                                  HostPressure, PlacementPolicy,
                                  PressurePlacement, PriorityPolicy,
                                  RoundRobinPlacement, SchedulingPolicy,
                                  TTFTAwarePolicy, make_placement,
                                  make_policy)
from repro.serving.replay import (GroupFleet, make_group_fleet,
                                  replay_model, replay_params,
                                  replay_requests, serve_replay,
                                  served_stop_times)
from repro.serving.request import (FleetMetrics, Request, RequestState,
                                   latency_stats, make_request, spec_stats)
from repro.serving.router import FleetRouter
from repro.serving.scheduler import OrcaScheduler

__all__ = ["BlockPool", "ChunkSeg", "ChunkWork", "ComposeView",
           "ContinuousServingEngine", "DraftCache", "EDFPolicy",
           "FIFOPolicy",
           "FleetMetrics", "FleetRouter", "GroupFleet", "HostPressure",
           "NULL_BLOCK", "OrcaScheduler",
           "PlacementPolicy", "PrefixEntry", "PressurePlacement",
           "PriorityPolicy", "ProbeState", "Request", "RequestGroup",
           "RequestState", "RoundRobinPlacement",
           "SchedulingPolicy", "ServeConfig",
           "ServeResult", "ServingEngine", "SlotStepView", "Spill",
           "StaticQueueResult", "TTFTAwarePolicy", "blocks_needed",
           "chunk_supported",
           "chunked_prefill", "extract_trajectories", "group_requests",
           "init_probe_state",
           "inject_prefill", "latency_stats", "make_group",
           "make_group_fleet",
           "make_placement", "make_policy", "make_request",
           "make_serve_step", "pad_row",
           "prefix_len", "probe_update", "prompt_key", "replay_model",
           "replay_params", "replay_requests", "reset_probe_slot",
           "serve_queue_static", "serve_replay", "served_stop_times",
           "spec_stats", "write_probe_slot"]
