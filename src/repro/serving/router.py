"""FleetRouter: multi-host fleet serving over simulated hosts.

The scheduler stack is host-local by construction — the paged ``BlockPool``,
the unified token-budget step and the ``SchedulingPolicy`` instance all
live inside one ``OrcaScheduler``.  This module shards that scheduler
across N *simulated* hosts: each host owns its own engine, pool and a
host-local policy instance, and the router owns only PLACEMENT — which
host a gang-admission unit lands on.

The design is the ``SchedulingPolicy`` split moved up one level:

* the router's own policy instance orders the cross-host queue with the
  SAME ``select_admit_unit`` semantics (priority, anti-starvation aging,
  gangs as atomic units) the host admission loop uses;
* a ``PlacementPolicy`` then picks the host, fed by per-host
  ``HostPressure`` summaries gossiped each step (n_running, n_prefilling,
  n_swapped, free pages — the ``ComposeView``-style snapshot
  ``OrcaScheduler.pressure()`` exports from scheduler + kv_pool);
* prefix-registry-aware placement routes same-prompt-hash traffic
  (including whole self-consistency gangs) to the host already holding
  the donor pages, so prefix sharing becomes a fleet-level win — the
  follower's prefill collapses to a page-table copy on the donor host
  (``prefill_skipped``) instead of a cold prefill elsewhere.

Because each host runs the UNCHANGED single-host scheduler, a request's
stop decision depends only on its own trajectory — per-request stops stay
byte-identical to single-host serving under every placement (the standing
invariant).  A gang is never split across hosts: the whole group places
as one unit, preserving gang-admission atomicity and intra-gang page
sharing.

Hosts step concurrently through a thread pool (the jitted fused step
releases the GIL, so simulated hosts genuinely overlap — the source of
the fleet's throughput win at equal total KV pages); pass
``parallel_hosts=False`` for strictly serial stepping.

The router speaks the same ``submit()`` / ``step()`` / ``drain()`` /
``run()`` protocol as ``OrcaScheduler``, so ``repro.api.serve_requests``
and the benchmark drive either interchangeably.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.config import ServeConfig
from repro.serving.engine import prefix_len
from repro.serving.groups import RequestGroup, group_requests
from repro.serving.kv_pool import prompt_key
from repro.serving.policy import (HostPressure, PlacementPolicy,
                                  SchedulingPolicy, make_placement,
                                  make_policy)
from repro.serving.draft_cache import DraftCache
from repro.serving.request import (FleetMetrics, Request, latency_stats,
                                   spec_stats)
from repro.serving.scheduler import OrcaScheduler, _pick, _UNSET


def _clone_policy(spec: Any) -> SchedulingPolicy:
    """A fresh policy instance per host (and one for the router): aging
    and placement state must be host-local, never shared."""
    if spec is None or isinstance(spec, str):
        return make_policy(spec)
    return copy.deepcopy(spec)


class FleetRouter:
    """Shards ``OrcaScheduler`` across ``n_hosts`` simulated hosts.

    Speaks the scheduler's ``submit``/``step``/``drain``/``run`` protocol;
    construct via ``repro.api.fleet`` in application code.
    """

    def __init__(self, model, params, probe_config, theta,
                 cfg: Optional[ServeConfig] = None, *,
                 n_hosts: Any = _UNSET, placement: Any = _UNSET,
                 parallel_hosts: bool = True) -> None:
        cfg = cfg if cfg is not None else ServeConfig()
        self.n_hosts = int(_pick(n_hosts, cfg.n_hosts))
        if self.n_hosts < 1:
            raise ValueError(
                f"n_hosts={self.n_hosts} must be >= 1; fix by passing a "
                "positive host count (1 behaves like a single scheduler)")
        self.cfg = dataclasses.replace(cfg, n_hosts=self.n_hosts)
        self.model = model
        self.placement: PlacementPolicy = make_placement(
            _pick(placement, cfg.placement))
        # the router's own ordering policy: same select_admit_unit
        # semantics as host admission, applied to the cross-host queue
        self.policy = _clone_policy(cfg.policy)
        self.parallel_hosts = bool(parallel_hosts) and self.n_hosts > 1

        # per-host page budget: cfg.num_blocks is the TOTAL fleet budget,
        # split as evenly as pages allow (first hosts take the remainder)
        shares: List[Optional[int]] = [None] * self.n_hosts
        if cfg.num_blocks:
            per, rem = divmod(int(cfg.num_blocks), self.n_hosts)
            if per < 1:
                raise ValueError(
                    f"num_blocks={cfg.num_blocks} split across "
                    f"{self.n_hosts} hosts leaves a host with an empty "
                    "pool; fix by raising num_blocks to >= "
                    f"{self.n_hosts} or lowering n_hosts")
            shares = [per + (1 if i < rem else 0)
                      for i in range(self.n_hosts)]
        # ONE shared draft cache for the whole fleet (prefix-registry
        # style): a continuation accepted on any host drafts for every
        # other host's traffic.  Host-locally safe — hosts step in
        # threads but lookups/promotions happen in the scheduler's
        # host-side composer/collection, and the cache is pure Python
        spec_on = bool(cfg.spec_tokens or cfg.spec_tree)
        self.draft_cache: Optional[DraftCache] = (
            DraftCache(capacity=cfg.draft_cache_size)
            if spec_on and cfg.draft_cache_size
            and getattr(model, "self_draft", False) else None)
        self.hosts: List[OrcaScheduler] = []
        for share in shares:
            host_cfg = dataclasses.replace(
                cfg, n_hosts=1, num_blocks=share,
                policy=_clone_policy(cfg.policy))
            self.hosts.append(OrcaScheduler(
                model, params, probe_config, theta, host_cfg,
                draft_cache=self.draft_cache))
        # mirror the resolved single-host attributes callers introspect
        h0 = self.hosts[0]
        self.n_slots = h0.n_slots            # PER HOST
        self.paged = h0.paged
        self.block_size = h0.block_size
        self.prefix_sharing = h0.prefix_sharing
        self.consensus = h0.consensus
        self.group_size = cfg.group_size
        self._pool = (ThreadPoolExecutor(
            max_workers=self.n_hosts,
            thread_name_prefix="fleet-host")
            if self.parallel_hosts else None)
        self._session_open = False
        self._reset_session()

    # ------------------------------------------------------------------
    def _reset_session(self) -> None:
        self._queue: List[List[Request]] = []    # unplaced admission units
        self._population: List[Request] = []     # every submitted request
        self._prefix_home: Dict[str, int] = {}   # prompt hash -> host
        self._steps = 0
        self._routed_affine = 0
        self._t0 = time.perf_counter()

    @property
    def has_work(self) -> bool:
        """True while any request is unplaced, queued, swapped or
        resident on any host."""
        return bool(self._queue) or any(h.has_work for h in self.hosts)

    @property
    def groups(self) -> List[RequestGroup]:
        """Consensus outcomes across the fleet (host-owned groups)."""
        out: List[RequestGroup] = []
        for h in self.hosts:
            out.extend(h.groups)
        return out

    def pressures(self) -> List[HostPressure]:
        """The per-host gossip the placement policy consumes."""
        return [h.pressure(i) for i, h in enumerate(self.hosts)]

    # ------------------------------------------------------------------
    def prepare(self, requests: Sequence[Request]) -> None:
        """Size every host's engine/pool for ``requests`` (cumulative with
        earlier submissions) without enqueueing them."""
        if not self._session_open:
            self._reset_session()
            self._session_open = True
        self._population.extend(requests)
        for h in self.hosts:
            h.prepare(self._population)

    def submit(self, requests: Sequence[Request]) -> None:
        """Enqueue ``requests`` and place them onto hosts (eagerly: the
        placement queue fully drains, by total-capacity feasibility, so a
        unit no host can EVER fit raises instead of waiting forever)."""
        requests = list(requests)
        fresh = not self._session_open
        if fresh:
            self._reset_session()
            self._session_open = True
        if not requests:
            return
        self._population.extend(requests)
        # every host sizes for the full population up front: placement
        # must never trigger a mid-flight engine rebuild on a busy host
        for h in self.hosts:
            h.prepare(self._population)
        units, groups = group_requests(requests)
        for grp in groups:
            if grp.size > self.n_slots:
                raise ValueError(
                    f"group {grp.group_id} has {grp.size} samples but "
                    f"each host has {self.n_slots} slots: a gang is "
                    "never split across hosts, so the whole group must "
                    "fit one host; fix by raising n_slots to >= "
                    f"{grp.size} or lowering the group size")
        if fresh:
            self._t0 = time.perf_counter()
        self._queue.extend(units)
        self._place()

    def run(self, requests: Sequence[Request]
            ) -> Tuple[List[Request], FleetMetrics]:
        """One-shot facade: submit + drain (same contract as the
        scheduler's ``run``)."""
        if self._session_open and self.has_work:
            raise RuntimeError(
                "run() while a fleet session is active would reset "
                "resident state; drive incremental traffic through "
                "submit()/step()/drain() instead")
        self._session_open = False
        self.submit(requests)
        return self.drain()

    # ------------------------------------------------------------------
    def _affinity_key(self, req: Request) -> Optional[str]:
        """The prompt hash the prefix registry would file this request
        under — computed router-side (same conditions as the scheduler's
        ``_sharing_key``, without needing a live engine)."""
        if not (self.paged and self.prefix_sharing
                and self.model.supports_paged):
            return None
        if set(req.inputs) != {"tokens"}:
            return None
        if prefix_len(self.model.cfg, req.inputs, req.prompt_len) \
                != req.prompt_len:
            return None
        return prompt_key(np.asarray(req.inputs["tokens"]))

    def _place(self) -> None:
        """Drain the placement queue: the router policy picks the next
        unit (same priority/aging/gang semantics as host admission), the
        placement policy picks its host from the gossiped pressures."""
        while self._queue:
            pressures = self.pressures()
            cand = self._queue
            sel = self.policy.select_admit_unit(cand, self._steps)
            unit = cand[sel]
            members = [r for r in unit if not r.done]
            if not members:          # fully cancelled before placement
                del self._queue[sel]
                continue
            need_pages = 0
            if self.paged:
                need_pages = sum(self.hosts[0]._request_blocks(r)
                                 for r in members)
            key = self._affinity_key(members[0])
            affine = self._prefix_home.get(key) if key else None
            host_idx = self.placement.select_host(
                members, pressures, need_slots=len(members),
                need_pages=need_pages, affine_host=affine)
            if host_idx is None:
                what = (f"group {members[0].group_id}"
                        if members[0].group_id is not None
                        else f"request {members[0].req_id}")
                raise RuntimeError(
                    f"{what} needs {len(members)} slots and "
                    f"{need_pages} pages but no host can ever fit it "
                    f"(per-host: {self.n_slots} slots, "
                    f"{pressures[0].pool_blocks} pages); fix by raising "
                    "n_slots/num_blocks or lowering the group size")
            self.policy.on_admitted_unit(cand, sel)
            del self._queue[sel]
            if affine is not None and host_idx == affine:
                self._routed_affine += 1
            if key is not None and key not in self._prefix_home:
                self._prefix_home[key] = host_idx
            for r in members:
                r.host = host_idx
            self.hosts[host_idx].submit(members)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One fleet iteration: place unrouted units, then step every
        host with work — concurrently when ``parallel_hosts`` (the jitted
        fused step releases the GIL).  Returns False when the fleet is
        idle."""
        if not self.has_work:
            return False
        self._place()
        active = [h for h in self.hosts if h.has_work]
        if self._pool is not None and len(active) > 1:
            list(self._pool.map(lambda h: h.step(), active))
        else:
            for h in active:
                h.step()
        self._steps += 1
        return True

    def drain(self) -> Tuple[List[Request], FleetMetrics]:
        """Step until every host is idle; return all requests (submission
        order) + fleet-aggregated metrics."""
        while self.step():
            pass
        wall = max(time.perf_counter() - self._t0, 1e-9)
        per_host = [h.drain() for h in self.hosts]  # hosts idle: metrics only
        metrics = self._aggregate([m for _, m in per_host], wall)
        requests = list(self._population)
        self._session_open = False
        return requests, metrics

    # ------------------------------------------------------------------
    def _aggregate(self, host_metrics: List[FleetMetrics],
                   wall: float) -> FleetMetrics:
        """Fleet-level FleetMetrics: counters sum, rates recompute over
        the union at the FLEET wall clock, percentiles recompute over the
        request union (never averaged across hosts — wrong for tails)."""
        requests = self._population
        n = len(requests)
        total_tokens = sum(len(r.tokens) for r in requests)
        sav = [r.savings(self.cfg.tokens_per_step, self.cfg.max_new_tokens)
               for r in requests]
        queue = [r.queue_steps for r in requests]
        ttft_p50, ttft_p99, per_class = latency_stats(list(requests))
        steps = self._steps
        active = sum(m.active_slot_steps for m in host_metrics)
        fired_steps = [(m.consensus_steps, m.consensus_groups)
                       for m in host_metrics if m.consensus_groups]
        n_fired = sum(k for _, k in fired_steps)
        groups = [g for g in self.groups if g.size >= 2]
        tps, dmn = self.cfg.tokens_per_step, self.cfg.max_new_tokens
        g_sav = [g.savings(tps, dmn) for g in groups]
        # speculative acceptance over the request UNION via the ONE
        # shared helper the scheduler's _metrics also calls (counters sum
        # via the requests themselves; percentiles recompute over the
        # union, never averaged across hosts)
        return FleetMetrics(
            **spec_stats(list(requests)),
            n_requests=n, n_slots=self.n_slots, engine_steps=steps,
            active_slot_steps=active, wall_time_s=wall,
            requests_per_s=n / wall, tokens_per_s=total_tokens / wall,
            slot_utilization=(active / max(steps * self.n_slots
                                           * self.n_hosts, 1)),
            mean_step_savings=float(np.mean(sav)) if sav else 0.0,
            mean_queue_steps=float(np.mean(queue)) if queue else 0.0,
            pool_blocks=sum(m.pool_blocks for m in host_metrics),
            peak_blocks_in_use=sum(m.peak_blocks_in_use
                                   for m in host_metrics),
            prefill_skips=sum(m.prefill_skips for m in host_metrics),
            ttft_ms_p50=ttft_p50, ttft_ms_p99=ttft_p99,
            # stalls are per-host step latencies; the fleet tail is the
            # worst host (hosts step concurrently)
            stall_ms_p50=max(m.stall_ms_p50 for m in host_metrics),
            stall_ms_p99=max(m.stall_ms_p99 for m in host_metrics),
            prefill_chunks=sum(m.prefill_chunks for m in host_metrics),
            packed_chunks=sum(m.packed_chunks for m in host_metrics),
            peak_step_tokens=max(m.peak_step_tokens
                                 for m in host_metrics),
            per_class=per_class,
            samples_cancelled=sum(m.samples_cancelled
                                  for m in host_metrics),
            consensus_groups=n_fired,
            consensus_steps=(sum(s * k for s, k in fired_steps)
                             / n_fired if n_fired else 0.0),
            group_savings=sum(m.group_savings for m in host_metrics),
            group_savings_mean=float(np.mean(g_sav)) if g_sav else 0.0,
            cancel_freed_blocks=sum(m.cancel_freed_blocks
                                    for m in host_metrics),
            preemptions=sum(m.preemptions for m in host_metrics),
            restores=sum(m.restores for m in host_metrics),
            spilled_blocks=sum(m.spilled_blocks for m in host_metrics),
            n_hosts=self.n_hosts, routed_affine=self._routed_affine)
