"""Replay model: serve synthetic trajectories through the REAL engines.

The conformal guarantee attaches to the deployed procedure — decode + probe
+ calibrated threshold — so validity has to be tested end-to-end through
``ContinuousServingEngine``/``OrcaScheduler``, not just on offline score
matrices.  Real rollouts are unavailable in this container, but the engines
only consume the model through four functions (prefill / decode_step /
init_decode_state / cfg), so a "model" that replays pre-generated step
embeddings as its hidden states drives the entire serving stack over a
``repro.trajectories.TrajectorySet``:

* each request's prompt encodes its trajectory id (token 0);
* ``decode_step`` looks up phi_{t} for the slot's trajectory at its decode
  position — per-slot ``pos`` vectors index independent trajectories;
* with ``tokens_per_step = 1`` the engine's step-embedding pooling is exact,
  so the served score trajectory equals the offline deployed-procedure
  scores and every stop decision can be checked bit-for-bit.

Used by ``tests/test_validity_regression.py`` (risk <= delta through the
server) and the parity suite's engine-level fixtures.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.serving.request import Request, make_request


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    name: str
    d_model: int
    vocab_size: int = 8
    arch_type: str = "dense"
    prompt_len: int = 1
    tokens_per_step: int = 1


def _draft_coin(traj, step, branch):
    """Deterministic per-(trajectory, step, branch) hash in [0, 1000) —
    the wrong-branch coin for the replay drafters.  Pure integer mixing
    (no RNG state), so the same (traj, step, branch) always lands the
    same way: branch 0 of the tree drafter reproduces the linear drafter
    bit for bit, and reruns are bytewise stable."""
    h = (jnp.asarray(traj, jnp.uint32) * jnp.uint32(2654435761)
         + jnp.asarray(step, jnp.uint32) * jnp.uint32(40503)
         + jnp.asarray(branch, jnp.uint32) * jnp.uint32(2246822519)
         + jnp.uint32(977))
    h = (h ^ (h >> 13)) * jnp.uint32(0x5bd1e995)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(1000)).astype(jnp.int32)


def replay_model(phis: np.ndarray, *, prompt_len: int = 1,
                 tokens_per_step: int = 1,
                 answers: Optional[np.ndarray] = None,
                 draft_wrong_rate: float = 0.0) -> Model:
    """Model whose decode-step hidden states replay ``phis`` (N, T, d).

    The decode state is {"traj": (1, B) int32} — batch axis 1 like every
    real family, so ``inject_prefill``'s per-slot dynamic-update-slice and
    the scheduler's slot machinery work unchanged.

    ``answers`` (N,) makes the greedy decode DETERMINISTICALLY emit each
    trajectory's answer hash (one-hot logits) instead of the default token
    0 — the scheduler's per-boundary answer recording then sees exactly
    the per-sample answer the group consensus should aggregate, driving
    consensus end-to-end without a real model.  Pass the same array to
    ``replay_params``.

    ``draft_wrong_rate`` in [0, 1] corrupts each drafted token with that
    probability (deterministic per (trajectory, step, branch) — see
    ``_draft_coin``), turning the default 100%-acceptance oracle drafter
    into a PARTIAL-acceptance one: the verifier rejects corrupted drafts
    and the accepted prefix/path lengths become genuinely variable, which
    is what tree-vs-linear speculative comparisons need.  Branch b of the
    tree drafter flips its coins independently of branch b', so a wrong
    branch-0 guess can still be rescued by a sibling — the tree's whole
    advantage.  0.0 (default) keeps the historical always-right drafter.
    """
    phis = np.asarray(phis, np.float32)
    n, t, d = phis.shape
    vocab = max(8, n)
    if answers is not None:
        vocab = max(vocab, int(np.asarray(answers).max()) + 1)
    if not 0.0 <= draft_wrong_rate <= 1.0:
        raise ValueError(f"draft_wrong_rate={draft_wrong_rate} is outside "
                         "[0, 1]")
    wrong_mil = int(round(float(draft_wrong_rate) * 1000))
    cfg = ReplayConfig(name=f"replay-{n}x{t}", d_model=d,
                       vocab_size=vocab, prompt_len=prompt_len,
                       tokens_per_step=tokens_per_step)

    def prefill(cfg, params, batch, cache_len):
        tokens = batch["tokens"]
        traj = tokens[:, 0].astype(jnp.int32)
        state = {"traj": traj[None, :]}                   # (L=1, B)
        hidden = jnp.zeros((tokens.shape[0], tokens.shape[1], cfg.d_model),
                           jnp.float32)
        return state, hidden[:, -1], hidden

    def decode_step(cfg, params, token, state, pos, window=None,
                    write_mask=None):
        traj = state["traj"][0]                           # (B,)
        bank = params["phis"]                             # (N, T, d)
        step = (jnp.asarray(pos, jnp.int32) - cfg.prompt_len) \
            // cfg.tokens_per_step
        idx = jnp.clip(step, 0, bank.shape[1] - 1)
        hidden = bank[traj, idx]                          # (B, d)
        if "answers" in params:        # trace-time: baked into the step
            logits = jax.nn.one_hot(params["answers"][traj],
                                    cfg.vocab_size, dtype=jnp.float32)
        else:
            logits = jnp.zeros((hidden.shape[0], cfg.vocab_size),
                               jnp.float32)
        return logits, hidden, state

    def prefill_chunk(cfg, params, tokens, state, rows, pos_start, chunk_len,
                      block_rows=None):
        # the whole "prompt" is the trajectory id in token 0: only the chunk
        # containing position 0 carries information, later chunks are no-ops
        traj = state["traj"]
        rows = jnp.asarray(rows, jnp.int32)
        first = (jnp.asarray(pos_start, jnp.int32) == 0) \
            & (jnp.asarray(chunk_len, jnp.int32) > 0)
        new = jnp.where(first, tokens[:, 0].astype(jnp.int32), traj[0, rows])
        return {"traj": traj.at[0, rows].set(new)}

    def prefill_packed(cfg, params, tokens, state, seg, slots, starts,
                       lengths, block_rows=None):
        # packed chunk: each SEGMENT whose slice starts at position 0
        # carries its request's trajectory id in its first chunk token
        traj = state["traj"]
        lengths = jnp.asarray(lengths, jnp.int32)
        starts = jnp.asarray(starts, jnp.int32)
        slots = jnp.asarray(slots, jnp.int32)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(lengths)[:-1]])
        ids = tokens[jnp.clip(offsets, 0, tokens.shape[0] - 1)] \
            .astype(jnp.int32)                                # (R,)
        first = (starts == 0) & (lengths > 0)
        new = jnp.where(first, ids, traj[0, slots])
        # unused segments are routed out of range (scatter drops them) so
        # their placeholder slot 0 can't race a real segment's write
        slot_w = jnp.where(lengths > 0, slots, traj.shape[1])
        return {"traj": traj.at[0, slot_w].set(new, mode="drop")}

    def init_decode_state(batch: int, cache_len: int, abstract: bool = False):
        return {"traj": jnp.zeros((1, batch), jnp.int32)}

    def _true_token(params, traj):
        if "answers" in params:
            return params["answers"][traj].astype(jnp.int32)
        return jnp.zeros_like(traj)

    def draft(cfg, params, state, token, pos, k):
        # the replay model drafts from its own trajectory: every decode
        # step emits answers[traj] (or token 0 without answers), so
        # proposing exactly that token makes the verifier accept the
        # whole block — the 100%-acceptance upper bound the speculative
        # throughput benchmark measures against.  With a wrong rate, each
        # drafted token is independently corrupted (branch-0 coins, so
        # this chain == the tree drafter's branch 0).
        traj = state["traj"][0]                           # (B,)
        tok = _true_token(params, traj)
        drafts = jnp.broadcast_to(tok[:, None], (traj.shape[0], k - 1))
        if wrong_mil:
            dd = jnp.arange(1, k, dtype=jnp.int32)[None, :]
            step = (jnp.asarray(pos, jnp.int32)[:, None] + dd
                    - cfg.prompt_len) // cfg.tokens_per_step
            bad = _draft_coin(traj[:, None], step, 0) < wrong_mil
            drafts = jnp.where(bad, (drafts + 1) % cfg.vocab_size, drafts)
        return drafts

    def draft_tree(cfg, params, state, token, pos, width, depth):
        # W independent draft chains from the root: branch b repeats the
        # trajectory's true continuation, each token corrupted with the
        # wrong rate under its OWN (traj, step, branch) coin — so the
        # best accepted root-to-leaf path is the max over W partially-
        # right chains, the controllable partial-acceptance workload the
        # tree tests and benchmark exercise.  branch 0 == ``draft``.
        traj = state["traj"][0]                           # (B,)
        b = traj.shape[0]
        tok = _true_token(params, traj)
        drafts = jnp.broadcast_to(tok[:, None, None], (b, width, depth))
        if wrong_mil:
            dd = jnp.arange(1, depth + 1, dtype=jnp.int32)[None, None, :]
            br = jnp.arange(width, dtype=jnp.int32)[None, :, None]
            step = (jnp.asarray(pos, jnp.int32)[:, None, None] + dd
                    - cfg.prompt_len) // cfg.tokens_per_step
            bad = _draft_coin(traj[:, None, None], step, br) < wrong_mil
            drafts = jnp.where(bad, (drafts + 1 + br) % cfg.vocab_size,
                               drafts)
        return drafts

    def verify_packed(cfg, params, tokens, state, seg, slots, starts,
                      lengths, block_rows=None):
        # packed verify: position c is token j of segment seg[c] at
        # sequence position starts[seg[c]] + j — the SAME bank lookup
        # (and one-hot logits) as decode_step at that position, so the
        # spec path is bit-identical to one-token replay decode
        traj_all = state["traj"][0]                       # (B,)
        seg = jnp.asarray(seg, jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        starts = jnp.asarray(starts, jnp.int32)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(lengths)[:-1]])
        row = jnp.asarray(slots, jnp.int32)[seg]          # batch row per pos
        traj = traj_all[row]                              # (C,)
        c = tokens.shape[0]
        j = jnp.arange(c, dtype=jnp.int32) - offsets[seg]
        step = (starts[seg] + j - cfg.prompt_len) // cfg.tokens_per_step
        bank = params["phis"]                             # (N, T, d)
        hidden = bank[traj, jnp.clip(step, 0, bank.shape[1] - 1)]
        if "answers" in params:
            logits = jax.nn.one_hot(params["answers"][traj],
                                    cfg.vocab_size, dtype=jnp.float32)
        else:
            logits = jnp.zeros((c, cfg.vocab_size), jnp.float32)
        return logits, hidden, state

    def verify_tree(cfg, params, tokens, state, seg, slots, starts,
                    lengths, depths, ancestors, block_rows=None):
        # tree verify: node c sits at sequence position starts[seg[c]] +
        # depths[c] — the SAME bank lookup as decode_step at that
        # position, whatever branch the node came from (the replay
        # trajectory is a function of position only), so the accepted
        # path's hidden states are bit-identical to one-token decode.
        # No KV cache -> nothing to defer; ks/vs are None and commit_kv
        # below is the no-op.
        traj_all = state["traj"][0]                       # (B,)
        seg = jnp.asarray(seg, jnp.int32)
        starts = jnp.asarray(starts, jnp.int32)
        depths = jnp.asarray(depths, jnp.int32)
        row = jnp.asarray(slots, jnp.int32)[seg]          # batch row per pos
        traj = traj_all[row]                              # (C,)
        c = tokens.shape[0]
        step = (starts[seg] + depths - cfg.prompt_len) // cfg.tokens_per_step
        bank = params["phis"]                             # (N, T, d)
        hidden = bank[traj, jnp.clip(step, 0, bank.shape[1] - 1)]
        if "answers" in params:
            logits = jax.nn.one_hot(params["answers"][traj],
                                    cfg.vocab_size, dtype=jnp.float32)
        else:
            logits = jnp.zeros((c, cfg.vocab_size), jnp.float32)
        return logits, hidden, None, None

    def commit_kv(cfg, state, ks, vs, slots, seg, positions, valid,
                  block_rows=None):
        return state                 # replay carries no KV cache

    return Model(cfg=cfg, decls=None, forward=None, prefill=prefill,
                 decode_step=decode_step, init_decode_state=init_decode_state,
                 decode_geometry=lambda shape: (shape.seq_len, None),
                 prefill_chunk=prefill_chunk,
                 prefill_packed=prefill_packed,
                 verify_packed=verify_packed,
                 draft=draft,
                 verify_tree=verify_tree,
                 commit_kv=commit_kv,
                 draft_tree=draft_tree)


def replay_params(phis: np.ndarray, answers: Optional[np.ndarray] = None):
    """The replay model's "weights": the trajectory bank itself (+ the
    optional per-trajectory answer hashes the decode emits)."""
    params = {"phis": jnp.asarray(phis, jnp.float32)}
    if answers is not None:
        params["answers"] = jnp.asarray(answers, jnp.int32)
    return params


def replay_requests(lengths: Sequence[int], *, prompt_len: int = 1,
                    tokens_per_step: int = 1) -> List[Request]:
    """One Request per trajectory: prompt = its id, budget = its length."""
    return [make_request(np.full((prompt_len,), i, np.int64),
                         max_new_tokens=int(T) * tokens_per_step)
            for i, T in enumerate(lengths)]


def served_stop_times(requests: Sequence[Request],
                      lengths: Sequence[int]) -> np.ndarray:
    """Map served outcomes onto offline ``stopping.stop_times`` semantics:
    0-based stop index, or T_i when the budget ran out (never charged).

    The engine convention is ``stop_step >= 0`` means "stopped" (see
    ``engine.ServeResult`` / ``ContinuousServingEngine``) — comparing
    against 0 here would misread a step-0 stop as budget-exhausted.  The
    0-based index floors at 0: the offline grid cannot stop before its
    first score, so a (convention-level) step-0 stop maps to index 0."""
    return np.array([max(r.stop_step - 1, 0) if r.stop_step >= 0 else int(T)
                     for r, T in zip(requests, lengths)], np.int64)


@dataclasses.dataclass(frozen=True)
class GroupFleet:
    """A replay fleet of self-consistency groups (``make_group_fleet``)."""
    model: Model
    params: dict
    requests: List[Request]
    members: np.ndarray      # (G, group_size) trajectory index per sample
    truth: np.ndarray        # (G,) reference answer hash (-1: none solves)
    answer_hash: np.ndarray  # (N,) per-trajectory answer the decode emits


def make_group_fleet(ts, group_size: int, *, seed: int = 0,
                     tokens_per_step: int = 1) -> GroupFleet:
    """Self-consistency groups over a TrajectorySet, served by replay.

    A seeded permutation is cut into consecutive groups of ``group_size``
    trajectories (distinct phis per sample — the probe diversity real
    sampling would give; remainder dropped).  Each sample's answer hash is
    derived from its trajectory id: a SOLVED sample (``correct.any()``)
    votes its group's id, an unsolved one votes a unique wrong hash
    (``n_groups + trajectory_id``) — so the group truth is the group id
    when any sample solves, else -1 (unmatchable).  The replay decode
    emits these hashes as its greedy tokens (one-hot logits), so the
    scheduler's per-boundary answer recording drives the consensus stop
    end-to-end without a real model.
    """
    if group_size < 1:
        raise ValueError(f"group_size must be >= 1, got {group_size}")
    n = len(ts)
    order = np.random.RandomState(seed).permutation(n)
    n_groups = n // group_size
    members = order[:n_groups * group_size].reshape(n_groups, group_size)
    answer_hash = np.arange(n, dtype=np.int64) + n_groups  # default: wrong
    truth = np.full((n_groups,), -1, np.int64)
    requests: List[Request] = []
    for g in range(n_groups):
        for j, i in enumerate(members[g]):
            if bool(ts.correct[i].any()):
                answer_hash[i] = g
                truth[g] = g
            requests.append(make_request(
                np.full((1,), i, np.int64),
                max_new_tokens=int(ts.lengths[i]) * tokens_per_step,
                group_id=int(g), sample_idx=j))
    model = replay_model(ts.phis, tokens_per_step=tokens_per_step,
                         answers=answer_hash)
    params = replay_params(ts.phis, answers=answer_hash)
    return GroupFleet(model=model, params=params, requests=requests,
                      members=members, truth=truth,
                      answer_hash=answer_hash)


def serve_replay(phis: np.ndarray, theta, *, n_hosts: int = 1,
                 cfg=None, placement=None, lengths=None,
                 priorities: Optional[Sequence[int]] = None,
                 parallel_hosts: bool = True, **cfg_overrides):
    """Drive a replay-model fleet end-to-end and return
    ``(requests, metrics, server)``.

    The fleet-serving harness: builds the replay model/params from the
    ``phis`` bank, a ``ServeConfig`` (``cfg`` or ``tokens_per_step=1`` +
    ``cfg_overrides``), and either a single ``OrcaScheduler``
    (``n_hosts=1``) or a ``FleetRouter`` — then runs one whole session.
    Because both servers speak the same submit/step/drain protocol and
    replay trajectories are deterministic, the returned stop decisions
    are directly comparable across host counts: byte-identical stops is
    the fleet invariant this harness exists to check (and benchmark).
    """
    from repro.core.probe import ProbeConfig
    from repro.serving.config import ServeConfig
    from repro.serving.router import FleetRouter
    from repro.serving.scheduler import OrcaScheduler

    phis = np.asarray(phis)
    if cfg is None:
        cfg = ServeConfig(tokens_per_step=1,
                          max_new_tokens=int(phis.shape[1]),
                          **cfg_overrides)
    elif cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    pc = ProbeConfig(d_phi=int(phis.shape[2]), smooth_window=4)
    model, params = replay_model(phis), replay_params(phis)
    if n_hosts == 1:
        server = OrcaScheduler(model, params, pc, theta, cfg)
    else:
        server = FleetRouter(model, params, pc, theta, cfg,
                             n_hosts=n_hosts, placement=placement,
                             parallel_hosts=parallel_hosts)
    if lengths is None:
        lengths = [int(phis.shape[1])] * int(phis.shape[0])
    requests = replay_requests(lengths)
    if priorities is not None:
        for r, p in zip(requests, priorities):
            r.priority = int(p)
    requests, metrics = server.run(requests)
    return requests, metrics, server
