"""Paged KV-cache block pool: fixed-size token blocks, refcounts, prefix
sharing.

The dense serving cache gives every batch slot one max-length lane, so an
ORCA-stopped request frees nothing until its slot is re-admitted and a short
request pays for the longest.  Here HBM is a pool of fixed-size token
blocks; each request holds a *block table* (virtual position ``j`` lives in
physical block ``table[j // block_size]`` at offset ``j % block_size``):

* admission RESERVES ``ceil((prompt_len + max_new) / block_size)`` blocks up
  front — if the pool can't cover it the request stays WAITING (the
  scheduler backpressures instead of over-admitting);
* an ORCA stop returns the request's blocks to the pool immediately — the
  paper's calibrated early stop is literally a memory-reclaim event;
* self-consistency decoding (N samples of one prompt) stores the shared
  prompt prefix ONCE: full prompt blocks are refcounted and shared
  copy-on-write-style (sharers never write them — decode tokens land in
  private tail blocks), keyed by a hash of the prompt tokens.

Physical block 0 is reserved as the NULL block: freed slots point their
block tables at it, so a parked slot's no-op cache write can never corrupt
a block that was reallocated to a live request.

Host-side and synchronous by design — the scheduler owns it; device state
(the page buffers themselves) lives in ``ContinuousServingEngine``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence

import numpy as np

NULL_BLOCK = 0


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Blocks covering ``n_tokens`` virtual positions."""
    return max((int(n_tokens) + block_size - 1) // block_size, 1)


def pad_row(block_row: Sequence[int], max_blocks: int) -> np.ndarray:
    """A request's physical block ids padded to a fixed-width table row
    with NULL blocks — the one layout the engine's device ops (admit,
    finish_prefill, preempt/restore page gather+scatter) all share."""
    row = np.full((int(max_blocks),), NULL_BLOCK, np.int32)
    row[:len(block_row)] = np.asarray(block_row, np.int32)
    return row


def prompt_key(tokens) -> str:
    """Prefix-sharing key: content hash of the prompt token ids."""
    arr = np.asarray(tokens, np.int64).ravel()
    return hashlib.sha1(arr.tobytes()).hexdigest()


@dataclasses.dataclass(frozen=True)
class PrefixEntry:
    """A resident prompt: its shared full blocks + (optional) partial tail.

    ``full_blocks`` cover positions [0, len(full_blocks) * block_size) and
    are shared read-only (new sharers bump their refcount and never write
    them).  ``tail_block`` — when the prompt length is not a block multiple
    — holds the prompt tail; a sharer COPIES it into a private block at
    admission (the donor keeps writing its own decode tokens there, which
    are stale-but-unreadable in the copy, same argument as dense slot
    reuse).  The entry holds no refcounts itself: the pool invalidates it
    the moment any referenced block's refcount hits zero.
    """
    full_blocks: tuple
    tail_block: Optional[int]
    prompt_len: int


class BlockPool:
    """Refcounted fixed-size block allocator with a prefix registry.

    Invariants (asserted, and fuzzed in ``tests/test_paged_kv.py``):
    * a block is either free (refcount 0, on the free list) or owned
      (refcount >= 1, off the free list) — never both, never double-handed;
    * ``allocate`` is all-or-nothing: a request that doesn't fit leaves the
      pool untouched;
    * block 0 (NULL) is never allocated and never freed.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least the null block + one usable"
        assert block_size >= 1
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self._refcount = np.zeros(num_blocks, np.int64)
        self._refcount[NULL_BLOCK] = 1          # permanently owned
        self._free: List[int] = list(range(num_blocks - 1, NULL_BLOCK, -1))
        self._prefixes: Dict[str, PrefixEntry] = {}

    # ------------------------------------------------------------------
    @property
    def num_usable(self) -> int:
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - self.num_free

    def refcount(self, block: int) -> int:
        return int(self._refcount[block])

    # ------------------------------------------------------------------
    def allocate(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` fresh blocks (refcount 0 -> 1), or None if the pool
        can't cover the whole reservation (all-or-nothing)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self._refcount[b] == 0, f"block {b} double-allocated"
            self._refcount[b] = 1
        return out

    def share(self, blocks: Sequence[int]) -> List[int]:
        """Bump refcounts on already-owned blocks (prefix hit)."""
        for b in blocks:
            assert b != NULL_BLOCK and self._refcount[b] >= 1, \
                f"sharing a dead block {b}"
            self._refcount[b] += 1
        return list(blocks)

    def free(self, blocks: Sequence[int]) -> int:
        """Drop one reference per block; blocks reaching refcount 0 return
        to the free list and invalidate any prefix entry that names them.
        Returns how many blocks DIED (refcount hit 0) — shared prefix pages
        survive their sharers, so the count is what actually returned to
        the pool (the observable group-cancellation reclaims)."""
        died = []
        for b in blocks:
            assert b != NULL_BLOCK, "freeing the null block"
            assert self._refcount[b] >= 1, f"double-free of block {b}"
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                self._free.append(b)
                died.append(b)
        if died:
            dead = set(died)
            self._prefixes = {
                k: e for k, e in self._prefixes.items()
                if not (dead.intersection(e.full_blocks)
                        or e.tail_block in dead)}
        return len(died)

    # ------------------------------------------------------------------
    # prefix sharing
    def register_prefix(self, key: str, full_blocks: Sequence[int],
                        tail_block: Optional[int], prompt_len: int) -> None:
        """Record a freshly-prefilled prompt so later admissions of the same
        prompt can share its blocks instead of recomputing prefill."""
        if not full_blocks and tail_block is None:
            return
        self._prefixes[key] = PrefixEntry(tuple(full_blocks), tail_block,
                                          int(prompt_len))

    def lookup_prefix(self, key: str) -> Optional[PrefixEntry]:
        """A live PrefixEntry for ``key``, or None.  Entries referencing any
        freed block were already invalidated by ``free``."""
        return self._prefixes.get(key)

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Internal consistency (used by tests after every fuzz op)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate block on free list"
        assert NULL_BLOCK not in free
        for b in range(1, self.num_blocks):
            if b in free:
                assert self._refcount[b] == 0, (b, self._refcount[b])
            else:
                assert self._refcount[b] >= 1, (b, self._refcount[b])
        for e in self._prefixes.values():
            for b in e.full_blocks + ((e.tail_block,)
                                      if e.tail_block is not None else ()):
                assert self._refcount[b] >= 1, f"prefix names dead block {b}"
