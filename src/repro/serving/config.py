"""ServeConfig: ONE frozen config object for the whole serving stack.

Seven PRs of feature growth left ``api.engine`` with ~15 keyword arguments
mirrored by ~20 CLI flags in ``launch/serve.py`` — and the fleet router
needs to construct N per-host schedulers from one description.  This
module is the consolidation: every serving knob (the fused-step parameters
the engines always took, plus the scheduler's paged / chunked / policy /
group / preemption / fleet knobs) lives on a single frozen dataclass, and
every cross-field validation that used to be inlined in ``api.engine``
runs in ``__post_init__`` — so an invalid configuration fails at
construction, once, with an error that names the fix, no matter which
entry point built it.

Construction paths:

* ``ServeConfig(lam=0.7, n_slots=8, paged=True)`` — direct;
* ``ServeConfig.from_args(args)`` — from an ``argparse`` namespace using
  the ``launch/serve.py`` flag names (``--slots`` -> ``n_slots``,
  ``--no-pack`` -> ``pack_chunks=False``, 0 -> None for the optional
  ints), so the CLI can stop re-plumbing flags into keywords by hand;
* ``dataclasses.replace(cfg, ...)`` — per-host / per-run overrides
  (validation re-runs automatically).

``api.engine(model, params, calibrator, config=cfg)`` and
``api.fleet(..., n_hosts=N, config=cfg)`` consume it; the legacy
``api.engine(**kwargs)`` path survives as a shim that builds a ServeConfig
and emits ``DeprecationWarning``.  ``OrcaScheduler`` and ``FleetRouter``
resolve every constructor keyword they still accept against this config
(explicit keyword wins), so old call sites keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

_VALID_PROBE_IMPLS = ("kernel", "ref")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every serving knob, validated once at construction.

    The first five fields are the fused serve-step parameters the engines
    have taken since PR 1; the rest are the scheduler/fleet knobs that
    used to be ``api.engine`` keyword arguments.  The step-level engines
    (``ServingEngine``, ``ContinuousServingEngine``) read only the step
    fields, so any ServeConfig drives any layer of the stack.
    """

    # -- fused serve step (PR-1 fields; every engine reads these) -------
    tokens_per_step: int = 16     # tokens per "reasoning step" for phi_t
    max_new_tokens: int = 256
    lam: float = 0.9              # LTT-calibrated threshold lambda*
    burn_in: int = 10             # steps before stopping is allowed
    greedy: bool = True

    # -- fleet shape ----------------------------------------------------
    n_slots: int = 4              # batch slots per scheduler (PER HOST for
    #                               a FleetRouter fleet)
    cache_len: Optional[int] = None   # None -> sized from the requests

    # -- paged KV (PR 3) ------------------------------------------------
    paged: bool = False
    block_size: int = 16
    num_blocks: Optional[int] = None  # pool pages; None -> dense-equivalent.
    #                               For a fleet this is the TOTAL page
    #                               budget, split across hosts.
    prefix_sharing: bool = True

    # -- chunked / packed prefill (PRs 4-5) ------------------------------
    chunk_tokens: Optional[int] = None
    token_budget: Optional[int] = None
    pack_chunks: bool = True
    pack_max: int = 4

    # -- speculative decode (PR 9) ----------------------------------------
    spec_tokens: Optional[int] = None  # draft-verify block length per slot
    #                               (current token + spec_tokens-1 drafts
    #                               scored in one fused pass); None/0
    #                               keeps one-token decode

    # -- tree speculative decode (PR 10) ----------------------------------
    spec_tree: Optional[str] = None   # "W.D" — W draft chains of depth D
    #                               per slot, verified as one token TREE
    #                               under the ancestor mask (1 + W*D nodes
    #                               claim the step budget); exclusive with
    #                               spec_tokens ("1.D" == linear D+1).
    #                               A (W, D) tuple is accepted and
    #                               normalized to the string form.
    draft_cache_size: int = 4096  # shared n-gram draft cache entries
    #                               (fleet-wide, LRU); 0 disables the
    #                               cache (model self-draft only)

    # -- scheduling policy (PR 5) ----------------------------------------
    policy: Any = None            # "fifo"/"priority"/"edf"/"ttft", a
    #                               SchedulingPolicy instance, or None

    # -- self-consistency groups (PR 6) ----------------------------------
    group_size: int = 1
    consensus: Any = None         # GroupCalibrator | float in (0,1] | None
    consensus_delta: Optional[float] = None

    # -- preemption (PR 7) -----------------------------------------------
    preemption: bool = True

    # -- probe dispatch ---------------------------------------------------
    probe_impl: str = "kernel"    # "kernel" (Pallas) or "ref" (jnp oracle)
    interpret: Optional[bool] = None

    # -- fleet serving (PR 8) ---------------------------------------------
    n_hosts: int = 1
    placement: Any = None         # "pressure"/"roundrobin", a
    #                               PlacementPolicy instance, or None

    def __post_init__(self) -> None:
        # normalize the optional ints the CLI passes as 0-for-disabled
        for field in ("cache_len", "num_blocks", "chunk_tokens",
                      "token_budget", "spec_tokens"):
            val = getattr(self, field)
            if val is not None:
                val = int(val)
                object.__setattr__(self, field, val if val > 0 else None)
        # normalize spec_tree: (W, D) tuples and "" both reach here from
        # programmatic / CLI paths; canonical form is the "W.D" string
        if self.spec_tree is not None:
            tree = self.spec_tree
            if isinstance(tree, (tuple, list)):
                tree = ".".join(str(int(x)) for x in tree)
            tree = str(tree).strip()
            object.__setattr__(self, "spec_tree", tree or None)
        self.validate()

    # ------------------------------------------------------------------
    def tree_shape(self) -> Optional[tuple]:
        """Parsed ``spec_tree``: (width, depth) ints, or None."""
        if self.spec_tree is None:
            return None
        parts = str(self.spec_tree).split(".")
        if len(parts) != 2:
            raise ValueError(
                f"spec_tree={self.spec_tree!r} is not 'W.D': the tree "
                "shape is width.depth (e.g. '3.4' = 3 draft chains of "
                "depth 4); fix by passing two dot-separated positive ints")
        try:
            w, d = int(parts[0]), int(parts[1])
        except ValueError:
            raise ValueError(
                f"spec_tree={self.spec_tree!r} is not 'W.D': both parts "
                "must be ints (e.g. '3.4'); fix by passing two "
                "dot-separated positive ints") from None
        return (w, d)

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Cross-field validation — every error names the fix.

        This is the single home for the checks that used to be inlined in
        ``api.engine`` (group/consensus/gang) plus the fleet checks; the
        scheduler re-validates the model-dependent ones (e.g. the
        ``token_budget`` floor depends on whether the model family
        supports chunked prefill) at construction.
        """
        if isinstance(self.tokens_per_step, bool) or self.tokens_per_step < 1:
            raise ValueError(
                f"tokens_per_step={self.tokens_per_step!r} must be an int "
                ">= 1: the probe pools this many tokens per reasoning "
                "step; fix by passing a positive count")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens={self.max_new_tokens} must be >= 1: a "
                "request with no decode budget can never emit a token; "
                "fix by passing a positive budget")
        if self.block_size < 1:
            raise ValueError(
                f"block_size={self.block_size} must be >= 1: a KV page "
                "holds this many token positions; fix by passing a "
                "positive page size (16 is the default)")
        if self.pack_max < 1:
            raise ValueError(
                f"pack_max={self.pack_max} must be >= 1: a packed chunk "
                "carries at least its own request; fix by passing a "
                "positive count (1 behaves like pack_chunks=False)")
        if self.probe_impl not in _VALID_PROBE_IMPLS:
            raise ValueError(
                f"unknown probe_impl {self.probe_impl!r} (expected one of "
                f"{_VALID_PROBE_IMPLS}); fix by passing 'kernel' (the "
                "Pallas serving probe) or 'ref' (the jnp parity oracle)")
        if self.spec_tokens is not None:
            if self.spec_tokens < 2:
                raise ValueError(
                    f"spec_tokens={self.spec_tokens} must be >= 2: a "
                    "verify block is the current token plus at least one "
                    "draft; fix by passing spec_tokens >= 2 (or None/0 "
                    "for one-token decode)")
            if self.chunk_tokens is not None \
                    and self.spec_tokens >= self.chunk_tokens:
                raise ValueError(
                    f"spec_tokens={self.spec_tokens} >= chunk_tokens="
                    f"{self.chunk_tokens}: a verify block must fit inside "
                    "the fused step's fixed chunk capacity alongside the "
                    "prefill share; fix by lowering spec_tokens to < "
                    f"{self.chunk_tokens} or raising chunk_tokens")
            if self.token_budget is not None \
                    and self.spec_tokens > self.token_budget:
                raise ValueError(
                    f"spec_tokens={self.spec_tokens} > token_budget="
                    f"{self.token_budget}: one slot's verify block alone "
                    "would blow the per-step token budget; fix by "
                    "lowering spec_tokens to <= "
                    f"{self.token_budget} or raising token_budget")
        if self.spec_tree is not None:
            if self.spec_tokens is not None:
                raise ValueError(
                    f"spec_tree={self.spec_tree!r} with spec_tokens="
                    f"{self.spec_tokens} is ambiguous — they are two "
                    "shapes of the same verify segment; fix by passing "
                    "ONE of them (spec_tree='1.k-1' is the linear "
                    "spec_tokens=k path)")
            w, d = self.tree_shape()
            if w < 1 or d < 1:
                raise ValueError(
                    f"spec_tree={self.spec_tree!r} needs width >= 1 and "
                    "depth >= 1: a tree is at least one draft chain of "
                    "one token; fix by passing e.g. '2.3' (or None for "
                    "one-token decode)")
            nodes = 1 + w * d
            if self.chunk_tokens is not None and nodes >= self.chunk_tokens:
                raise ValueError(
                    f"spec_tree={self.spec_tree!r} needs {nodes} nodes "
                    f">= chunk_tokens={self.chunk_tokens}: the verify "
                    "tree must fit inside the fused step's fixed chunk "
                    "capacity alongside the prefill share; fix by "
                    "shrinking the tree or raising chunk_tokens to > "
                    f"{nodes}")
            if self.token_budget is not None and nodes > self.token_budget:
                raise ValueError(
                    f"spec_tree={self.spec_tree!r} needs {nodes} nodes "
                    f"> token_budget={self.token_budget}: one slot's "
                    "verify tree alone would blow the per-step token "
                    "budget; fix by shrinking the tree or raising "
                    f"token_budget to >= {nodes}")
        if int(self.draft_cache_size) < 0:
            raise ValueError(
                f"draft_cache_size={self.draft_cache_size} must be >= 0: "
                "the shared draft cache's entry bound (0 disables it); "
                "fix by passing a non-negative count")
        if isinstance(self.n_hosts, bool) or int(self.n_hosts) < 1:
            raise ValueError(
                f"n_hosts={self.n_hosts!r} must be an int >= 1: the number "
                "of simulated hosts the FleetRouter shards the scheduler "
                "across; fix by passing a positive count (1 serves "
                "single-host)")
        # group/consensus checks — moved verbatim from api.engine so every
        # construction path fails identically
        group_size = self.group_size
        if isinstance(group_size, bool) or int(group_size) < 1:
            raise ValueError(
                f"group_size={group_size!r} must be an int >= 1: the number "
                "of self-consistency samples per prompt; fix by passing a "
                "positive count (1 disables grouping)")
        object.__setattr__(self, "group_size", int(group_size))
        if self.group_size > self.n_slots:
            raise ValueError(
                f"group_size={self.group_size} > n_slots={self.n_slots}: "
                "gang admission needs every sample of a group resident at "
                "once; fix by raising n_slots to >= "
                f"{self.group_size} or lowering group_size")
        if self.consensus is not None and self.group_size == 1:
            raise ValueError(
                "consensus= with group_size=1 can never fire (every request "
                "is its own singleton and a lone sample never votes); fix by "
                "passing group_size >= 2 (or grouping requests yourself via "
                "repro.serving.make_group) or dropping consensus=")
        if isinstance(self.consensus, bool):
            raise ValueError(
                f"consensus={self.consensus!r} is not a threshold: pass a "
                "float agreement threshold in (0, 1], a calibrated "
                "GroupCalibrator, or None to disable the consensus stop")
        if isinstance(self.consensus, (int, float)) \
                and not 0.0 < float(self.consensus) <= 1.0:
            raise ValueError(
                f"consensus={float(self.consensus)} is outside (0, 1]: the "
                "threshold is the weight share the top answer must reach; "
                "fix by passing a float in (0, 1] or a calibrated "
                "GroupCalibrator")
        if self.consensus_delta is not None:
            from repro.core.calibrator import GroupCalibrator
            if self.consensus is None:
                raise ValueError(
                    "consensus_delta= without consensus= does nothing; fix "
                    "by passing consensus=<GroupCalibrator calibrated at "
                    f"delta={self.consensus_delta}> (or a float threshold, "
                    "and dropping consensus_delta)")
            if isinstance(self.consensus, GroupCalibrator) \
                    and self.consensus.delta is not None \
                    and not math.isclose(float(self.consensus.delta),
                                         float(self.consensus_delta)):
                raise ValueError(
                    f"consensus_delta={self.consensus_delta} does not match "
                    "the GroupCalibrator's calibrated delta="
                    f"{self.consensus.delta}; fix by re-running "
                    "GroupCalibrator.calibrate(..., delta="
                    f"{self.consensus_delta}) or passing consensus_delta="
                    f"{self.consensus.delta}")

    # ------------------------------------------------------------------
    # CLI flag names (launch/serve.py) -> field, with the transforms the
    # driver used to hand-roll.  ``from_args`` reads only the attributes
    # the namespace actually has, so partial namespaces work.
    _ARG_FIELDS = (
        ("tokens_per_step", "tokens_per_step", None),
        ("max_new_tokens", "max_new_tokens", None),
        ("burn_in", "burn_in", None),
        ("slots", "n_slots", None),
        ("paged", "paged", None),
        ("block_size", "block_size", None),
        ("num_blocks", "num_blocks", None),      # 0 -> None in __post_init__
        ("chunk_tokens", "chunk_tokens", None),
        ("token_budget", "token_budget", None),
        ("spec_tokens", "spec_tokens", None),    # 0 -> None in __post_init__
        ("spec_tree", "spec_tree", None),        # "" -> None in __post_init__
        ("draft_cache", "draft_cache_size", None),
        ("policy", "policy", None),
        ("no_pack", "pack_chunks", "invert"),
        ("pack_max", "pack_max", None),
        ("group_size", "group_size", None),
        ("no_preempt", "preemption", "invert"),
        ("hosts", "n_hosts", None),
    )

    @classmethod
    def from_args(cls, args, **overrides) -> "ServeConfig":
        """Build a ServeConfig from an ``argparse`` namespace using the
        ``launch/serve.py`` flag names.

        Only attributes present on the namespace are read (so any driver
        with a subset of the flags works); ``overrides`` win over the
        namespace — the place for runtime-computed values like the
        LTT-calibrated ``lam`` or a calibrated ``consensus`` object.
        Optional ints passed as 0 (the CLI's "disabled") normalize to
        None.
        """
        fields: dict = {}
        for arg_name, field, transform in cls._ARG_FIELDS:
            if not hasattr(args, arg_name):
                continue
            val = getattr(args, arg_name)
            if transform == "invert":
                val = not val
            fields[field] = val
        fields.update(overrides)
        return cls(**fields)
