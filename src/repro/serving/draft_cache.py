"""Fleet-wide shared n-gram draft cache: the serving layer's drafter.

Speculative decode is only as good as its drafts, and a dense model's
degenerate self-draft (repeat the last committed token) proposes the right
continuation essentially never.  This module is the cheap fix the tree
verify step makes worthwhile: a bounded, host-side table keyed by the last
``ngram`` committed tokens of a request, whose values are the
continuations the VERIFIER itself accepted — promoted on every commit, so
the cache learns exactly the n-gram statistics of the traffic it serves.
Requests across slots, schedulers and simulated hosts share ONE instance
(the ``FleetRouter`` passes the same object to every per-host scheduler,
the way the prefix registry is shared), so a continuation accepted on
host 0 drafts for host 3's next request for free.

The cache feeds the engine as traced data: the scheduler calls
``lookup`` per RUNNING slot, stacks the (width, depth) proposals plus a
per-slot hit mask, and hands both to ``ContinuousServingEngine.step`` —
slots that miss fall back to the model family's own drafter inside the
SAME executable, so hit/miss mixes never recompile.  Branching is native:
the top ``width`` remembered continuations become the tree's chains, and
short entries are extended by CHAINED lookups (the accepted continuation
of its own tail), giving depth without ever storing long values.

Purely deterministic (insertion-ordered dict, no hashing randomness
observable): two runs over the same traffic draft identically, which the
byte-identity tests rely on.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np


class DraftCache:
    """Bounded LRU of n-gram -> accepted-continuation lists.

    ``capacity`` bounds the number of KEYS (eviction is LRU on key
    touches); each key retains at most ``fanout`` continuations, most
    recently accepted first; ``store_len`` caps the stored continuation
    length (depth beyond it comes from chained lookups).  ``hits`` /
    ``misses`` count top-level lookups only (chained extension lookups
    are internal and free).
    """

    def __init__(self, capacity: int = 4096, ngram: int = 3,
                 fanout: int = 8, store_len: int = 8):
        assert capacity >= 0 and ngram >= 1 and fanout >= 1 \
            and store_len >= 1, (capacity, ngram, fanout, store_len)
        self.capacity = int(capacity)
        self.ngram = int(ngram)
        self.fanout = int(fanout)
        self.store_len = int(store_len)
        self._table: "OrderedDict[tuple, List[tuple]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._table)

    # ------------------------------------------------------------------
    def _key(self, context: Sequence[int]) -> Optional[tuple]:
        """The last ``ngram`` tokens as a key; shorter prefixes key on
        what exists (tuples of different lengths never collide)."""
        ctx = tuple(int(t) for t in context[-self.ngram:])
        return ctx if ctx else None

    def _peek(self, context: Sequence[int]) -> List[tuple]:
        """Continuations for ``context`` WITHOUT touching LRU order or
        counters — the chained-extension read."""
        key = self._key(context)
        return list(self._table.get(key, ())) if key is not None else []

    def _chain(self, context: List[int], depth: int) -> List[int]:
        """Extend ``context`` to ``depth`` more tokens by repeatedly
        looking up its own tail's best continuation."""
        out: List[int] = []
        while len(out) < depth:
            conts = self._peek(context + out)
            if not conts:
                break
            nxt = list(conts[0])[:depth - len(out)]
            if not nxt:
                break
            out.extend(nxt)
        return out

    # ------------------------------------------------------------------
    def lookup(self, context: Sequence[int], width: int,
               depth: int) -> Tuple[np.ndarray, bool]:
        """Draft a (width, depth) token tree for a request whose last
        committed tokens are ``context``.

        Returns ``(drafts, hit)``: on a hit, branch b follows the b-th
        most-recently-accepted continuation of the context's n-gram (the
        available ones cycled across branches), each chain extended to
        full depth by chained lookups and padded with its own last token;
        on a miss, zeros and False — the engine substitutes the model
        family's drafter for that slot.
        """
        drafts = np.zeros((int(width), int(depth)), np.int32)
        key = self._key(context)
        conts = self._table.get(key) if key is not None else None
        if not conts:
            self.misses += 1
            return drafts, False
        self.hits += 1
        self._table.move_to_end(key)
        ctx = [int(t) for t in context]
        for b in range(int(width)):
            chain = list(conts[b % len(conts)])[:depth]
            if len(chain) < depth:
                chain.extend(self._chain(ctx + chain, depth - len(chain)))
            while len(chain) < depth:          # pad: repeat the tail token
                chain.append(chain[-1] if chain else ctx[-1])
            drafts[b] = np.asarray(chain[:depth], np.int32)
        return drafts, True

    # ------------------------------------------------------------------
    def observe(self, context: Sequence[int],
                accepted: Sequence[int]) -> None:
        """Promote a verifier-accepted continuation: every n-gram of the
        sliding window over ``context + accepted`` that precedes at least
        one accepted token maps to the accepted tokens that follow it
        (front of its MRU list, trimmed to ``fanout``)."""
        if self.capacity == 0 or not len(accepted):
            return
        toks = [int(t) for t in context] + [int(t) for t in accepted]
        n_ctx = len(toks) - len(accepted)
        lo = max(0, n_ctx - self.ngram)
        for i in range(lo, len(toks) - 1):
            key = tuple(toks[max(0, i + 1 - self.ngram):i + 1])
            cont = tuple(toks[i + 1:i + 1 + self.store_len])
            if not key or not cont:
                continue
            lst = self._table.get(key)
            if lst is None:
                lst = []
                self._table[key] = lst
            # exact or prefix-superseded duplicates collapse to the front
            lst[:] = [c for c in lst if c != cont and cont[:len(c)] != c]
            lst.insert(0, cont)
            del lst[self.fanout:]
            self._table.move_to_end(key)
        while len(self._table) > self.capacity:
            self._table.popitem(last=False)

    # ------------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return float(self.hits) / total if total else 0.0
