"""OrcaScheduler: continuous batching with ORCA-stop eviction.

The scheduler owns the request lifecycle (queues, admission, eviction,
metrics); ``ContinuousServingEngine`` owns device state.  The loop follows
the vLLM/sarathi shape — waiting requests are admitted into fixed-shape
batch slots; the moment the calibrated ORCA threshold test stops a
sequence, its slot is released and refilled from the queue on the very
next step — but the *capacity mechanism* here is the paper's calibrated
early stopping: every early stop returns its remaining step budget to the
fleet, so calibrated savings become measurable throughput.

Eviction is score-invariant by construction: each slot's probe fast
weights are reset to (W0, b0) at admission and the per-slot KV cache only
ever exposes the slot's own request, so a request's score trajectory and
stop step are identical to a fresh single-request run (tested in
``tests/test_serving_scheduler.py``; the throughput benchmark asserts it
against the static-batch baseline).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.probe import ProbeConfig
from repro.models.registry import Model
from repro.serving.engine import (ContinuousServingEngine, ServeConfig,
                                  SlotStepView)
from repro.serving.request import FleetMetrics, Request, RequestState


class OrcaScheduler:
    """Admit waiting requests into slots; evict on ORCA stop or budget."""

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig, *, n_slots: int = 4,
                 cache_len: Optional[int] = None,
                 probe_impl: str = "kernel",
                 interpret: Optional[bool] = None):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        # probe_impl/interpret route the fused step's probe math: "kernel"
        # (the Pallas serving_probe_step) or "ref" (jnp parity oracle)
        self.probe_impl = probe_impl
        self.interpret = interpret
        self._engine: Optional[ContinuousServingEngine] = None

    # ------------------------------------------------------------------
    def _ensure_engine(self, requests: Sequence[Request]) -> ContinuousServingEngine:
        cache_len = self.cache_len
        if cache_len is None:
            max_prompt = max((r.prompt_len for r in requests), default=0)
            if self.model.cfg.arch_type == "audio":
                max_prompt = 0  # decoder cache holds generated tokens only
            max_new = max([r.max_new_tokens or self.cfg.max_new_tokens
                           for r in requests] + [self.cfg.max_new_tokens])
            cache_len = max_prompt + max_new
        if self._engine is None or self._engine.cache_len < cache_len:
            self._engine = ContinuousServingEngine(
                self.model, self.params, self.pc, self.theta, self.cfg,
                self.n_slots, cache_len, probe_impl=self.probe_impl,
                interpret=self.interpret)
        return self._engine

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request]
            ) -> Tuple[List[Request], FleetMetrics]:
        """Drive every request to STOPPED/FINISHED; return them + metrics."""
        eng = self._ensure_engine(requests)
        waiting = deque(requests)
        running: Dict[int, Request] = {}          # slot -> request
        free = list(range(self.n_slots))
        steps = active_slot_steps = 0
        total_tokens = 0
        t0 = time.perf_counter()

        while waiting or running:
            # admission: refill every free slot before the next fused step
            while free and waiting:
                req = waiting.popleft()
                slot = free.pop()
                req.state = RequestState.PREFILL
                eng.admit(slot, req.inputs, req.prompt_len)
                req.slot, req.admitted_step = slot, steps
                req.state = RequestState.RUNNING
                running[slot] = req

            view = eng.step()
            steps += 1
            active_slot_steps += len(running)

            for slot, req in list(running.items()):
                req.tokens.append(int(view.tokens[slot]))
                total_tokens += 1
                n_scores = int(view.n_scores[slot])
                if n_scores > len(req.scores):
                    req.scores.append(float(view.smoothed[slot]))
                max_new = req.max_new_tokens or self.cfg.max_new_tokens
                if bool(view.stopped[slot]):
                    # ORCA stop: evict NOW — the slot is free next step
                    req.stop_step = int(view.stop_step[slot])
                    req.steps_run = req.stop_step
                    self._complete(req, RequestState.STOPPED, steps)
                elif len(req.tokens) >= max_new:
                    req.stop_step = -1
                    req.steps_run = n_scores
                    self._complete(req, RequestState.FINISHED, steps)
                else:
                    continue
                eng.release(slot)
                free.append(slot)
                del running[slot]

        wall = max(time.perf_counter() - t0, 1e-9)
        return list(requests), self._metrics(requests, steps,
                                             active_slot_steps,
                                             total_tokens, wall)

    # ------------------------------------------------------------------
    @staticmethod
    def _complete(req: Request, state: RequestState, step: int) -> None:
        req.state = state
        req.completed_step = step

    def _metrics(self, requests: Sequence[Request], steps: int,
                 active_slot_steps: int, total_tokens: int,
                 wall: float) -> FleetMetrics:
        n = len(requests)
        sav = [r.savings(self.cfg.tokens_per_step, self.cfg.max_new_tokens)
               for r in requests]
        queue = [r.queue_steps for r in requests]
        return FleetMetrics(
            n_requests=n, n_slots=self.n_slots, engine_steps=steps,
            active_slot_steps=active_slot_steps, wall_time_s=wall,
            requests_per_s=n / wall, tokens_per_s=total_tokens / wall,
            slot_utilization=(active_slot_steps
                              / max(steps * self.n_slots, 1)),
            mean_step_savings=float(np.mean(sav)) if sav else 0.0,
            mean_queue_steps=float(np.mean(queue)) if queue else 0.0)
