"""OrcaScheduler: continuous batching with ORCA-stop eviction.

The scheduler owns the request lifecycle (queues, admission, eviction,
metrics) and — in paged mode — the KV block pool; ``ContinuousServingEngine``
owns device state.  The loop follows the vLLM/sarathi shape — waiting
requests are admitted into fixed-shape batch slots; the moment the
calibrated ORCA threshold test stops a sequence, its slot is released and
refilled from the queue on the very next step — but the *capacity
mechanism* here is the paper's calibrated early stopping: every early stop
returns its remaining step budget to the fleet, so calibrated savings
become measurable throughput.

Paged admission (``paged=True``) replaces "find a free slot lane" with
"reserve blocks from the pool":

* a request needs ``ceil((prompt_len + max_new) / block_size)`` pages; if
  the pool can't cover the reservation the request stays WAITING — the
  scheduler backpressures instead of over-admitting (FIFO order is kept:
  head-of-line blocking, no starvation);
* a prompt that is already resident (self-consistency decoding: N samples
  of one prompt) is admitted as a block-table copy + refcount bump on the
  shared full prompt pages — prefill is skipped entirely; only the partial
  tail page (if any) is copied into a private page before this request
  writes its own decode tokens there;
* an ORCA stop releases the request's pages back to the pool immediately —
  the paper's early stop is literally a memory-reclaim event.

Chunked prefill (``chunk_tokens=N``) turns prefill itself into schedulable
work (the Sarathi shape): admission still reserves pages all-or-nothing,
but instead of a batch-1 full-prompt prefill stalling the whole fleet, the
request becomes a resident PREFILL row and the *batch composer* packs each
engine iteration up to ``token_budget`` tokens — every resident decode
token first, the remainder filled FIFO with up to one ``chunk_tokens``-wide
chunk of the head PREFILL request's prompt.  No decode slot ever skips a
step while prefill work is pending, TTFT and per-step stall tails collapse
(FleetMetrics p50/p99), and ONE compiled step executable serves every
prompt length.  Chunking changes *when* prefill work happens, never *what*
the probe sees: stop decisions are identical to admission-time prefill
(asserted in ``tests/test_chunked_prefill.py`` and the throughput gate).

Eviction is score-invariant by construction: each slot's probe fast
weights are reset to (W0, b0) at admission and the per-slot KV view (dense
lane or block table) only ever exposes the slot's own request, so a
request's score trajectory and stop step are identical to a fresh
single-request run (tested in ``tests/test_serving_scheduler.py`` and
``tests/test_paged_kv.py``; the throughput benchmark asserts it against
the static-batch baseline).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.probe import ProbeConfig
from repro.models.registry import Model
from repro.serving.engine import (ChunkWork, ContinuousServingEngine,
                                  ServeConfig, chunk_supported, prefix_len)
from repro.serving.kv_pool import BlockPool, blocks_needed, prompt_key
from repro.serving.request import FleetMetrics, Request, RequestState


@dataclasses.dataclass(frozen=True)
class _AdmitPlan:
    """One request's reserved pages + how to fill them."""
    row: List[int]               # physical pages, virtual order
    n_shared: int                # leading pages refcount-shared with a donor
    skip_prefill: bool
    copy_tail: Optional[Tuple[int, int]]   # (donor tail page, private copy)
    register_key: Optional[str]  # register as prefix donor after admission


class OrcaScheduler:
    """Admit waiting requests into slots; evict on ORCA stop or budget."""

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig, *, n_slots: int = 4,
                 cache_len: Optional[int] = None,
                 probe_impl: str = "kernel",
                 interpret: Optional[bool] = None,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefix_sharing: bool = True,
                 chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        # probe_impl/interpret route the fused step's probe math: "kernel"
        # (the Pallas serving_probe_step) or "ref" (jnp parity oracle)
        self.probe_impl = probe_impl
        self.interpret = interpret
        self.paged = bool(paged)
        self.block_size = int(block_size)
        self.num_blocks = num_blocks
        self.prefix_sharing = bool(prefix_sharing)
        # chunked prefill (Sarathi-style): prefill stops being an admission
        # event and becomes schedulable work — each engine iteration packs
        # every resident decode token plus up to ``chunk_tokens`` of the
        # FIFO-head PREFILL request's prompt, bounded by ``token_budget``
        # tokens per step (default: n_slots decode tokens + one full chunk)
        self.chunk_tokens = int(chunk_tokens) if chunk_tokens else None
        if self.chunk_tokens is not None and not model.supports_chunked:
            self.chunk_tokens = None      # family without prefill_chunk
        self.token_budget = (int(token_budget) if token_budget
                             else n_slots + (self.chunk_tokens or 0))
        assert self.token_budget >= 1
        self.pool: Optional[BlockPool] = None
        self._engine: Optional[ContinuousServingEngine] = None

    # ------------------------------------------------------------------
    def _ensure_engine(self, requests: Sequence[Request]) -> ContinuousServingEngine:
        cache_len = self.cache_len
        if cache_len is None:
            mcfg = self.model.cfg
            max_prompt = max((prefix_len(mcfg, r.inputs, r.prompt_len)
                              for r in requests), default=0)
            if mcfg.arch_type == "audio":
                max_prompt = 0  # decoder cache holds generated tokens only
            max_new = max([r.max_new_tokens or self.cfg.max_new_tokens
                           for r in requests] + [self.cfg.max_new_tokens])
            cache_len = max_prompt + max_new
        if self.paged:
            # device-paged only for families with a page layout; every
            # family still gets pool-based admission control (backpressure)
            device_paged = self.model.supports_paged
            # the virtual capacity must also cover the largest prefill
            # prefix (vlm patches / meta tokens can exceed prompt+max_new);
            # _request_blocks reserves pages for it, so the engine's block
            # tables and the default pool have to be sized for it too
            cache_len = max([cache_len]
                            + [self._request_tokens(r) for r in requests])
            max_blocks = blocks_needed(cache_len, self.block_size)
            num_blocks = int(self.num_blocks or
                             (self.n_slots * max_blocks + 1))
            if self.pool is None or self.pool.num_blocks != num_blocks:
                self.pool = BlockPool(num_blocks, self.block_size)
            if self._engine is None or self._engine.cache_len < cache_len:
                self._engine = ContinuousServingEngine(
                    self.model, self.params, self.pc, self.theta, self.cfg,
                    self.n_slots, cache_len, probe_impl=self.probe_impl,
                    interpret=self.interpret, paged=device_paged,
                    block_size=self.block_size, num_blocks=num_blocks,
                    chunk_tokens=self.chunk_tokens)
        elif self._engine is None or self._engine.cache_len < cache_len:
            self._engine = ContinuousServingEngine(
                self.model, self.params, self.pc, self.theta, self.cfg,
                self.n_slots, cache_len, probe_impl=self.probe_impl,
                interpret=self.interpret, chunk_tokens=self.chunk_tokens)
        return self._engine

    # ------------------------------------------------------------------
    # paged admission: reserve pages (all-or-nothing) + prefix sharing
    def _request_tokens(self, req: Request) -> int:
        """Virtual positions this request needs: the full prefill prefix
        (vlm patches / meta tokens included — decode resumes after it)
        plus the decode budget."""
        mcfg = self.model.cfg
        max_new = req.max_new_tokens or self.cfg.max_new_tokens
        if mcfg.arch_type == "audio":
            return max_new
        return prefix_len(mcfg, req.inputs, req.prompt_len) + max_new

    def _request_blocks(self, req: Request) -> int:
        return blocks_needed(self._request_tokens(req), self.block_size)

    def _sharing_key(self, req: Request) -> Optional[str]:
        if not (self.prefix_sharing and self._engine is not None
                and self._engine.paged):
            return None
        if set(req.inputs) != {"tokens"}:      # multimodal prefixes differ
            return None
        # sharing assumes virtual positions [0, prompt_len) hold exactly
        # the prompt's K/V — a hidden prefix (meta tokens) breaks that
        if prefix_len(self.model.cfg, req.inputs, req.prompt_len) \
                != req.prompt_len:
            return None
        return prompt_key(np.asarray(req.inputs["tokens"]))

    def _reserve(self, req: Request) -> Optional[_AdmitPlan]:
        """Try to reserve this request's pages; None = pool exhausted (the
        request stays WAITING — backpressure, not over-admission)."""
        pool = self.pool
        n_total = self._request_blocks(req)
        key = self._sharing_key(req)
        entry = pool.lookup_prefix(key) if key else None
        if entry is not None and entry.prompt_len == req.prompt_len \
                and len(entry.full_blocks) <= n_total:
            private = pool.allocate(n_total - len(entry.full_blocks))
            if private is None:
                return None
            shared = pool.share(entry.full_blocks)
            copy_tail = None
            if entry.tail_block is not None and private:
                copy_tail = (entry.tail_block, private[0])
            return _AdmitPlan(row=shared + private, n_shared=len(shared),
                              skip_prefill=True, copy_tail=copy_tail,
                              register_key=None)
        row = pool.allocate(n_total)
        if row is None:
            return None
        return _AdmitPlan(row=row, n_shared=0, skip_prefill=False,
                          copy_tail=None, register_key=key)

    def _register_donor(self, req: Request, plan: _AdmitPlan) -> None:
        if plan.register_key is None:
            return
        bs = self.block_size
        n_full = req.prompt_len // bs
        tail = plan.row[n_full] if (req.prompt_len % bs
                                    and n_full < len(plan.row)) else None
        self.pool.register_prefix(plan.register_key, plan.row[:n_full],
                                  tail, req.prompt_len)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request]
            ) -> Tuple[List[Request], FleetMetrics]:
        """Drive every request to STOPPED/FINISHED; return them + metrics."""
        eng = self._ensure_engine(requests)
        chunked = bool(eng.chunk_tokens)
        waiting = deque(requests)
        running: Dict[int, Request] = {}          # slot -> request
        prefilling: Dict[int, Request] = {}       # slot -> mid-prefill req
        plans: Dict[int, _AdmitPlan] = {}         # deferred donor registry
        free = list(range(self.n_slots))
        steps = active_slot_steps = 0
        total_tokens = n_chunks = 0
        peak_blocks = prefill_skips = 0
        stalls: List[float] = []
        t0 = time.perf_counter()

        while waiting or running or prefilling:
            t_iter = time.perf_counter()
            # admission: refill free slots before the next fused step; in
            # paged mode a request that doesn't fit the pool keeps FIFO
            # order and WAITS for an eviction to return pages.  Pages are
            # still reserved ALL-OR-NOTHING here, whether the prompt then
            # prefills in one admission shot or in scheduled chunks.
            while free and waiting:
                req = waiting[0]
                plan = None
                if self.paged:
                    plan = self._reserve(req)
                    if plan is None:
                        if not (running or prefilling):
                            raise RuntimeError(
                                f"request {req.req_id} needs "
                                f"{self._request_blocks(req)} pages but the "
                                f"pool holds {self.pool.num_usable}; nothing "
                                "left to evict")
                        break
                waiting.popleft()
                slot = free.pop()
                req.slot, req.admitted_step = slot, steps
                req.state = RequestState.PREFILL
                skip = plan.skip_prefill if plan is not None else False
                if plan is not None:
                    req.block_ids = list(plan.row)
                    req.n_shared_blocks = plan.n_shared
                    req.prefill_skipped = skip
                    prefill_skips += int(skip)
                    peak_blocks = max(peak_blocks, self.pool.blocks_in_use)
                if chunked and not skip \
                        and chunk_supported(self.model, req.inputs):
                    # prefill is schedulable work, not an admission event:
                    # the slot becomes a resident PREFILL row and the
                    # prompt rides the unified step in token-budget chunks
                    eng.begin_prefill(slot)
                    req.prefill_progress = 0
                    prefilling[slot] = req
                    if plan is not None:
                        # donor registration deferred: the pages only hold
                        # the prompt K/V once the last chunk lands
                        plans[slot] = plan
                else:
                    if plan is not None and eng.paged:
                        eng.admit(slot, req.inputs, req.prompt_len,
                                  block_row=plan.row,
                                  skip_prefill=skip,
                                  copy_tail=plan.copy_tail)
                    else:
                        # family without a page layout / non-text prompt:
                        # the pool still admission-controls, the device
                        # cache stays dense and prefill stays one shot
                        eng.admit(slot, req.inputs, req.prompt_len)
                    if plan is not None:
                        self._register_donor(req, plan)
                    req.state = RequestState.RUNNING
                    running[slot] = req

            # batch composer: every resident decode token rides this step;
            # what's left of the token budget goes to the FIFO-head
            # PREFILL request, capped at one chunk
            chunk = None
            if prefilling:
                room = min(self.token_budget - len(running),
                           eng.chunk_tokens)
                if room > 0:
                    slot, req = next(iter(prefilling.items()))
                    n = min(room, req.prompt_len - req.prefill_progress)
                    chunk = ChunkWork(
                        slot=slot,
                        tokens=np.asarray(req.inputs["tokens"][0]),
                        start=req.prefill_progress, length=int(n),
                        row=(np.asarray(req.block_ids, np.int32)
                             if eng.paged and req.block_ids else None))
                    n_chunks += 1

            view = eng.step(chunk) if chunked else eng.step()
            steps += 1
            active_slot_steps += len(running)
            now = time.perf_counter()

            for slot, req in list(running.items()):
                if req.first_token_step < 0:
                    req.first_token_step = steps
                    req.ttft_s = now - t0
                req.tokens.append(int(view.tokens[slot]))
                total_tokens += 1
                n_scores = int(view.n_scores[slot])
                if n_scores > len(req.scores):
                    req.scores.append(float(view.smoothed[slot]))
                max_new = req.max_new_tokens or self.cfg.max_new_tokens
                if bool(view.stopped[slot]):
                    # ORCA stop: evict NOW — the slot is free next step
                    req.stop_step = int(view.stop_step[slot])
                    req.steps_run = req.stop_step
                    self._complete(req, RequestState.STOPPED, steps)
                elif len(req.tokens) >= max_new:
                    req.stop_step = -1
                    req.steps_run = n_scores
                    self._complete(req, RequestState.FINISHED, steps)
                else:
                    continue
                eng.release(slot)
                if self.paged and req.block_ids:
                    # the stop IS the reclaim: pages return to the pool now
                    self.pool.free(req.block_ids)
                free.append(slot)
                del running[slot]

            # prefill bookkeeping AFTER token collection: a request whose
            # last chunk just landed decodes its first token NEXT step
            if chunk is not None:
                req = prefilling[chunk.slot]
                req.prefill_progress += chunk.length
                if req.prefill_progress >= req.prompt_len:
                    eng.finish_prefill(
                        chunk.slot, req.inputs, req.prompt_len,
                        block_row=(req.block_ids
                                   if eng.paged and req.block_ids else None))
                    del prefilling[chunk.slot]
                    plan = plans.pop(chunk.slot, None)
                    if plan is not None:
                        self._register_donor(req, plan)
                    req.state = RequestState.RUNNING
                    running[chunk.slot] = req
            stalls.append((time.perf_counter() - t_iter) * 1e3)

        wall = max(time.perf_counter() - t0, 1e-9)
        return list(requests), self._metrics(requests, steps,
                                             active_slot_steps,
                                             total_tokens, wall,
                                             peak_blocks, prefill_skips,
                                             stalls, n_chunks)

    # ------------------------------------------------------------------
    @staticmethod
    def _complete(req: Request, state: RequestState, step: int) -> None:
        req.state = state
        req.completed_step = step

    def _metrics(self, requests: Sequence[Request], steps: int,
                 active_slot_steps: int, total_tokens: int,
                 wall: float, peak_blocks: int = 0,
                 prefill_skips: int = 0,
                 stalls: Optional[Sequence[float]] = None,
                 prefill_chunks: int = 0) -> FleetMetrics:
        n = len(requests)
        sav = [r.savings(self.cfg.tokens_per_step, self.cfg.max_new_tokens)
               for r in requests]
        queue = [r.queue_steps for r in requests]
        ttft = np.array([r.ttft_s for r in requests if r.ttft_s >= 0]) * 1e3
        st = np.asarray(stalls if stalls else [0.0])
        return FleetMetrics(
            n_requests=n, n_slots=self.n_slots, engine_steps=steps,
            active_slot_steps=active_slot_steps, wall_time_s=wall,
            requests_per_s=n / wall, tokens_per_s=total_tokens / wall,
            slot_utilization=(active_slot_steps
                              / max(steps * self.n_slots, 1)),
            mean_step_savings=float(np.mean(sav)) if sav else 0.0,
            mean_queue_steps=float(np.mean(queue)) if queue else 0.0,
            pool_blocks=self.pool.num_usable if self.pool else 0,
            peak_blocks_in_use=peak_blocks, prefill_skips=prefill_skips,
            ttft_ms_p50=float(np.percentile(ttft, 50)) if ttft.size else 0.0,
            ttft_ms_p99=float(np.percentile(ttft, 99)) if ttft.size else 0.0,
            stall_ms_p50=float(np.percentile(st, 50)),
            stall_ms_p99=float(np.percentile(st, 99)),
            prefill_chunks=prefill_chunks)
