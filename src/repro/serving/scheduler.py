"""OrcaScheduler: continuous batching with ORCA-stop eviction.

The scheduler owns the request lifecycle (queues, admission, eviction,
metrics) and — in paged mode — the KV block pool; ``ContinuousServingEngine``
owns device state.  The loop follows the vLLM/sarathi shape — waiting
requests are admitted into fixed-shape batch slots; the moment the
calibrated ORCA threshold test stops a sequence, its slot is released and
refilled from the queue on the very next step — but the *capacity
mechanism* here is the paper's calibrated early stopping: every early stop
returns its remaining step budget to the fleet, so calibrated savings
become measurable throughput.

Paged admission (``paged=True``) replaces "find a free slot lane" with
"reserve blocks from the pool":

* a request needs ``ceil((prompt_len + max_new) / block_size)`` pages; if
  the pool can't cover the reservation the request stays WAITING — the
  scheduler backpressures instead of over-admitting (FIFO order is kept:
  head-of-line blocking, no starvation);
* a prompt that is already resident (self-consistency decoding: N samples
  of one prompt) is admitted as a block-table copy + refcount bump on the
  shared full prompt pages — prefill is skipped entirely; only the partial
  tail page (if any) is copied into a private page before this request
  writes its own decode tokens there;
* an ORCA stop releases the request's pages back to the pool immediately —
  the paper's early stop is literally a memory-reclaim event.

Chunked prefill (``chunk_tokens=N``) turns prefill itself into schedulable
work (the Sarathi shape): admission still reserves pages all-or-nothing,
but instead of a batch-1 full-prompt prefill stalling the whole fleet, the
request becomes a resident PREFILL row and the *batch composer* packs each
engine iteration up to ``token_budget`` tokens — every resident decode
token first, the remainder filled with a PACKED prefill chunk: up to
``chunk_tokens`` prompt tokens drawn from up to ``pack_max`` mid-prefill
residents (the tail of one prompt piggybacked with the head of the next,
block-diagonally isolated on device), so short prompt tails no longer
leave budget on the table.  ``pack_chunks=False`` restores the PR-4
one-request-per-chunk composer through the SAME step executable.  No
decode slot ever skips a step while prefill work is pending, TTFT and
per-step stall tails collapse (FleetMetrics p50/p99), and ONE compiled
step executable serves every prompt length and packing shape.

*Who* gets admitted and *how much* prefill rides each step is delegated to
a pluggable ``SchedulingPolicy`` (``repro.serving.policy``): FIFO (the
default, PR-4's composer), priority classes with anti-starvation aging
(``Request.priority``), and a TTFT-aware policy that widens the prefill
share when decode slots are idle — plus the probe-aware chunk-sizing knob
that shrinks prefill when residents approach a probe boundary.  Scheduling
changes *when* work happens, never *what* the probe sees: stop decisions
are identical to admission-time prefill across every policy and packing
mode (asserted in ``tests/test_chunked_prefill.py``,
``tests/test_packed_chunks.py`` and the throughput gate).

Preemption (``preemption=True``, the overload-safe default) makes the
scheduler reclaim residents, not just wait for them: when capacity (slots
or pages) fails for a unit that is strictly MORE urgent than some
resident, the policy's ``select_victim`` picks strictly-lower-priority
victims (newest first by default) and ``engine.preempt`` spills each one's
KV pages AND probe fast-weight state to host RAM (``engine.Spill``).
Spilled requests sit in a SWAPPED queue that re-admits BEFORE the waiting
queue (``engine.restore`` is a block-table rewrite + page copy-back with
the probe buffers reloaded bit-for-bit), and a swapped head that cannot
yet restore barriers its own class so it is never overtaken.  A
feasibility simulation runs before any spill (no victim is evicted unless
the unit will actually fit) and victims are only ever strictly lower
priority, so the preemption relation is a DAG — no livelock.  Because the
spill/restore round-trip is byte-exact, stop decisions are invariant
under ANY preemption schedule (asserted in ``tests/test_preemption.py``
and ``tests/test_validity_regression.py``).

Eviction is score-invariant by construction: each slot's probe fast
weights are reset to (W0, b0) at admission and the per-slot KV view (dense
lane or block table) only ever exposes the slot's own request, so a
request's score trajectory and stop step are identical to a fresh
single-request run (tested in ``tests/test_serving_scheduler.py`` and
``tests/test_paged_kv.py``; the throughput benchmark asserts it against
the static-batch baseline).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.calibrator import GroupCalibrator
from repro.core.probe import ProbeConfig
from repro.models.registry import Model
from repro.serving.engine import (ChunkSeg, ChunkWork,
                                  ContinuousServingEngine, ServeConfig,
                                  Spill, chunk_supported, prefix_len)
from repro.serving.groups import RequestGroup, group_requests
from repro.serving.kv_pool import BlockPool, blocks_needed, prompt_key
from repro.serving.policy import (ComposeView, HostPressure,
                                  SchedulingPolicy, make_policy)
from repro.serving.draft_cache import DraftCache
from repro.serving.request import (FleetMetrics, Request, RequestState,
                                   latency_stats, spec_stats)


@dataclasses.dataclass(frozen=True)
class _AdmitPlan:
    """One request's reserved pages + how to fill them."""
    row: List[int]               # physical pages, virtual order
    n_shared: int                # leading pages refcount-shared with a donor
    skip_prefill: bool
    copy_tail: Optional[Tuple[int, int]]   # (donor tail page, private copy)
    register_key: Optional[str]  # register as prefix donor after admission


# constructor-keyword sentinel: distinguishes "not passed" (resolve from
# the unified ServeConfig) from an explicit None (which is meaningful for
# cache_len / num_blocks / chunk_tokens / token_budget / policy / ...)
_UNSET: object = object()


def _pick(explicit, cfg_value):
    """An explicitly passed constructor keyword wins; else the value comes
    from the unified ``ServeConfig`` (the api_redesign contract that lets
    one config object describe a whole scheduler — or N fleet hosts)."""
    return cfg_value if explicit is _UNSET else explicit


class OrcaScheduler:
    """Admit waiting requests into slots; evict on ORCA stop or budget.

    Driving protocol (shared with ``FleetRouter`` — ``serve_requests``
    duck-types over either):

    * ``submit(requests)`` — enqueue gang-admission units (opens a fresh
      serving session if none is active; callable repeatedly);
    * ``step()`` — ONE scheduler iteration (admission -> batch composition
      -> fused engine step -> collection/eviction -> consensus); returns
      False once the fleet is idle;
    * ``drain()`` — step until idle, close the session, return
      ``(requests, FleetMetrics)``;
    * ``run(requests)`` — the classic one-shot facade: submit + drain.

    ``prepare(requests)`` sizes the engine/pool for a population WITHOUT
    enqueueing it, and ``pressure()`` exports the ``HostPressure`` summary
    the fleet router's placement policy consumes.

    Every constructor keyword resolves against the unified ``ServeConfig``
    (explicit keyword wins), so ``OrcaScheduler(model, params, pc, theta,
    cfg)`` alone builds the fully-described scheduler.
    """

    def __init__(self, model: Model, params, pc: ProbeConfig, theta,
                 cfg: ServeConfig, *, n_slots: int = _UNSET,
                 cache_len: Optional[int] = _UNSET,
                 probe_impl: str = _UNSET,
                 interpret: Optional[bool] = _UNSET,
                 paged: bool = _UNSET, block_size: int = _UNSET,
                 num_blocks: Optional[int] = _UNSET,
                 prefix_sharing: bool = _UNSET,
                 chunk_tokens: Optional[int] = _UNSET,
                 token_budget: Optional[int] = _UNSET,
                 policy: Union[str, SchedulingPolicy, None] = _UNSET,
                 pack_chunks: bool = _UNSET,
                 pack_max: int = _UNSET,
                 consensus: Union[GroupCalibrator, float, None] = _UNSET,
                 preemption: bool = _UNSET,
                 spec_tokens: Optional[int] = _UNSET,
                 spec_tree: Optional[str] = _UNSET,
                 draft_cache: Optional[DraftCache] = None):
        self.model, self.params, self.pc, self.theta, self.cfg = \
            model, params, pc, theta, cfg
        n_slots = int(_pick(n_slots, cfg.n_slots))
        chunk_tokens = _pick(chunk_tokens, cfg.chunk_tokens)
        token_budget = _pick(token_budget, cfg.token_budget)
        spec_tokens = _pick(spec_tokens, cfg.spec_tokens)
        policy = _pick(policy, cfg.policy)
        consensus = _pick(consensus, cfg.consensus)
        pack_chunks = _pick(pack_chunks, cfg.pack_chunks)
        pack_max = _pick(pack_max, cfg.pack_max)
        preemption = _pick(preemption, cfg.preemption)
        self.n_slots = n_slots
        self.cache_len = _pick(cache_len, cfg.cache_len)
        # probe_impl/interpret route the fused step's probe math: "kernel"
        # (the Pallas serving_probe_step) or "ref" (jnp parity oracle)
        self.probe_impl = _pick(probe_impl, cfg.probe_impl)
        self.interpret = _pick(interpret, cfg.interpret)
        self.paged = bool(_pick(paged, cfg.paged))
        self.block_size = int(_pick(block_size, cfg.block_size))
        self.num_blocks = _pick(num_blocks, cfg.num_blocks)
        self.prefix_sharing = bool(_pick(prefix_sharing, cfg.prefix_sharing))
        # chunked prefill (Sarathi-style): prefill stops being an admission
        # event and becomes schedulable work — each engine iteration packs
        # every resident decode token plus up to ``chunk_tokens`` prompt
        # tokens of mid-prefill residents (PACKED across up to ``pack_max``
        # requests unless ``pack_chunks=False``), bounded by
        # ``token_budget`` tokens per step (default: n_slots decode tokens
        # + one full chunk)
        self.chunk_tokens = int(chunk_tokens) if chunk_tokens else None
        if self.chunk_tokens is not None and not model.supports_chunked:
            warnings.warn(
                f"chunk_tokens={self.chunk_tokens} ignored: model family "
                f"{model.cfg.name!r} has no chunked/packed prefill — "
                "serving falls back to admission-time (one-shot) prefill; "
                "drop chunk_tokens or use a family with "
                "supports_chunked=True to silence this",
                RuntimeWarning, stacklevel=2)
            self.chunk_tokens = None      # family without prefill_chunk
        # speculative draft-verify decode: each RUNNING slot may ride the
        # packed verify chunk with up to spec_tokens tokens per step, drawn
        # from the same token budget the prefill share composes against
        self.spec_tokens = int(spec_tokens) if spec_tokens else None
        if self.spec_tokens is not None and not model.supports_spec:
            warnings.warn(
                f"spec_tokens={self.spec_tokens} ignored: model family "
                f"{model.cfg.name!r} has no draft/verify speculative "
                "decode — serving falls back to one-token decode; drop "
                "spec_tokens or use a family with supports_spec=True to "
                "silence this",
                RuntimeWarning, stacklevel=2)
            self.spec_tokens = None       # family without verify_packed
        # tree speculative decode: "W.D" generalizes the verify block to
        # 1 + W*D candidate NODES per slot; self.spec_tokens becomes that
        # node count so every budget computation below stays unit-correct
        spec_tree = _pick(spec_tree, cfg.spec_tree)
        self.spec_tree: Optional[Tuple[int, int]] = None
        if spec_tree:
            if self.spec_tokens is not None:
                raise ValueError(
                    f"spec_tree={spec_tree!r} with spec_tokens="
                    f"{self.spec_tokens} is ambiguous — they are two "
                    "shapes of the same verify segment; fix by passing "
                    "ONE of them")
            if isinstance(spec_tree, (tuple, list)):
                shape = (int(spec_tree[0]), int(spec_tree[1]))
            else:
                shape = dataclasses.replace(
                    cfg, spec_tree=str(spec_tree),
                    spec_tokens=None).tree_shape()
            if not model.supports_tree:
                warnings.warn(
                    f"spec_tree={spec_tree!r} ignored: model family "
                    f"{model.cfg.name!r} has no tree speculative decode "
                    "— serving falls back to one-token decode; drop "
                    "spec_tree or use a family with supports_tree=True "
                    "to silence this",
                    RuntimeWarning, stacklevel=2)
            else:
                self.spec_tree = shape
                self.spec_tokens = 1 + shape[0] * shape[1]
        # shared n-gram draft cache: the serving layer's drafter for
        # families whose own draft is the degenerate repeat-last-token
        # self-draft.  The FleetRouter passes ONE instance to every host
        # (prefix-registry style); explicit injection also lets tests /
        # callers front ANY family with it
        if draft_cache is not None:
            self.draft_cache: Optional[DraftCache] = draft_cache
        elif (self.spec_tokens is not None and model.self_draft
                and cfg.draft_cache_size):
            self.draft_cache = DraftCache(capacity=cfg.draft_cache_size)
        else:
            self.draft_cache = None
        if self.spec_tokens is None:
            self.draft_cache = None       # nothing to draft for
        if token_budget is not None:
            token_budget = int(token_budget)
            floor = n_slots if (self.chunk_tokens is not None
                                or self.spec_tokens is not None) else 1
            if token_budget < floor:
                raise ValueError(
                    f"token_budget={token_budget} < n_slots={n_slots}: "
                    "every resident decode token rides each unified step, "
                    "so this budget can never be honored and would "
                    "silently starve prefill; fix by raising token_budget "
                    f"to >= n_slots (default n_slots + chunk_tokens = "
                    f"{n_slots + (self.chunk_tokens or 0)}) or lowering "
                    "n_slots")
        # default budget: one decode token per slot (spec_tokens of them
        # in draft-verify mode) plus the prefill chunk; an EXPLICIT
        # budget instead throttles spec extras before prefill share
        self.token_budget = (token_budget if token_budget
                             else n_slots * (self.spec_tokens or 1)
                             + (self.chunk_tokens or 0))
        # pluggable composer policy: admission order + per-step prefill
        # share (repro.serving.policy — "fifo", "priority", "ttft", or an
        # instance); packing is a composer property, not an executable one
        self.policy = make_policy(policy)
        self.pack_chunks = bool(pack_chunks)
        self.pack_max = int(pack_max)
        # group consensus stop (self-consistency tentpole): a calibrated
        # GroupCalibrator, a raw agreement threshold in (0, 1], or None
        # (groups still gang-schedule and share prompt pages, but every
        # sample runs to its own per-request stop)
        if isinstance(consensus, bool):
            raise ValueError(
                f"consensus={consensus!r} is not a threshold: pass a float "
                "agreement threshold in (0, 1], a calibrated "
                "GroupCalibrator, or None to disable the consensus stop")
        if isinstance(consensus, (int, float)):
            thr = float(consensus)
            if not 0.0 < thr <= 1.0:
                raise ValueError(
                    f"consensus={thr} is outside (0, 1]: the threshold is "
                    "the weight share the top answer must reach; fix by "
                    "passing a float in (0, 1] or a calibrated "
                    "GroupCalibrator")
            consensus = GroupCalibrator(lam=thr, burn_in=cfg.burn_in)
        elif consensus is not None:
            if not isinstance(consensus, GroupCalibrator):
                raise ValueError(
                    f"consensus must be a GroupCalibrator, a float in "
                    f"(0, 1] or None, got {type(consensus).__name__}")
            if consensus.lam is None:
                raise ValueError(
                    "consensus GroupCalibrator has no threshold — run "
                    "GroupCalibrator.calibrate(...) first or pass "
                    "consensus=<float threshold>")
        self.consensus = consensus
        # involuntary preemption: reservation failures for strictly-more-
        # urgent units spill lower-priority residents to host RAM instead
        # of waiting; False restores the wait-only (PR-6) admission
        self.preemption = bool(preemption)
        self._n_preempted = self._n_restored = self._n_spilled_blocks = 0
        self.pool: Optional[BlockPool] = None
        self._engine: Optional[ContinuousServingEngine] = None
        self._session_open = False
        self._reset_session()

    # ------------------------------------------------------------------
    # serving-session state: queues, residents and counters for ONE
    # submit..drain cycle.  Engine, pool and policy objects deliberately
    # survive across sessions (repeated runs must not recompile).
    def _reset_session(self) -> None:
        self._waiting: deque = deque()            # gang-admission units
        self._swapped: deque = deque()            # (request, Spill) pairs
        self._running: Dict[int, Request] = {}    # slot -> request
        self._prefilling: Dict[int, Request] = {}  # slot -> mid-prefill req
        self._plans: Dict[int, _AdmitPlan] = {}   # deferred donor registry
        self._free: List[int] = list(range(self.n_slots))
        self._requests: List[Request] = []        # submission order
        self.groups: List[RequestGroup] = []      # consensus outcomes
        self._open_groups: List[RequestGroup] = []
        self._steps = 0
        self._active_slot_steps = 0
        self._total_tokens = self._n_chunks = self._n_packed = 0
        self._peak_blocks = self._prefill_skips = self._peak_step_tokens = 0
        self._n_cancelled = self._cancel_freed = 0
        self._n_preempted = self._n_restored = self._n_spilled_blocks = 0
        self._stalls: List[float] = []
        self._t0 = time.perf_counter()

    @property
    def has_work(self) -> bool:
        """True while any request is queued, swapped or resident."""
        return bool(self._waiting or self._swapped or self._running
                    or self._prefilling)

    # ------------------------------------------------------------------
    def _resident(self) -> bool:
        return bool(self._running or self._prefilling or self._swapped)

    def _refuse_rebuild(self, what: str, have, need) -> None:
        raise RuntimeError(
            f"submit() needs {what} of {need} but the live session has "
            f"{have} with requests resident — a rebuild would discard "
            "their KV/probe state; fix by sizing the fleet up front via "
            "prepare(<full request population>) (or an explicit "
            "cache_len/num_blocks) before serving starts")

    def _ensure_engine(self, requests: Sequence[Request]) -> ContinuousServingEngine:
        cache_len = self.cache_len
        if cache_len is None:
            mcfg = self.model.cfg
            max_prompt = max((prefix_len(mcfg, r.inputs, r.prompt_len)
                              for r in requests), default=0)
            if mcfg.arch_type == "audio":
                max_prompt = 0  # decoder cache holds generated tokens only
            max_new = max([r.max_new_tokens or self.cfg.max_new_tokens
                           for r in requests] + [self.cfg.max_new_tokens])
            cache_len = max_prompt + max_new
        if self.paged:
            # device-paged only for families with a page layout; every
            # family still gets pool-based admission control (backpressure)
            device_paged = self.model.supports_paged
            # the virtual capacity must also cover the largest prefill
            # prefix (vlm patches / meta tokens can exceed prompt+max_new);
            # _request_blocks reserves pages for it, so the engine's block
            # tables and the default pool have to be sized for it too
            cache_len = max([cache_len]
                            + [self._request_tokens(r) for r in requests])
            max_blocks = blocks_needed(cache_len, self.block_size)
            if self.num_blocks:
                num_blocks = int(self.num_blocks)
            else:
                num_blocks = self.n_slots * max_blocks + 1
                if self.pool is not None:
                    # derived sizing never shrinks a live pool: a smaller
                    # incremental submit (the router's placement path) must
                    # not drop pages a bigger earlier population reserved
                    num_blocks = max(num_blocks, self.pool.num_blocks)
            if self.pool is not None and self.pool.num_blocks != num_blocks \
                    and (self.pool.blocks_in_use or self._resident()):
                if num_blocks > self.pool.num_blocks:
                    self._refuse_rebuild("a page pool",
                                         self.pool.num_blocks, num_blocks)
                num_blocks = self.pool.num_blocks   # big enough: keep it
            if self.pool is None or self.pool.num_blocks != num_blocks:
                self.pool = BlockPool(num_blocks, self.block_size)
            if self._engine is None or self._engine.cache_len < cache_len:
                if self._engine is not None and self._resident():
                    self._refuse_rebuild("an engine cache_len",
                                         self._engine.cache_len, cache_len)
                self._engine = ContinuousServingEngine(
                    self.model, self.params, self.pc, self.theta, self.cfg,
                    self.n_slots, cache_len, probe_impl=self.probe_impl,
                    interpret=self.interpret, paged=device_paged,
                    block_size=self.block_size, num_blocks=num_blocks,
                    chunk_tokens=self.chunk_tokens,
                    pack_max=self.pack_max,
                    spec_tokens=(None if self.spec_tree
                                 else self.spec_tokens),
                    spec_tree=self.spec_tree)
        elif self._engine is None or self._engine.cache_len < cache_len:
            if self._engine is not None and self._resident():
                self._refuse_rebuild("an engine cache_len",
                                     self._engine.cache_len, cache_len)
            self._engine = ContinuousServingEngine(
                self.model, self.params, self.pc, self.theta, self.cfg,
                self.n_slots, cache_len, probe_impl=self.probe_impl,
                interpret=self.interpret, chunk_tokens=self.chunk_tokens,
                pack_max=self.pack_max,
                spec_tokens=(None if self.spec_tree
                             else self.spec_tokens),
                spec_tree=self.spec_tree)
        return self._engine

    # ------------------------------------------------------------------
    # paged admission: reserve pages (all-or-nothing) + prefix sharing
    def _request_tokens(self, req: Request) -> int:
        """Virtual positions this request needs: the full prefill prefix
        (vlm patches / meta tokens included — decode resumes after it)
        plus the decode budget."""
        mcfg = self.model.cfg
        max_new = req.max_new_tokens or self.cfg.max_new_tokens
        if mcfg.arch_type == "audio":
            return max_new
        return prefix_len(mcfg, req.inputs, req.prompt_len) + max_new

    def _request_blocks(self, req: Request) -> int:
        return blocks_needed(self._request_tokens(req), self.block_size)

    def _draft_context(self, req: Request, before: int = 0) -> List[int]:
        """The request's last draft-cache n-gram of committed tokens
        (prompt tail + decoded tokens), as plain ints.  ``before`` drops
        that many just-landed trailing tokens — the PRE-step context the
        promotion path keys on."""
        n = self.draft_cache.ngram
        toks = req.tokens[:len(req.tokens) - before] if before \
            else req.tokens
        if len(toks) >= n:
            return [int(t) for t in toks[-n:]]
        prompt = (np.asarray(req.inputs["tokens"][0]).tolist()
                  if "tokens" in req.inputs else [])
        need = n - len(toks)
        return ([int(t) for t in prompt[max(len(prompt) - need, 0):]]
                + [int(t) for t in toks])

    def _sharing_key(self, req: Request) -> Optional[str]:
        if not (self.prefix_sharing and self._engine is not None
                and self._engine.paged):
            return None
        if set(req.inputs) != {"tokens"}:      # multimodal prefixes differ
            return None
        # sharing assumes virtual positions [0, prompt_len) hold exactly
        # the prompt's K/V — a hidden prefix (meta tokens) breaks that
        if prefix_len(self.model.cfg, req.inputs, req.prompt_len) \
                != req.prompt_len:
            return None
        return prompt_key(np.asarray(req.inputs["tokens"]))

    def _reserve(self, req: Request) -> Optional[_AdmitPlan]:
        """Try to reserve this request's pages; None = pool exhausted (the
        request stays WAITING — backpressure, not over-admission)."""
        pool = self.pool
        n_total = self._request_blocks(req)
        key = self._sharing_key(req)
        entry = pool.lookup_prefix(key) if key else None
        if entry is not None and entry.prompt_len == req.prompt_len \
                and len(entry.full_blocks) <= n_total:
            private = pool.allocate(n_total - len(entry.full_blocks))
            if private is None:
                return None
            shared = pool.share(entry.full_blocks)
            copy_tail = None
            if entry.tail_block is not None and private:
                copy_tail = (entry.tail_block, private[0])
            return _AdmitPlan(row=shared + private, n_shared=len(shared),
                              skip_prefill=True, copy_tail=copy_tail,
                              register_key=None)
        row = pool.allocate(n_total)
        if row is None:
            return None
        return _AdmitPlan(row=row, n_shared=0, skip_prefill=False,
                          copy_tail=None, register_key=key)

    def _register_donor(self, req: Request, plan: _AdmitPlan) -> None:
        if plan.register_key is None:
            return
        bs = self.block_size
        n_full = req.prompt_len // bs
        tail = plan.row[n_full] if (req.prompt_len % bs
                                    and n_full < len(plan.row)) else None
        self.pool.register_prefix(plan.register_key, plan.row[:n_full],
                                  tail, req.prompt_len)

    def _chunks_prefill(self, req: Request) -> bool:
        """Will this request's prompt prefill in scheduled chunks (its
        pages only hold the prompt K/V once the LAST chunk lands)?"""
        return bool(self._engine is not None and self._engine.chunk_tokens
                    and chunk_supported(self.model, req.inputs))

    def _share_from_donor(self, donor, req: Request) -> Optional[_AdmitPlan]:
        """Intra-gang prefix sharing: build a sibling's plan off the unit
        leader's freshly-reserved prompt pages (refcount bump on the full
        pages + private pages for the tail/decode), without waiting for
        the leader to populate the prefix registry.  Same page shape as a
        registry hit."""
        key, row, d_prompt = donor
        n_total = self._request_blocks(req)
        n_full = req.prompt_len // self.block_size
        if d_prompt != req.prompt_len or n_full > len(row) \
                or n_total < n_full:
            return None
        private = self.pool.allocate(n_total - n_full)
        if private is None:
            return None
        shared = self.pool.share(row[:n_full])
        copy_tail = None
        if req.prompt_len % self.block_size and n_full < len(row) \
                and private:
            copy_tail = (row[n_full], private[0])
        return _AdmitPlan(row=shared + private, n_shared=n_full,
                          skip_prefill=True, copy_tail=copy_tail,
                          register_key=None)

    def _reserve_unit(self, members: Sequence[Request]
                      ) -> Optional[List[Optional[_AdmitPlan]]]:
        """ALL-OR-NOTHING page reservation for a gang-admission unit.

        The first sample reserves (or prefix-hits) the prompt pages; the
        siblings share its full prompt pages by refcount — the group is
        its own prefix donor, so N samples of one prompt store the prompt
        K/V once even on a cold registry.  Intra-gang sharing only
        engages when the leader's prompt lands in one admission shot
        (chunked prefill defers the donor until the last chunk, so
        siblings then take full private reservations).  Any member
        failing rolls the whole unit back: a group is never
        half-reserved."""
        plans: List[_AdmitPlan] = []
        donor = None
        for req in members:
            plan = None
            key = self._sharing_key(req)
            if donor is not None and key is not None and key == donor[0]:
                plan = self._share_from_donor(donor, req)
            if plan is None:
                plan = self._reserve(req)
                if plan is not None and plan.register_key is not None \
                        and donor is None and not self._chunks_prefill(req):
                    plan_key: str = plan.register_key
                    donor = (plan_key, plan.row, req.prompt_len)
            if plan is None:
                for p in plans:
                    self.pool.free(p.row)
                return None
            plans.append(plan)
        return plans

    # ------------------------------------------------------------------
    # involuntary preemption: spill residents to host RAM, restore later
    def _spill(self, req: Request, running: Dict[int, Request],
               prefilling: Dict[int, Request], plans: Dict[int, "_AdmitPlan"],
               free: List[int], swapped) -> None:
        """Preempt one resident: engine state to host RAM, pages back to
        the pool, slot back to the fleet, request onto the SWAPPED queue."""
        eng = self._engine
        slot = req.slot
        armed = req.state is RequestState.RUNNING
        spill = eng.preempt(
            slot,
            block_row=(req.block_ids if eng.paged and req.block_ids
                       else None),
            armed=armed, prompt_len=req.prefill_progress)
        if self.paged and req.block_ids:
            self._n_spilled_blocks += len(req.block_ids)
            self.pool.free(req.block_ids)
        req.block_ids = []
        req.n_shared_blocks = 0
        running.pop(slot, None)
        prefilling.pop(slot, None)
        # a mid-prefill victim's deferred donor plan names the pages just
        # freed — stale the moment the spill lands, so it is dropped (the
        # restored request re-registers nothing; only an optimization lost)
        plans.pop(slot, None)
        free.append(slot)
        req.slot = -1
        req.state = RequestState.SWAPPED
        req.n_preempted += 1
        self._n_preempted += 1
        swapped.append((req, spill))

    def _restore(self, req: Request, spill: Spill,
                 row: Optional[List[int]], free: List[int],
                 running: Dict[int, Request],
                 prefilling: Dict[int, Request], steps: int) -> None:
        """Resume a spilled request in a free slot: page copy-back (the
        new pages need not be the originals — only the block-table
        indirection changes), probe buffers reloaded exactly, and the
        request re-enters RUNNING (armed) or PREFILL (mid-prompt, its
        remaining chunks ride the unified step as before)."""
        eng = self._engine
        slot = free.pop()
        eng.restore(slot, spill,
                    block_row=(row if eng.paged else None))
        if row is not None:
            req.block_ids = list(row)
            req.n_shared_blocks = 0
        req.slot = slot
        req.restored_step = steps
        self._n_restored += 1
        if spill.armed:
            req.state = RequestState.RUNNING
            running[slot] = req
        else:
            req.state = RequestState.PREFILL
            prefilling[slot] = req

    def _preempt_for(self, members: Sequence[Request], prio: int,
                     running: Dict[int, Request],
                     prefilling: Dict[int, Request], free: List[int],
                     swapped, plans: Dict[int, "_AdmitPlan"]) -> bool:
        """Make room (slots and, in paged mode, pages) for ``members`` by
        spilling strictly-lower-priority residents.

        Runs a FEASIBILITY SIMULATION first — victims are chosen by the
        policy over a shrinking candidate list while simulated refcount
        decrements track which shared pages would actually die — and
        executes NO spill unless the unit will fit afterwards, so a spill
        can never be wasted on a unit that still doesn't fit (and a
        restored victim, being strictly lower priority, can never preempt
        its preemptor back: the relation is a DAG, no livelock)."""
        if not self.preemption:
            return False
        cand = list(running.values()) + list(prefilling.values())
        victims: List[Request] = []
        sim_slots = len(free)
        need_slots = len(members)
        sim_pages = self.pool.num_free if self.paged else 0
        need_pages = (sum(self._request_blocks(r) for r in members)
                      if self.paged else 0)
        sim_dec: Dict[int, int] = {}

        def fits() -> bool:
            return sim_slots >= need_slots and sim_pages >= need_pages

        while not fits():
            vi = self.policy.select_victim(cand, prio)
            if vi is None:
                return False
            victim = cand.pop(vi)
            victims.append(victim)
            sim_slots += 1
            for b in victim.block_ids:
                d = sim_dec.get(b, 0) + 1
                sim_dec[b] = d
                # a shared page only returns with its LAST owner
                if self.pool.refcount(b) - d == 0:
                    sim_pages += 1
        for victim in victims:
            self._spill(victim, running, prefilling, plans, free, swapped)
        return True

    # ------------------------------------------------------------------
    # the submit/step/drain protocol (shared with FleetRouter)
    def prepare(self, requests: Sequence[Request]) -> None:
        """Size the engine and (in paged mode) the page pool for a request
        population WITHOUT enqueueing it.

        The fleet router calls this on every host with the FULL fleet
        population before placement, so no host ever needs a mid-flight
        engine rebuild (refused while requests are resident — a rebuild
        would discard their KV/probe state)."""
        fresh = not self._session_open
        if fresh:
            self._reset_session()
            self._session_open = True
        if requests:
            self._ensure_engine(requests)
        if fresh:
            # engine construction (jit) stays out of queue-wait time, same
            # as the fresh-submit path
            self._t0 = time.perf_counter()

    def submit(self, requests: Sequence[Request]) -> None:
        """Enqueue ``requests`` as gang-admission units, opening a fresh
        serving session if none is active.  Callable repeatedly — the
        engine/pool are sized for each submitted population, so size for
        the UNION up front via ``prepare`` when submitting incrementally."""
        requests = list(requests)
        fresh = not self._session_open
        if fresh:
            self._reset_session()
            self._session_open = True
        if not requests:
            return
        self._ensure_engine(requests)
        # gang-admission units: a whole self-consistency group (atomic:
        # all samples or none) or a singleton; with no grouped requests
        # this is exactly the classic per-request queue
        units, groups = group_requests(requests)
        for grp in groups:
            if grp.size > self.n_slots:
                raise ValueError(
                    f"group {grp.group_id} has {grp.size} samples but the "
                    f"fleet has {self.n_slots} slots: gang admission needs "
                    "every sample resident at once; fix by raising n_slots "
                    f"to >= {grp.size} or lowering the group size")
        if fresh:
            # the serving clock starts once the first batch is staged —
            # engine construction stays out of queue-wait time, matching
            # the pre-split run() semantics
            self._t0 = time.perf_counter()
        self._requests.extend(requests)
        self.groups.extend(groups)     # exposed: consensus outcomes
        if self.consensus:
            # groups whose consensus may still fire (checked every step a
            # member could have emitted a score; a lone sample never votes)
            self._open_groups.extend(g for g in groups if g.size >= 2)
        self._waiting.extend(units)

    def run(self, requests: Sequence[Request]
            ) -> Tuple[List[Request], FleetMetrics]:
        """Drive every request to STOPPED/FINISHED/CANCELLED; return them
        + metrics.  The classic one-shot facade over submit + drain."""
        if self._session_open and self.has_work:
            raise RuntimeError(
                "run() while a serving session is active would reset "
                "resident state; drive incremental traffic through "
                "submit()/step()/drain() instead")
        self._session_open = False     # fresh session even after a drain
        self.submit(requests)
        return self.drain()

    def drain(self) -> Tuple[List[Request], FleetMetrics]:
        """Step until the fleet is idle, close the session and return
        every submitted request plus the session's ``FleetMetrics``."""
        while self.step():
            pass
        wall = max(time.perf_counter() - self._t0, 1e-9)
        requests = list(self._requests)
        metrics = self._metrics(requests, self._steps,
                                self._active_slot_steps,
                                self._total_tokens, wall,
                                self._peak_blocks, self._prefill_skips,
                                self._stalls, self._n_chunks,
                                self._n_packed, self._peak_step_tokens,
                                self.groups, self._n_cancelled,
                                self._cancel_freed)
        self._session_open = False
        return requests, metrics

    def step(self) -> bool:
        """ONE scheduler iteration: admission -> batch composition -> the
        fused engine step -> token collection / ORCA eviction -> prefill
        bookkeeping -> consensus.  Returns False when the fleet is idle
        (nothing queued, swapped or resident)."""
        if not self.has_work:
            return False
        eng = self._engine
        chunked = bool(eng.chunk_tokens)
        waiting, swapped = self._waiting, self._swapped
        running, prefilling = self._running, self._prefilling
        plans, free = self._plans, self._free
        steps = self._steps
        t_iter = time.perf_counter()

        # admission: refill free slots before the next fused step.
        # SWAPPED requests (preemption victims) restore FIRST — ahead
        # of every WAITING unit — and a swapped head that cannot yet
        # restore BARRIERS its own class: only strictly-more-urgent
        # units admit past it, so a victim is never overtaken by its
        # own class.  Then the POLICY picks which WAITING UNIT (a
        # whole group, or a singleton for the classic request) — in
        # paged mode a unit that doesn't fit the pool holds its place
        # and WAITS for an eviction to return pages, and a group
        # additionally waits for enough free SLOTS: gang admission is
        # all-or-nothing on both resources, so a group is never
        # half-resident.  Pages are still reserved ALL-OR-NOTHING,
        # whether the prompt then prefills in one admission shot or in
        # scheduled chunks.  When capacity fails for a unit strictly
        # MORE urgent than some resident, ``_preempt_for`` spills
        # policy-chosen victims until the unit fits; and a gang
        # needing more slots than are free no longer stalls smaller
        # units behind it — the policy may SKIP it, bounded by the
        # ``max_head_skips`` aging guard (a pinned gang admits next).
        tried: set = set()        # id(unit) passed over this round
        barrier_prio: Optional[int] = None
        while swapped or waiting:
            if swapped and barrier_prio is None:
                req, spill = swapped[0]
                if req.done:      # cancelled while swapped
                    swapped.popleft()
                    continue
                if free:
                    row = None
                    if self.paged:
                        row = self.pool.allocate(
                            self._request_blocks(req))
                    if row is not None or not self.paged:
                        swapped.popleft()
                        self._restore(req, spill, row, free, running,
                                      prefilling, steps)
                        if self.paged:
                            self._peak_blocks = max(
                                self._peak_blocks,
                                self.pool.blocks_in_use)
                        continue
                if self._preempt_for([req], req.priority, running,
                                     prefilling, free, swapped, plans):
                    continue      # room made: retry the restore
                if not (running or prefilling):
                    raise RuntimeError(
                        f"swapped request {req.req_id} cannot restore "
                        "with the fleet empty — slot/page accounting "
                        "is corrupt")
                barrier_prio = req.priority
            if not waiting:
                break
            cand_idx = [i for i, u in enumerate(waiting)
                        if id(u) not in tried]
            if not cand_idx:
                break
            cand = [waiting[i] for i in cand_idx]
            sel = self.policy.select_admit_unit(cand, steps)
            idx = cand_idx[sel]
            unit = waiting[idx]
            members = [r for r in unit
                       if r.state is RequestState.WAITING]
            if not members:          # fully cancelled before admission
                del waiting[idx]
                continue
            prio = min(r.priority for r in members)
            if barrier_prio is not None and prio >= barrier_prio:
                break     # nothing more urgent than the blocked head
            if len(members) > len(free):
                # slot shortage: preempt strictly-less-urgent
                # residents; else let the policy skip the oversized
                # unit so smaller units behind it still admit
                if not self._preempt_for(members, prio, running,
                                         prefilling, free, swapped,
                                         plans):
                    if free and len(cand) > 1 \
                            and self.policy.on_skipped_unit(cand, sel):
                        tried.add(id(unit))
                        continue
                    break
            if self.paged:
                mplans = self._reserve_unit(members)
                if mplans is None and self._preempt_for(
                        members, prio, running, prefilling, free,
                        swapped, plans):
                    mplans = self._reserve_unit(members)
                if mplans is None:
                    if not (running or prefilling or swapped):
                        need = sum(self._request_blocks(r)
                                   for r in members)
                        what = (f"group {members[0].group_id}"
                                if members[0].group_id is not None
                                else f"request {members[0].req_id}")
                        raise RuntimeError(
                            f"{what} needs {need} pages but the "
                            f"pool holds {self.pool.num_usable}; "
                            "nothing left to evict")
                    break
            else:
                mplans = [None] * len(members)
            self.policy.on_admitted_unit(cand, sel)
            del waiting[idx]
            for req, plan in zip(members, mplans):
                slot = free.pop()
                req.slot, req.admitted_step = slot, steps
                req.queue_wait_s = time.perf_counter() - self._t0
                req.state = RequestState.PREFILL
                skip = plan.skip_prefill if plan is not None else False
                if plan is not None:
                    req.block_ids = list(plan.row)
                    req.n_shared_blocks = plan.n_shared
                    req.prefill_skipped = skip
                    self._prefill_skips += int(skip)
                    self._peak_blocks = max(self._peak_blocks,
                                            self.pool.blocks_in_use)
                if chunked and not skip \
                        and chunk_supported(self.model, req.inputs):
                    # prefill is schedulable work, not an admission
                    # event: the slot becomes a resident PREFILL row
                    # and the prompt rides the unified step in
                    # token-budget chunks
                    eng.begin_prefill(slot)
                    req.prefill_progress = 0
                    prefilling[slot] = req
                    if plan is not None:
                        # donor registration deferred: the pages only
                        # hold the prompt K/V once the last chunk lands
                        plans[slot] = plan
                else:
                    if plan is not None and eng.paged:
                        eng.admit(slot, req.inputs, req.prompt_len,
                                  block_row=plan.row,
                                  skip_prefill=skip,
                                  copy_tail=plan.copy_tail)
                    else:
                        # family without a page layout / non-text
                        # prompt: the pool still admission-controls,
                        # the device cache stays dense and prefill
                        # stays one shot
                        eng.admit(slot, req.inputs, req.prompt_len)
                    if plan is not None:
                        self._register_donor(req, plan)
                    req.state = RequestState.RUNNING
                    running[slot] = req

        # batch composer: every resident decode token rides this step;
        # in spec mode each RUNNING slot additionally claims up to
        # spec_tokens - 1 extra verify tokens (greedy in slot order,
        # capped by its remaining decode budget) from the SAME token
        # budget; the POLICY then sizes the prefill share of what's
        # left, and the share is PACKED across mid-prefill residents
        # in admission order — the tail of one prompt and the head of
        # the next fuse into one block-diagonal chunk
        # (pack_chunks=False: one request per chunk, PR-4's composer)
        spec_lens = None
        spec_drafts = spec_have = None
        draft_ctx: Dict[int, List[int]] = {}
        spec_total = len(running)
        if self.spec_tokens:
            spec_lens = np.zeros((self.n_slots,), np.int32)
            # no token budget -> spec extras are bounded by block length
            # alone (n_slots * (spec_tokens - 1) can never exceed this cap)
            budget_left = (self.token_budget - len(running)
                           if self.token_budget is not None
                           else self.n_slots * self.spec_tokens)
            # tree mode: the accepted path is at most one node per DEPTH,
            # so extra nodes beyond width * (remaining - 1) can never
            # commit — the depth cap that keeps a near-budget slot from
            # claiming nodes it cannot use (width 1 == the linear cap)
            width = self.spec_tree[0] if self.spec_tree else 1
            for slot in sorted(running):
                req = running[slot]
                max_new = req.max_new_tokens or self.cfg.max_new_tokens
                remaining = max_new - len(req.tokens)
                extra = max(min(self.spec_tokens - 1,
                                width * (remaining - 1), budget_left), 0)
                spec_lens[slot] = 1 + extra
                budget_left -= extra
            spec_total = int(spec_lens.sum())
            if self.draft_cache is not None:
                # shared-cache drafts for every slot actually drafting
                # this step; misses keep have=False — the engine falls
                # back to the family drafter inside the same executable
                if self.spec_tree:
                    w_, d_ = self.spec_tree
                    spec_drafts = np.zeros((self.n_slots, w_, d_), np.int32)
                else:
                    w_, d_ = 1, self.spec_tokens - 1
                    spec_drafts = np.zeros((self.n_slots, d_), np.int32)
                spec_have = np.zeros((self.n_slots,), bool)
                for slot in sorted(running):
                    if spec_lens[slot] < 2:
                        continue
                    req = running[slot]
                    ctx = self._draft_context(req)
                    draft_ctx[slot] = ctx
                    tree, hit = self.draft_cache.lookup(ctx, w_, d_)
                    spec_drafts[slot] = tree if self.spec_tree else tree[0]
                    spec_have[slot] = hit
                    if hit:
                        req.draft_hits += 1
                    else:
                        req.draft_misses += 1
        chunk = None
        if prefilling:
            share = self.policy.prefill_share(self._compose_view(
                running, prefilling, waiting, eng))
            share = min(share, eng.chunk_tokens,
                        self.token_budget - spec_total)
            segs: List[ChunkSeg] = []
            residents = list(prefilling.items())
            if any(r.group_id is not None
                   for r in prefilling.values()):
                # sample spreading: order mid-prefill residents by
                # sample_idx first, so one packed chunk carries sample
                # k of SEVERAL groups rather than all samples of one —
                # siblings finish prefill on different steps and their
                # probe boundaries (hence votes) de-phase.  Ungrouped
                # fleets keep admission order byte-for-byte.
                residents.sort(key=lambda kv: (kv[1].sample_idx,
                                               kv[1].admitted_step,
                                               kv[1].req_id))
            for slot, req in residents:
                if share <= 0 or len(segs) >= eng.max_pack:
                    break
                n = min(share, req.prompt_len - req.prefill_progress)
                if n <= 0:
                    continue
                segs.append(ChunkSeg(
                    slot=slot,
                    tokens=np.asarray(req.inputs["tokens"][0]),
                    start=req.prefill_progress, length=int(n),
                    row=(np.asarray(req.block_ids, np.int32)
                         if eng.paged and req.block_ids else None)))
                share -= n
                if not self.pack_chunks:
                    break
            if segs:
                chunk = ChunkWork(segs=tuple(segs))
                self._n_chunks += 1
                self._n_packed += int(len(segs) >= 2)
        self._peak_step_tokens = max(
            self._peak_step_tokens,
            spec_total + (chunk.total_tokens if chunk else 0))

        if self.spec_tokens:
            kw = dict(spec_lens=spec_lens, spec_drafts=spec_drafts,
                      spec_have=spec_have)
            view = eng.step(chunk, **kw) if chunked else eng.step(**kw)
        else:
            view = eng.step(chunk) if chunked else eng.step()
        steps = self._steps = self._steps + 1
        self._active_slot_steps += len(running)
        now = time.perf_counter()

        for slot, req in list(running.items()):
            if req.first_token_step < 0:
                req.first_token_step = steps
                req.ttft_s = now - self._t0
            if self.spec_tokens:
                # speculative block: the slot proposed spec_lens[slot]
                # tokens and the verifier accepted a prefix of
                # view.gen[slot]; append accepted tokens in order,
                # collecting each probe boundary's (score, answer)
                # vote as it lands, and TRUNCATE at the stop boundary
                # — tokens past the stop were never "emitted" (the
                # one-token engine would have evicted the slot there)
                lp = int(spec_lens[slot])
                g = int(view.gen[slot])
                req.spec_proposed += max(lp - 1, 0)
                req.spec_accepted += max(g - 1, 0)
                if lp > 0:
                    req.accepted_lens.append(g)
                    if self.spec_tree:
                        req.tree_nodes += max(lp - 1, 0)
                        req.tree_path_lens.append(g)
                stopped_now = bool(view.stopped[slot])
                stop_at = int(view.stop_step[slot]) if stopped_now else -1
                landed: List[int] = []
                for j in range(g):
                    tok = int(view.seq[slot, j])
                    req.tokens.append(tok)
                    landed.append(tok)
                    self._total_tokens += 1
                    nsj = int(view.seq_n[slot, j])
                    if nsj > len(req.scores):
                        req.scores.append(float(view.seq_scores[slot, j]))
                        req.answers.append(tok)
                    if stopped_now and nsj == stop_at:
                        break
                if self.draft_cache is not None and landed:
                    # promote what the VERIFIER accepted: the cache
                    # learns exactly the continuations this traffic
                    # commits, shared fleet-wide
                    ctx = draft_ctx.get(slot)
                    if ctx is None:
                        ctx = self._draft_context(req, before=len(landed))
                    self.draft_cache.observe(ctx, landed)
                n_scores = int(view.n_scores[slot])
            else:
                req.tokens.append(int(view.tokens[slot]))
                self._total_tokens += 1
                n_scores = int(view.n_scores[slot])
                if n_scores > len(req.scores):
                    req.scores.append(float(view.smoothed[slot]))
                    # the vote at this probe boundary: the answer hash
                    # is the token just decoded (the step's answer
                    # proxy, same convention as launch.serve's
                    # trajectory extraction) — recorded alongside the
                    # score so a group's consensus sees matched
                    # (confidence, answer) pairs
                    req.answers.append(int(view.tokens[slot]))
            max_new = req.max_new_tokens or self.cfg.max_new_tokens
            if bool(view.stopped[slot]):
                # ORCA stop: evict NOW — the slot is free next step
                req.stop_step = int(view.stop_step[slot])
                req.steps_run = req.stop_step
                self._complete(req, RequestState.STOPPED, steps)
            elif len(req.tokens) >= max_new:
                req.stop_step = -1
                req.steps_run = n_scores
                self._complete(req, RequestState.FINISHED, steps)
            else:
                continue
            eng.release(slot)
            if self.paged and req.block_ids:
                # the stop IS the reclaim: pages return to the pool now
                self.pool.free(req.block_ids)
            free.append(slot)
            del running[slot]

        # prefill bookkeeping AFTER token collection: every segment of
        # the packed chunk advances; a request whose last chunk just
        # landed decodes its first token NEXT step
        if chunk is not None:
            for seg in chunk.segs:
                req = prefilling[seg.slot]
                req.prefill_progress += seg.length
                if req.prefill_progress >= req.prompt_len:
                    eng.finish_prefill(
                        seg.slot, req.inputs, req.prompt_len,
                        block_row=(req.block_ids
                                   if eng.paged and req.block_ids
                                   else None))
                    del prefilling[seg.slot]
                    plan = plans.pop(seg.slot, None)
                    if plan is not None:
                        self._register_donor(req, plan)
                    req.state = RequestState.RUNNING
                    running[seg.slot] = req

        # consensus stop: after this step's scores landed (and ORCA
        # evictions ran — a sample stopping at this very boundary
        # still votes its final frozen score), each open group's
        # calibrated vote is re-checked; the first crossing CANCELS
        # every still-running sibling mid-flight — slot, pages and
        # probe state return to the fleet, the unspent budget becomes
        # group savings
        if self._open_groups:
            still_open: List[RequestGroup] = []
            for grp in self._open_groups:
                fire, ans, agr = self.consensus.decide(
                    [r.scores for r in grp.requests],
                    [r.answers for r in grp.requests])
                if fire:
                    grp.consensus_step = steps
                    grp.consensus_index = max(
                        len(r.scores) for r in grp.requests) - 1
                    grp.consensus_answer = int(ans)
                    grp.consensus_agreement = float(agr)
                    for sib in grp.requests:
                        if sib.done:
                            continue
                        if sib.state is RequestState.SWAPPED:
                            # a spilled sibling holds no slot and no
                            # pages (both returned at spill) — drop
                            # its queued restore and mark it cancelled
                            for qi, (q, _) in enumerate(swapped):
                                if q is sib:
                                    del swapped[qi]
                                    break
                            sib.steps_run = len(sib.scores)
                            sib.stop_step = -1
                            self._complete(sib, RequestState.CANCELLED,
                                           steps)
                            self._n_cancelled += 1
                            continue
                        slot = sib.slot
                        eng.cancel(slot)
                        if self.paged and sib.block_ids:
                            self._cancel_freed += \
                                self.pool.free(sib.block_ids)
                        free.append(slot)
                        running.pop(slot, None)
                        if slot in prefilling:
                            # cancel-mid-prefill: the row sat parked
                            # at NULL the whole prefill, so it was
                            # never armed; drop the deferred donor
                            # plan with it
                            del prefilling[slot]
                            plans.pop(slot, None)
                        sib.steps_run = len(sib.scores)
                        sib.stop_step = -1
                        self._complete(sib, RequestState.CANCELLED,
                                       steps)
                        self._n_cancelled += 1
                elif not grp.done:
                    still_open.append(grp)
            self._open_groups = still_open
        self._stalls.append((time.perf_counter() - t_iter) * 1e3)
        return True

    # ------------------------------------------------------------------
    def pressure(self, host: int = 0) -> HostPressure:
        """Export this scheduler's ``ComposeView``-style pressure summary
        — the per-host snapshot the ``FleetRouter``'s placement policy
        consumes each step (the gossip of the simulated fleet).  Valid at
        any point in a session, including before the first submit."""
        residents = list(self._running.values()) \
            + list(self._prefilling.values())
        queued = sum(sum(1 for r in u if not r.done)
                     for u in self._waiting)
        swapped_live = sum(1 for r, _ in self._swapped if not r.done)
        return HostPressure(
            host=int(host), n_slots=self.n_slots,
            n_running=len(self._running),
            n_prefilling=len(self._prefilling),
            n_swapped=swapped_live,
            n_waiting=len(self._waiting),
            queued_samples=queued,
            free_slots=len(self._free),
            pool_blocks=self.pool.num_usable if self.pool else 0,
            free_blocks=self.pool.num_free if self.pool else 0,
            blocks_in_use=self.pool.blocks_in_use if self.pool else 0,
            max_resident_priority=(max(r.priority for r in residents)
                                   if residents else None))

    # ------------------------------------------------------------------
    def _compose_view(self, running: Dict[int, Request],
                      prefilling: Dict[int, Request], waiting,
                      eng: ContinuousServingEngine) -> ComposeView:
        near = 0
        margin = self.policy.probe_margin
        if margin is not None and running:
            tps = self.cfg.tokens_per_step
            # tokens still owed before each resident's next probe boundary
            # (the step a stop decision can fire): len(tokens) counts
            # decoded tokens, the boundary closes every tokens_per_step
            near = sum(1 for r in running.values()
                       if tps - (len(r.tokens) % tps) <= margin)
        return ComposeView(n_running=len(running), n_slots=self.n_slots,
                           n_prefilling=len(prefilling),
                           n_waiting=len(waiting),
                           token_budget=self.token_budget,
                           chunk_tokens=eng.chunk_tokens,
                           near_boundary=near)

    # ------------------------------------------------------------------
    @staticmethod
    def _complete(req: Request, state: RequestState, step: int) -> None:
        req.state = state
        req.completed_step = step

    def _metrics(self, requests: Sequence[Request], steps: int,
                 active_slot_steps: int, total_tokens: int,
                 wall: float, peak_blocks: int = 0,
                 prefill_skips: int = 0,
                 stalls: Optional[Sequence[float]] = None,
                 prefill_chunks: int = 0, packed_chunks: int = 0,
                 peak_step_tokens: int = 0,
                 groups: Optional[Sequence[RequestGroup]] = None,
                 n_cancelled: int = 0,
                 cancel_freed: int = 0) -> FleetMetrics:
        n = len(requests)
        sav = [r.savings(self.cfg.tokens_per_step, self.cfg.max_new_tokens)
               for r in requests]
        queue = [r.queue_steps for r in requests]
        # latency tails via the shared helper (CANCELLED excluded there;
        # the FleetRouter recomputes the same stats over the fleet union)
        ttft_p50, ttft_p99, per_class = latency_stats(list(requests))
        st = np.asarray(stalls if stalls else [0.0])
        # group-level accounting: savings COUNT a cancelled sample's
        # unspent budget (the whole point of consensus cancellation)
        tps, dmn = self.cfg.tokens_per_step, self.cfg.max_new_tokens
        real_groups = [g for g in (groups or []) if g.size >= 2]
        g_sav = [g.savings(tps, dmn) for g in real_groups]
        fired = [g for g in real_groups if g.decided]
        # total unspent reasoning steps across groups — what the fleet
        # actually got back (the documented group_savings semantics; the
        # old per-group mean fraction survives as group_savings_mean)
        g_unspent = [max(g.budget_steps(tps, dmn) - g.steps_spent(), 0)
                     for g in real_groups]
        # speculative-decode acceptance via the ONE shared helper
        # (CANCELLED siblings excluded there; the FleetRouter calls the
        # same function over the fleet union, so the two can never drift)
        return FleetMetrics(
            **spec_stats(list(requests)),
            samples_cancelled=n_cancelled,
            consensus_groups=len(fired),
            consensus_steps=(float(np.mean([g.consensus_index
                                            for g in fired]))
                             if fired else 0.0),
            group_savings=float(sum(g_unspent)),
            group_savings_mean=float(np.mean(g_sav)) if g_sav else 0.0,
            cancel_freed_blocks=cancel_freed,
            preemptions=self._n_preempted,
            restores=self._n_restored,
            spilled_blocks=self._n_spilled_blocks,
            n_requests=n, n_slots=self.n_slots, engine_steps=steps,
            active_slot_steps=active_slot_steps, wall_time_s=wall,
            requests_per_s=n / wall, tokens_per_s=total_tokens / wall,
            slot_utilization=(active_slot_steps
                              / max(steps * self.n_slots, 1)),
            mean_step_savings=float(np.mean(sav)) if sav else 0.0,
            mean_queue_steps=float(np.mean(queue)) if queue else 0.0,
            pool_blocks=self.pool.num_usable if self.pool else 0,
            peak_blocks_in_use=peak_blocks, prefill_skips=prefill_skips,
            ttft_ms_p50=ttft_p50, ttft_ms_p99=ttft_p99,
            stall_ms_p50=float(np.percentile(st, 50)),
            stall_ms_p99=float(np.percentile(st, 99)),
            prefill_chunks=prefill_chunks, packed_chunks=packed_chunks,
            peak_step_tokens=peak_step_tokens, per_class=per_class)
