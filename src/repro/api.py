"""repro.api — the ORCA facade: fit -> evaluate -> engine.

One import gives the whole paper pipeline plus the serving stack, without
re-plumbing the train -> calibrate -> serve path by hand:

    from repro import api as orca

    cal   = orca.fit(train, mode="supervised", method="ttt", epochs=25)
    ev    = orca.evaluate(cal, cal_split, test_split, deltas=(0.1,))
    lam   = cal.calibrate(cal_split, delta=0.1)          # LTT lambda*
    cfg   = orca.ServeConfig(n_slots=4, tokens_per_step=8,
                             max_new_tokens=96)
    sched = orca.engine(model, params, cal, config=cfg)
    done, fm = orca.serve_requests(sched, prompt_token_rows)

    router = orca.fleet(model, params, cal,      # multi-host serving:
                        config=cfg, n_hosts=2)   # same protocol
    done, fm = orca.serve_requests(router, prompt_token_rows)

``fit``/``evaluate`` work for every registered Calibrator ("ttt",
"static"); ``engine`` needs a calibrator that can hand (ProbeConfig,
theta) to the fused serve step (the TTT probe).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.calibrator import (Calibrator, GroupCalibrator, GroupTrace,
                                   StaticCalibrator, TTTCalibrator,
                                   groups_from_trajectories, make_calibrator)
from repro.core.pipeline import ProcedureEval, evaluate_probe
from repro.serving.config import ServeConfig
from repro.serving.router import FleetRouter
from repro.serving.scheduler import OrcaScheduler
from repro.trajectories import TrajectorySet

__all__ = ["Calibrator", "GroupCalibrator", "GroupTrace",
           "ServeConfig", "StaticCalibrator", "TTTCalibrator",
           "calibrated_lambda", "engine", "evaluate", "fit", "fleet",
           "groups_from_trajectories", "make_calibrator", "serve_requests"]

DELTAS = (0.05, 0.1, 0.15, 0.2)


def fit(train: TrajectorySet, mode: str = "supervised",
        method: str = "ttt", **kwargs) -> Calibrator:
    """Train a calibrator on ``train``.

    ``method`` picks the implementation ("ttt" — the paper's meta-learned
    probe — or "static"); ``kwargs`` go to its constructor (e.g.
    ``epochs=25, seed=1, pc=ProbeConfig(...)`` for ttt).
    """
    return make_calibrator(method, **kwargs).fit(train, mode)


def evaluate(calibrator: Calibrator, cal: TrajectorySet, test: TrajectorySet,
             *, deltas: Sequence[float] = DELTAS,
             eps: float = 0.05) -> ProcedureEval:
    """LTT-calibrate on ``cal`` and report deployed savings/error on ``test``
    (risk against supervised ground truth — what the paper's tables show)."""
    return evaluate_probe(calibrator.scores(cal), cal,
                          calibrator.scores(test), test,
                          calibrator.mode, deltas, eps=eps,
                          method=calibrator.method)


def calibrated_lambda(calibrator: Calibrator, cal: TrajectorySet,
                      delta: float, *, eps: float = 0.05,
                      fallback: float = math.inf) -> float:
    """``calibrate()`` with ONE policy for "LTT selected nothing".

    The honest default keeps lambda* = inf (never stop early — zero savings,
    zero stopping risk; ``engine()`` then serves with stopping disabled).
    Demos on tiny random-weight models may pass ``fallback=0.99`` (the most
    aggressive grid threshold) to keep eviction observable — an explicit
    opt-out of the guarantee, in one place instead of per driver.
    """
    lam = calibrator.calibrate(cal, delta, eps)
    if not math.isfinite(lam):
        return float(fallback)
    return lam


def _resolve_lam(calibrator: Calibrator,
                 lam: Optional[float]) -> float:
    """Explicit lam wins, else the calibrator's LTT threshold; a
    non-finite lambda* (LTT selected nothing) serves with stopping
    disabled — sigmoid scores <= 1 never cross 2.0."""
    if lam is None:
        lam = calibrator.threshold()
    lam = float(lam)
    if not math.isfinite(lam):
        lam = 2.0
    return lam


def engine(model, params, calibrator: Calibrator,
           config: Optional[ServeConfig] = None, *,
           lam: Optional[float] = None,
           serve: Optional[ServeConfig] = None,
           **kwargs) -> OrcaScheduler:
    """Build a continuous-batching ``OrcaScheduler`` serving the calibrated
    procedure.

    The blessed path is ONE config object::

        cfg = ServeConfig(n_slots=8, paged=True, chunk_tokens=32,
                          policy="priority", group_size=4, consensus=gcal)
        sched = orca.engine(model, params, cal, config=cfg)

    Every serving knob — fleet shape, paged KV, chunked/packed prefill,
    speculative draft-verify decode (``spec_tokens=``), scheduling
    policy, self-consistency groups, preemption, probe dispatch — is a
    ``ServeConfig`` field, validated once at construction with errors
    that name the fix (see ``repro.serving.ServeConfig``).
    The threshold comes from ``config.lam`` unless ``lam=`` overrides it;
    with neither, the calibrator's LTT ``threshold()`` is used (a
    non-finite lambda* serves with stopping disabled).

    The pre-ServeConfig keyword sprawl (``n_slots=8, paged=True, ...``)
    still works as a shim that builds the same ServeConfig — it emits
    ``DeprecationWarning`` and will be removed; so does ``serve=`` (the
    old name for the step-field config, now the same class as
    ``config=``).

    Stop decisions are unchanged by ANY scheduling knob — paging,
    chunking, packing, policy, preemption and grouping all preserve the
    standing invariant that per-request stops are byte-identical across
    serving configurations.
    """
    if serve is not None:
        if config is not None:
            raise ValueError("pass either config= or the deprecated "
                             "serve=, not both")
        if lam is not None or kwargs:
            raise ValueError("pass either a full ServeConfig via serve= or "
                             "lam=/ServeConfig kwargs, not both")
        warnings.warn(
            "engine(serve=...) is deprecated: the step config and the "
            "scheduler kwargs are one ServeConfig now — pass it as "
            "engine(..., config=cfg)", DeprecationWarning, stacklevel=2)
        config = serve
    if config is not None:
        if kwargs:
            raise ValueError(
                f"config= together with ServeConfig kwargs {sorted(kwargs)} "
                "is ambiguous; fix by folding them into the config "
                "(dataclasses.replace(config, ...)) or dropping config=")
        if lam is not None:
            config = dataclasses.replace(config, lam=float(lam))
    else:
        if kwargs:
            warnings.warn(
                "engine(**serving_kwargs) is deprecated: build a "
                "repro.serving.ServeConfig and pass engine(..., "
                "config=cfg) — ServeConfig.from_args converts argparse "
                "namespaces", DeprecationWarning, stacklevel=2)
        # kwargs validation first (as the pre-config API did), THEN the
        # lam resolution that touches the calibrator
        config = ServeConfig(**kwargs)
        config = dataclasses.replace(
            config, lam=_resolve_lam(calibrator, lam))
    pc, theta = calibrator.serving_params()
    sched = OrcaScheduler(model, params, pc, theta, config)
    sched.group_size = config.group_size  # serve_requests' expansion default
    return sched


def fleet(model, params, calibrator: Calibrator,
          config: Optional[ServeConfig] = None, *,
          n_hosts: Optional[int] = None,
          lam: Optional[float] = None,
          placement=None,
          parallel_hosts: bool = True) -> FleetRouter:
    """Build a multi-host ``FleetRouter`` serving the calibrated procedure.

    The fleet facade: N simulated hosts, each running the unchanged
    single-host scheduler with its own engine, ``BlockPool`` and policy
    instance; the router places gang-admission units by gossiped
    ``HostPressure`` with prefix-affine routing (see
    ``repro.serving.FleetRouter``).  Config-only — no legacy kwargs
    path::

        cfg = ServeConfig(n_slots=4, paged=True, num_blocks=96,
                          n_hosts=2)             # num_blocks = TOTAL pages
        router = orca.fleet(model, params, cal, config=cfg)
        done, fm = orca.serve_requests(router, prompt_rows)

    ``n_hosts=``/``lam=``/``placement=`` override the config fields.
    Per-request stop decisions are byte-identical to single-host serving
    under every placement policy.
    """
    if config is None:
        config = ServeConfig(lam=_resolve_lam(calibrator, lam))
    elif lam is not None:
        config = dataclasses.replace(config, lam=float(lam))
    pc, theta = calibrator.serving_params()
    return FleetRouter(model, params, pc, theta, config,
                       n_hosts=(n_hosts if n_hosts is not None
                                else config.n_hosts),
                       placement=(placement if placement is not None
                                  else config.placement),
                       parallel_hosts=parallel_hosts)


def serve_requests(server: Union[OrcaScheduler, FleetRouter],
                   prompts: np.ndarray,
                   group_size: Optional[int] = None):
    """Convenience: one Request per row of ``prompts`` (N, prompt_len),
    driven through ``server`` — an ``OrcaScheduler`` or a ``FleetRouter``;
    both speak the same submit/step/drain/run protocol, so callers and the
    benchmark drive single-host and fleet serving through this one entry
    point.  ``group_size`` (default: the server's configured value)
    expands each prompt into a gang-admitted self-consistency group.
    Returns (requests, FleetMetrics)."""
    from repro.serving.groups import make_group
    from repro.serving.request import make_request
    if group_size is None:
        group_size = getattr(server, "group_size", 1)
    if group_size > 1:
        reqs = [r for i in range(len(prompts))
                for r in make_group(np.asarray(prompts[i]), group_size,
                                    group_id=i)]
    else:
        reqs = [make_request(np.asarray(prompts[i]))
                for i in range(len(prompts))]
    return server.run(reqs)
