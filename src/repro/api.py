"""repro.api — the ORCA facade: fit -> evaluate -> engine.

One import gives the whole paper pipeline plus the serving stack, without
re-plumbing the train -> calibrate -> serve path by hand:

    from repro import api as orca

    cal   = orca.fit(train, mode="supervised", method="ttt", epochs=25)
    ev    = orca.evaluate(cal, cal_split, test_split, deltas=(0.1,))
    lam   = cal.calibrate(cal_split, delta=0.1)          # LTT lambda*
    sched = orca.engine(model, params, cal, n_slots=4,
                        tokens_per_step=8, max_new_tokens=96)
    done, fleet = orca.serve_requests(sched, prompt_token_rows)

``fit``/``evaluate`` work for every registered Calibrator ("ttt",
"static"); ``engine`` needs a calibrator that can hand (ProbeConfig,
theta) to the fused serve step (the TTT probe).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from repro.core.calibrator import (Calibrator, GroupCalibrator, GroupTrace,
                                   StaticCalibrator, TTTCalibrator,
                                   groups_from_trajectories, make_calibrator)
from repro.core.pipeline import ProcedureEval, evaluate_probe
from repro.serving.engine import ServeConfig
from repro.serving.scheduler import OrcaScheduler
from repro.trajectories import TrajectorySet

__all__ = ["Calibrator", "GroupCalibrator", "GroupTrace",
           "StaticCalibrator", "TTTCalibrator",
           "calibrated_lambda", "engine", "evaluate", "fit",
           "groups_from_trajectories", "make_calibrator", "serve_requests"]

DELTAS = (0.05, 0.1, 0.15, 0.2)


def fit(train: TrajectorySet, mode: str = "supervised",
        method: str = "ttt", **kwargs) -> Calibrator:
    """Train a calibrator on ``train``.

    ``method`` picks the implementation ("ttt" — the paper's meta-learned
    probe — or "static"); ``kwargs`` go to its constructor (e.g.
    ``epochs=25, seed=1, pc=ProbeConfig(...)`` for ttt).
    """
    return make_calibrator(method, **kwargs).fit(train, mode)


def evaluate(calibrator: Calibrator, cal: TrajectorySet, test: TrajectorySet,
             *, deltas: Sequence[float] = DELTAS,
             eps: float = 0.05) -> ProcedureEval:
    """LTT-calibrate on ``cal`` and report deployed savings/error on ``test``
    (risk against supervised ground truth — what the paper's tables show)."""
    return evaluate_probe(calibrator.scores(cal), cal,
                          calibrator.scores(test), test,
                          calibrator.mode, deltas, eps=eps,
                          method=calibrator.method)


def calibrated_lambda(calibrator: Calibrator, cal: TrajectorySet,
                      delta: float, *, eps: float = 0.05,
                      fallback: float = math.inf) -> float:
    """``calibrate()`` with ONE policy for "LTT selected nothing".

    The honest default keeps lambda* = inf (never stop early — zero savings,
    zero stopping risk; ``engine()`` then serves with stopping disabled).
    Demos on tiny random-weight models may pass ``fallback=0.99`` (the most
    aggressive grid threshold) to keep eviction observable — an explicit
    opt-out of the guarantee, in one place instead of per driver.
    """
    lam = calibrator.calibrate(cal, delta, eps)
    if not math.isfinite(lam):
        return float(fallback)
    return lam


def engine(model, params, calibrator: Calibrator, *,
           n_slots: int = 4, cache_len: Optional[int] = None,
           lam: Optional[float] = None,
           serve: Optional[ServeConfig] = None,
           paged: bool = False, block_size: int = 16,
           num_blocks: Optional[int] = None,
           chunk_tokens: Optional[int] = None,
           token_budget: Optional[int] = None,
           policy=None, pack_chunks: bool = True,
           pack_max: int = 4,
           group_size: int = 1,
           consensus=None,
           consensus_delta: Optional[float] = None,
           preemption: bool = True,
           **serve_kwargs) -> OrcaScheduler:
    """Build a continuous-batching ``OrcaScheduler`` serving the calibrated
    procedure.

    The threshold comes from an explicit ``serve`` config (exclusive with
    ``lam``/``serve_kwargs``), else ``lam``, else ``calibrator.threshold()``
    (requires a prior ``calibrate()``).  A non-finite lambda* (LTT selected
    nothing) serves with stopping disabled — scores never cross a threshold
    above 1.

    ``paged=True`` serves from a paged KV cache: admission reserves
    fixed-size pages from a ``BlockPool`` of ``num_blocks`` (default: the
    dense-equivalent n_slots * blocks-per-request + null page), resident
    prompts are prefix-shared (refcount bump instead of recompute), ORCA
    stops return pages to the pool immediately and the scheduler keeps
    requests WAITING when the pool is exhausted.

    ``chunk_tokens=N`` enables chunked prefill (stall-free serving): prompt
    prefill becomes schedulable work — each engine iteration packs every
    resident decode token plus up to N prompt tokens of mid-prefill
    residents (``token_budget`` tokens per step total), instead of a
    batch-1 full-prompt prefill stalling the fleet at admission.  With
    ``pack_chunks`` (the default) one fused chunk carries tokens of up to
    ``pack_max`` requests — the tail of one prompt piggybacked with the
    head of the next, block-diagonally isolated — so short prompt tails
    don't leave budget on the table; ``pack_chunks=False`` restores the
    one-request-per-chunk composer through the same step executable.
    ``policy`` picks the scheduling policy ("fifo", "priority", "edf",
    "ttft" or a ``repro.serving.SchedulingPolicy`` instance): admission
    order, the per-step prefill share and — under overload — victim
    selection.  Stop decisions are unchanged by ANY of these knobs;
    TTFT/stall tails and per-prompt-length recompiles go away.

    ``preemption`` (default True) makes the scheduler overload-safe: when
    capacity fails for a unit strictly more urgent than some resident,
    the policy's victims are spilled to host RAM (KV pages AND probe
    fast-weight state, ``engine.Spill``) and restored byte-identically
    once room returns — the SWAPPED queue re-admits before WAITING.
    ``preemption=False`` restores wait-only admission.  Stop decisions
    are invariant under any preemption schedule.

    ``group_size=N`` serves self-consistency groups: ``serve_requests``
    expands each prompt into N gang-admitted samples sharing its prompt
    pages, and ``consensus`` (a calibrated ``GroupCalibrator`` or a raw
    agreement threshold in (0, 1]) enables the conformal consensus stop —
    the moment a group's confidence-weighted answer vote clears the
    threshold, the still-running siblings are CANCELLED mid-flight and
    their pages/slots return to the fleet.  ``consensus_delta`` documents
    (and cross-checks) the risk level the GroupCalibrator was calibrated
    at.  With ``group_size=1`` or ``consensus=None`` the group layer is
    inert: stop decisions are byte-identical to the classic engine.
    """
    if isinstance(group_size, bool) or int(group_size) < 1:
        raise ValueError(
            f"group_size={group_size!r} must be an int >= 1: the number "
            "of self-consistency samples per prompt; fix by passing a "
            "positive count (1 disables grouping)")
    group_size = int(group_size)
    if group_size > n_slots:
        raise ValueError(
            f"group_size={group_size} > n_slots={n_slots}: gang admission "
            "needs every sample of a group resident at once; fix by "
            f"raising n_slots to >= {group_size} or lowering group_size")
    if consensus is not None and group_size == 1:
        raise ValueError(
            "consensus= with group_size=1 can never fire (every request "
            "is its own singleton and a lone sample never votes); fix by "
            "passing group_size >= 2 (or grouping requests yourself via "
            "repro.serving.make_group) or dropping consensus=")
    if consensus_delta is not None:
        if consensus is None:
            raise ValueError(
                "consensus_delta= without consensus= does nothing; fix by "
                "passing consensus=<GroupCalibrator calibrated at delta="
                f"{consensus_delta}> (or a float threshold, and dropping "
                "consensus_delta)")
        if isinstance(consensus, GroupCalibrator) \
                and consensus.delta is not None \
                and not math.isclose(float(consensus.delta),
                                     float(consensus_delta)):
            raise ValueError(
                f"consensus_delta={consensus_delta} does not match the "
                f"GroupCalibrator's calibrated delta={consensus.delta}; "
                "fix by re-running GroupCalibrator.calibrate(..., delta="
                f"{consensus_delta}) or passing consensus_delta="
                f"{consensus.delta}")
    pc, theta = calibrator.serving_params()
    if serve is not None:
        if lam is not None or serve_kwargs:
            raise ValueError("pass either a full ServeConfig via serve= or "
                             "lam=/ServeConfig kwargs, not both")
    else:
        if lam is None:
            lam = calibrator.threshold()
        if not math.isfinite(lam):
            lam = 2.0               # sigmoid scores <= 1: never stop early
        serve = ServeConfig(lam=float(lam), **serve_kwargs)
    sched = OrcaScheduler(model, params, pc, theta, serve,
                          n_slots=n_slots, cache_len=cache_len,
                          paged=paged, block_size=block_size,
                          num_blocks=num_blocks, chunk_tokens=chunk_tokens,
                          token_budget=token_budget, policy=policy,
                          pack_chunks=pack_chunks, pack_max=pack_max,
                          consensus=consensus, preemption=preemption)
    sched.group_size = group_size       # serve_requests' expansion default
    return sched


def serve_requests(scheduler: OrcaScheduler, prompts: np.ndarray,
                   group_size: Optional[int] = None):
    """Convenience: one Request per row of ``prompts`` (N, prompt_len),
    driven through the scheduler.  ``group_size`` (default: the value the
    scheduler was built with via ``engine(group_size=...)``) expands each
    prompt into a gang-admitted self-consistency group.  Returns
    (requests, FleetMetrics)."""
    from repro.serving.groups import make_group
    from repro.serving.request import make_request
    if group_size is None:
        group_size = getattr(scheduler, "group_size", 1)
    if group_size > 1:
        reqs = [r for i in range(len(prompts))
                for r in make_group(np.asarray(prompts[i]), group_size,
                                    group_id=i)]
    else:
        reqs = [make_request(np.asarray(prompts[i]))
                for i in range(len(prompts))]
    return scheduler.run(reqs)
