"""GQA attention: prefill (einsum / blockwise-flash), decode (full-cache,
sequence-sharded flash-decode, ring-buffer sliding window), int8 KV cache.

Layouts
-------
q:      (B, S, H, d_head)
k, v:   (B, S, KV, d_head)
cache:  {"k","v"}: (B, KV, S_cache, d_head)  (+ "k_scale","v_scale" for int8)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import parallel

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV cache (de)quantization

def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(position, head) absmax int8 quantization. x: (..., d_head)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_cache(cfg, batch: int, length: int, n_layers: Optional[int] = None,
               abstract: bool = False) -> Dict[str, jnp.ndarray]:
    """Stacked-layer KV cache: (L, B, KV, S, d_head)."""
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, batch, cfg.n_kv_heads, length, cfg.d_head)
    if cfg.kv_cache_dtype == "int8":
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
             (lambda s, d: jnp.zeros(s, d))
        return {"k": mk(shape, jnp.int8), "v": mk(shape, jnp.int8),
                "k_scale": mk(shape[:-1] + (1,), jnp.float32),
                "v_scale": mk(shape[:-1] + (1,), jnp.float32)}
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    return {"k": mk(shape, dt), "v": mk(shape, dt)}


def cache_write(cache_l: Dict[str, jnp.ndarray], k: jnp.ndarray, v: jnp.ndarray,
                slot: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write one token into a per-layer cache slice (B, KV, S, dh) at ``slot``."""
    def upd(buf, val):
        # val: (B, KV, d) -> (B, KV, 1, d)
        return jax.lax.dynamic_update_slice_in_dim(buf, val[:, :, None, :], slot, axis=2)
    out = dict(cache_l)
    if "k_scale" in cache_l:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        out["k"] = upd(cache_l["k"], kq)
        out["v"] = upd(cache_l["v"], vq)
        out["k_scale"] = upd(cache_l["k_scale"], ks)
        out["v_scale"] = upd(cache_l["v_scale"], vs)
    else:
        out["k"] = upd(cache_l["k"], k.astype(cache_l["k"].dtype))
        out["v"] = upd(cache_l["v"], v.astype(cache_l["v"].dtype))
    return out


def cache_write_stacked(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                        vs: jnp.ndarray, slot: jnp.ndarray
                        ) -> Dict[str, jnp.ndarray]:
    """Write one token for ALL layers at once: cache (L,B,KV,S,dh),
    ks/vs (L,B,KV,dh).  One in-place (donated) update outside the layer scan
    instead of copying the cache through scan outputs (§Perf C2).

    ``slot`` is a scalar (the whole batch writes the same position — classic
    static batching) or a (B,) vector (continuous batching: each batch row
    sits at its own sequence position and writes its own slot)."""
    slot = jnp.asarray(slot)
    if slot.ndim == 1:
        iB = jnp.arange(slot.shape[0])

        def upd(buf, val):
            # advanced indices (batch row, per-row slot) sit at axes 1 and 3;
            # jax moves them to the front, so the scattered value is
            # (B, L, KV, dh) — a per-row scatter, not a full-buffer rewrite.
            # mode="drop": rows routed out of range (parked / mid-prefill
            # slots under a write mask) simply don't write
            return buf.at[:, iB, :, slot, :].set(
                val.transpose(1, 0, 2, 3).astype(buf.dtype), mode="drop")
    else:
        def upd(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val[:, :, :, None, :], slot, axis=3)
    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def decode_valid_mask(pos: jnp.ndarray, batch: int, s_cache: int,
                      window: Optional[int] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cache write slot + readable-entry mask for one decode step.

    ``pos`` is a scalar (every batch row at the same length — static
    batching) or a (B,) vector (continuous batching: per-row absolute
    positions).  Returns (slot, valid) with slot scalar or (B,) matching
    ``pos`` and valid (B, s_cache).

    Without a window: slot = pos, valid = [0, pos).  With a window the cache
    is a ring buffer: index i holds the most recent position p <= pos with
    p % window == i, readable iff that position exists AND is < pos (the pos
    entry is stale until the post-scan write).
    """
    pos = jnp.asarray(pos, jnp.int32)
    idxs = jnp.arange(s_cache)
    pos_col = pos[:, None] if pos.ndim == 1 else pos[None, None]
    if window is not None:
        slot = jnp.mod(pos, window)
        stored = pos_col - jnp.mod(pos_col - idxs[None, :], window)
        valid = (stored >= 0) & (stored < pos_col)
    else:
        slot = pos
        valid = idxs[None, :] < pos_col
    return slot, jnp.broadcast_to(valid, (batch, s_cache))


def cache_kv(cache_l: Dict[str, jnp.ndarray], dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "k_scale" in cache_l:
        return (dequantize_kv(cache_l["k"], cache_l["k_scale"], dtype),
                dequantize_kv(cache_l["v"], cache_l["v_scale"], dtype))
    return cache_l["k"], cache_l["v"]


# ---------------------------------------------------------------------------
# Paged KV cache: a pool of fixed-size token blocks shared by all requests.
#
# Layout: {"k","v"}: (L, P, KV, bs, d_head) — P physical pages; each batch
# row reads/writes through its block table (B, nb): virtual position j lives
# in page table[j // bs] at offset j % bs.  Page 0 is the NULL page
# (``repro.serving.kv_pool.NULL_BLOCK``): parked slots point at it so their
# no-op writes can't corrupt a reallocated page.

def init_paged_cache(cfg, num_blocks: int, block_size: int,
                     n_layers: Optional[int] = None,
                     abstract: bool = False) -> Dict[str, jnp.ndarray]:
    """Stacked-layer paged KV pool: (L, P, KV, bs, d_head)."""
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, num_blocks, cfg.n_kv_heads, block_size, cfg.d_head)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    if cfg.kv_cache_dtype == "int8":
        return {"k": mk(shape, jnp.int8), "v": mk(shape, jnp.int8),
                "k_scale": mk(shape[:-1] + (1,), jnp.float32),
                "v_scale": mk(shape[:-1] + (1,), jnp.float32)}
    dt = jnp.dtype(cfg.dtype)
    return {"k": mk(shape, dt), "v": mk(shape, dt)}


def cache_write_paged(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                      vs: jnp.ndarray, block_tables: jnp.ndarray,
                      pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write one token for ALL layers through the block tables.

    cache k/v (L, P, KV, bs, dh); ks/vs (L, B, KV, dh); block_tables
    (B, nb) int32; ``pos`` scalar or (B,) — each row writes page
    ``table[b, pos_b // bs]`` at offset ``pos_b % bs``.  Same single
    donated-buffer scatter shape as ``cache_write_stacked``."""
    bs = cache["k"].shape[3]
    B = ks.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    iB = jnp.arange(B)
    page = block_tables[iB, pos // bs]            # (B,) physical page ids
    off = pos % bs

    def upd(buf, val):
        # advanced indices (page, offset) at axes 1 and 3 move to the
        # front: scattered value is (B, L, KV, dh) — per-row page write
        return buf.at[:, page, :, off, :].set(
            val.transpose(1, 0, 2, 3).astype(buf.dtype))

    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def chunk_write_positions(pos_start: jnp.ndarray, chunk_len: jnp.ndarray,
                          c: int, s_cache: int) -> jnp.ndarray:
    """Target positions for a C-token prefill chunk: ``pos_start + i`` for
    real tokens, ``s_cache`` (out of range — the scatter drops the write)
    for padding past ``chunk_len``."""
    i = jnp.arange(c)
    return jnp.where(i < chunk_len, jnp.asarray(pos_start, jnp.int32) + i,
                     s_cache)


def cache_write_chunk(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                      vs: jnp.ndarray, rows: jnp.ndarray,
                      pos_start: jnp.ndarray, chunk_len: jnp.ndarray
                      ) -> Dict[str, jnp.ndarray]:
    """Write one prefill chunk's K/V for ALL layers into the ``rows`` lanes
    of a dense stacked cache.

    cache (L,B,KV,S,dh); ks/vs (L,Bc,KV,C,dh); rows (Bc,) batch lanes;
    positions [pos_start, pos_start+chunk_len) receive the chunk, padded
    chunk positions are routed out of range and dropped."""
    s_cache = cache["k"].shape[3]
    c = ks.shape[3]
    wpos = chunk_write_positions(pos_start, chunk_len, c, s_cache)
    r = jnp.asarray(rows, jnp.int32)[:, None]        # (Bc, 1)
    w = wpos[None, :]                                # (1, C)

    def upd(buf, val):
        # advanced indices at axes 1 and 3 broadcast to (Bc, C) and move to
        # the front: the scattered value is (Bc, C, L, KV, dh)
        return buf.at[:, r, :, w, :].set(
            val.transpose(1, 3, 0, 2, 4).astype(buf.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def cache_write_chunk_paged(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                            vs: jnp.ndarray, block_rows: jnp.ndarray,
                            pos_start: jnp.ndarray, chunk_len: jnp.ndarray
                            ) -> Dict[str, jnp.ndarray]:
    """Paged variant of :func:`cache_write_chunk`: virtual position
    ``pos_start + i`` of request ``b`` lands in page
    ``block_rows[b, (pos_start+i) // bs]`` at offset ``(pos_start+i) % bs``;
    padded chunk positions are routed to the NULL page (page 0 — scratch by
    construction, never allocated to a request)."""
    bs = cache["k"].shape[3]
    c = ks.shape[3]
    block_rows = jnp.asarray(block_rows, jnp.int32)  # (Bc, nb)
    bc, nb = block_rows.shape
    i = jnp.arange(c)
    vpos = jnp.asarray(pos_start, jnp.int32) + i
    blk = jnp.clip(vpos // bs, 0, nb - 1)
    off = vpos % bs
    real = (i < chunk_len)[None, :]                  # (1, C)
    page = jnp.where(real, block_rows[jnp.arange(bc)[:, None], blk[None, :]],
                     0)                              # (Bc, C); 0 = NULL page
    off_b = jnp.broadcast_to(off[None, :], (bc, c))

    def upd(buf, val):
        # advanced indices (page, offset) at axes 1 and 3 -> value (Bc, C,
        # L, KV, dh); duplicate NULL targets may race, NULL is scratch
        return buf.at[:, page, :, off_b, :].set(
            val.transpose(1, 3, 0, 2, 4).astype(buf.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def gather_cache_rows(cache_l: Dict[str, jnp.ndarray], rows: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-layer dense cache lanes for a prefill chunk: (Bc, KV, S, d) f32
    (int8 lanes dequantized)."""
    rows = jnp.asarray(rows, jnp.int32)
    k = cache_l["k"][rows].astype(jnp.float32)
    v = cache_l["v"][rows].astype(jnp.float32)
    if "k_scale" in cache_l:
        k = k * cache_l["k_scale"][rows].astype(jnp.float32)
        v = v * cache_l["v_scale"][rows].astype(jnp.float32)
    return k, v


def gather_page_rows(cache_l: Dict[str, jnp.ndarray],
                     block_tables: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-layer paged K/V gathered through block tables into contiguous
    virtual caches: (Bc, KV, nb*bs, d) f32."""
    bt = jnp.asarray(block_tables, jnp.int32)
    bc, nb = bt.shape
    n_kv, bs = cache_l["k"].shape[1], cache_l["k"].shape[2]

    def gather(key, scale_key):
        g = cache_l[key][bt].astype(jnp.float32)     # (Bc, nb, KV, bs, d')
        if scale_key in cache_l:
            g = g * cache_l[scale_key][bt].astype(jnp.float32)
        return g.transpose(0, 2, 1, 3, 4).reshape(bc, n_kv, nb * bs, -1)

    return gather("k", "k_scale"), gather("v", "v_scale")


def _merge_kv_block(qc, o, l, m, k_blk, v_blk, mask):
    """Fold a block of keys into unnormalized online-softmax partials.

    qc (B,KV,G,C,d) f32; o (B,KV,G,C,d); l/m (B,KV,G,C); k_blk/v_blk
    (B,KV,T,d); mask (C,T) — the causal-within-chunk mask.  The chunked-
    prefill sibling of ``_merge_extra_kv`` (T keys per query row instead of
    one)."""
    d = qc.shape[-1]
    s = jnp.einsum("bkgcd,bktd->bkgct", qc, k_blk) \
        / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_x = jnp.max(s, axis=-1)
    m_f = jnp.maximum(m, m_x)
    # NEG_INF is finite (-1e30): exp underflows to exactly 0, flushing the
    # garbage partials a fully-masked cache pass accumulates
    w_c = jnp.exp(m - m_f)
    p = jnp.exp(s - m_f[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    o = o * w_c[..., None] + jnp.einsum("bkgct,bktd->bkgcd", p, v_blk)
    l = l * w_c + jnp.sum(p, axis=-1)
    return o, l


def attn_prefill_chunk(q, k_new, v_new, cache_l: Dict[str, jnp.ndarray],
                       valid: jnp.ndarray, dtype, *, rows=None,
                       block_tables=None, impl: Optional[str] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Chunked-prefill attention: a C-token query chunk of each request
    attends to its already-written cache positions plus causally within the
    chunk.

    q (Bc, C, H, d); k_new/v_new (Bc, C, KV, d) — the chunk's own K/V (not
    yet in the cache); cache_l — per-layer dense cache (Bfull, KV, S, dh)
    read through ``rows`` (Bc,), or paged pools (P, KV, bs, dh) read
    through ``block_tables`` (Bc, nb); valid (Bc, S_virtual) marks readable
    cache positions ([0, pos_start) — stale/unwritten entries masked).
    Padded chunk positions (beyond the real chunk length) produce garbage
    rows whose K/V writes are dropped downstream; causality keeps real
    queries from attending padded keys.  Returns (Bc, C, H, d).

    The paged path has two impls mirroring ``attn_decode_paged``:
    ``jnp`` gathers pages and runs one full softmax over [cache | chunk]
    (numerically closest to full prefill), ``pallas`` runs the q-block > 1
    ``paged_flash_prefill_chunk`` kernel over the pages and folds the
    within-chunk block into its unnormalized partials.
    """
    b, c, h, d = q.shape
    n_kv = k_new.shape[2]
    g = h // n_kv
    qc = q.reshape(b, c, n_kv, g, d).transpose(0, 2, 3, 1, 4) \
        .astype(jnp.float32)                         # (B, KV, G, C, d)
    kb = k_new.transpose(0, 2, 1, 3).astype(jnp.float32)   # (B, KV, C, d)
    vb = v_new.transpose(0, 2, 1, 3).astype(jnp.float32)
    causal = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
    paged = block_tables is not None
    impl = impl or (default_paged_impl() if paged else "jnp")
    if paged and impl == "pallas":
        from repro.kernels import ops as K           # deferred: no cycle
        interp = K.default_interpret() if interpret is None else interpret
        o, l, m = K.paged_flash_prefill_chunk(
            q.astype(jnp.float32), cache_l["k"], cache_l["v"], block_tables,
            valid, cache_l.get("k_scale"), cache_l.get("v_scale"),
            interpret=interp)
        o, l = _merge_kv_block(qc, o, l, m, kb, vb, causal)
        out = o / jnp.maximum(l, 1e-30)[..., None]
    else:
        if paged:
            k_c, v_c = gather_page_rows(cache_l, block_tables)
        else:
            k_c, v_c = gather_cache_rows(cache_l, rows)
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
        sc_c = jnp.einsum("bkgcd,bksd->bkgcs", qc, k_c) * scale
        sc_c = jnp.where(valid[:, None, None, None, :], sc_c, NEG_INF)
        sc_n = jnp.einsum("bkgcd,bktd->bkgct", qc, kb) * scale
        sc_n = jnp.where(causal[None, None, None], sc_n, NEG_INF)
        # ONE softmax over [cache | chunk] — the same full-row softmax
        # shape as attn_prefill_einsum, so chunked == full prefill up to
        # reduction order (exactly, for non-quantized caches)
        p = jax.nn.softmax(jnp.concatenate([sc_c, sc_n], axis=-1), axis=-1)
        s_len = k_c.shape[2]
        out = jnp.einsum("bkgcs,bksd->bkgcd", p[..., :s_len], v_c) \
            + jnp.einsum("bkgct,bktd->bkgcd", p[..., s_len:], vb)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, h, d).astype(dtype)


def packed_chunk_mask(seg: jnp.ndarray, valid_tok: jnp.ndarray,
                      ancestors: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Block-diagonal mask for a PACKED chunk's within-chunk keys.

    Without ``ancestors`` (chunked prefill, linear verify): token i may
    attend chunk token j iff both belong to the same segment (request),
    j precedes i in the chunk (segments are laid out contiguously in
    request order, so this is exactly per-request causality) and j is a
    real token (padding never serves as a key).

    With ``ancestors`` (C,) — per-token parent pointers into the chunk,
    root tokens pointing at THEMSELVES — each segment's tokens form a
    candidate TREE instead of a chain (tree speculative decode): token i
    may attend chunk token j iff j lies on i's root path (i itself, its
    parent, its parent's parent, ...).  The closure is computed by
    following parent pointers to their fixpoint, so the width-1 tree
    (ancestors[i] = i - 1 within each segment) reproduces the causal
    chain mask bit for bit.  seg (C,), valid_tok (C,) -> (C, C)."""
    seg = jnp.asarray(seg, jnp.int32)
    c = seg.shape[0]
    i = jnp.arange(c)
    base = ((seg[:, None] == seg[None, :])
            & jnp.asarray(valid_tok, bool)[None, :])
    if ancestors is None:
        return base & (i[None, :] <= i[:, None])
    anc = jnp.asarray(ancestors, jnp.int32)

    def walk(_, carry):
        cur, reach = carry
        cur = anc[cur]
        return cur, reach | (cur[:, None] == i[None, :])

    _, reach = jax.lax.fori_loop(
        0, c, walk, (i, i[:, None] == i[None, :]))
    return base & reach


def _merge_packed_block(qg, o, l, m, k_new, v_new, mask):
    """Fold a packed chunk's own keys into per-token unnormalized partials.

    qg (C,KV,G,d) f32; o (C,KV,G,d); l/m (C,KV,G); k_new/v_new (C,KV,d) —
    the chunk's freshly-projected K/V (not yet in any cache); mask (C,C)
    the block-diagonal chunk mask.  The packed sibling of
    ``_merge_kv_block``: every token is its own query row with its own
    key-visibility row.  Tokens whose cache pass was fully masked (a
    prompt head with nothing written yet) carry m = NEG_INF partials which
    ``exp(m - m_f)`` flushes to exact zeros here."""
    d = qg.shape[-1]
    kb = k_new.transpose(1, 0, 2).astype(jnp.float32)      # (KV, C, d)
    vb = v_new.transpose(1, 0, 2).astype(jnp.float32)
    s = jnp.einsum("ckgd,ktd->ckgt", qg, kb) \
        / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m_x = jnp.max(s, axis=-1)
    m_f = jnp.maximum(m, m_x)
    w_c = jnp.exp(m - m_f)
    p = jnp.exp(s - m_f[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    o = o * w_c[..., None] + jnp.einsum("ckgt,ktd->ckgd", p, vb)
    l = l * w_c + jnp.sum(p, axis=-1)
    return o, l


def attn_prefill_packed(q, k_new, v_new, cache_l: Dict[str, jnp.ndarray],
                        seg: jnp.ndarray, seg_starts: jnp.ndarray,
                        chunk_mask: jnp.ndarray, dtype, *, rows=None,
                        seg_tables=None, impl: Optional[str] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Packed multi-request chunk attention: C chunk tokens belonging to up
    to R requests ("segments") each attend THEIR OWN request's
    already-written cache positions plus, under the block-diagonal
    ``chunk_mask``, the chunk tokens of their own segment that precede
    them.  Tokens of different requests never attend each other — the
    cross-request structure is block-diagonal end to end.

    q (C, H, d); k_new/v_new (C, KV, d) — the chunk's own K/V (not yet in
    the cache); seg (C,) segment id per token; seg_starts (R,) each
    segment's prefill progress (its readable-cache prefix); cache_l —
    per-layer dense cache (Bfull, KV, S, dh) read through ``rows`` (C,)
    per-token batch lanes, or paged pools (P, KV, bs, dh) read through
    ``seg_tables`` (R, nb) per-segment block-table rows.  Returns
    (C, H, d).

    The paged path mirrors ``attn_prefill_chunk``: ``jnp`` gathers pages
    and runs ONE softmax over [cache | chunk] per token (numerically the
    full-prefill shape), ``pallas`` runs ``paged_flash_packed_chunk``
    (each page DMA'd once per segment for the whole chunk) and folds the
    within-chunk block into its unnormalized partials."""
    c, h, d = q.shape
    n_kv = k_new.shape[1]
    g = h // n_kv
    qg = q.reshape(c, n_kv, g, d).astype(jnp.float32)
    kb = k_new.transpose(1, 0, 2).astype(jnp.float32)      # (KV, C, d)
    vb = v_new.transpose(1, 0, 2).astype(jnp.float32)
    seg = jnp.asarray(seg, jnp.int32)
    seg_starts = jnp.asarray(seg_starts, jnp.int32)
    paged = seg_tables is not None
    impl = impl or (default_paged_impl() if paged else "jnp")
    if paged and impl == "pallas":
        from repro.kernels import ops as K           # deferred: no cycle
        interp = K.default_interpret() if interpret is None else interpret
        bs = cache_l["k"].shape[2]
        n_virtual = seg_tables.shape[1] * bs
        seg_valid = jnp.arange(n_virtual)[None, :] < seg_starts[:, None]
        o, l, m = K.paged_flash_packed_chunk(
            q.astype(jnp.float32), cache_l["k"], cache_l["v"], seg,
            seg_tables, seg_valid, cache_l.get("k_scale"),
            cache_l.get("v_scale"), interpret=interp)
        o, l = _merge_packed_block(qg, o, l, m, k_new, v_new, chunk_mask)
        out = o / jnp.maximum(l, 1e-30)[..., None]
    else:
        if paged:
            k_c, v_c = gather_page_rows(cache_l, seg_tables[seg])
        else:
            k_c, v_c = gather_cache_rows(cache_l, rows)
        valid = jnp.arange(k_c.shape[2])[None, :] < seg_starts[seg][:, None]
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
        sc_c = jnp.einsum("ckgd,cksd->ckgs", qg, k_c) * scale
        sc_c = jnp.where(valid[:, None, None, :], sc_c, NEG_INF)
        sc_n = jnp.einsum("ckgd,ktd->ckgt", qg, kb) * scale
        sc_n = jnp.where(chunk_mask[:, None, None, :], sc_n, NEG_INF)
        # ONE softmax over [cache | chunk] per token — the same full-row
        # softmax shape as attn_prefill_chunk, so packed == unpacked ==
        # full prefill up to reduction order
        p = jax.nn.softmax(jnp.concatenate([sc_c, sc_n], axis=-1), axis=-1)
        s_len = k_c.shape[2]
        out = jnp.einsum("ckgs,cksd->ckgd", p[..., :s_len], v_c) \
            + jnp.einsum("ckgt,ktd->ckgd", p[..., s_len:], vb)
    return out.reshape(c, h, d).astype(dtype)


def cache_write_packed(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                       vs: jnp.ndarray, rows: jnp.ndarray,
                       wpos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write a PACKED chunk's K/V for ALL layers into a dense stacked
    cache: every chunk token targets its own (batch lane, position).

    cache (L,B,KV,S,dh); ks/vs (L,KV,C,dh); rows (C,) per-token batch
    lanes; wpos (C,) per-token target positions — padding tokens are
    routed out of range (>= S) and dropped by the scatter."""
    rows = jnp.asarray(rows, jnp.int32)
    wpos = jnp.asarray(wpos, jnp.int32)

    def upd(buf, val):
        # advanced indices (lane, position) at axes 1 and 3 move to the
        # front: the scattered value is (C, L, KV, dh)
        return buf.at[:, rows, :, wpos, :].set(
            val.transpose(2, 0, 1, 3).astype(buf.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def cache_write_packed_paged(cache: Dict[str, jnp.ndarray], ks: jnp.ndarray,
                             vs: jnp.ndarray, tok_tables: jnp.ndarray,
                             wpos: jnp.ndarray, valid_tok: jnp.ndarray
                             ) -> Dict[str, jnp.ndarray]:
    """Paged variant of :func:`cache_write_packed`: chunk token t lands in
    page ``tok_tables[t, wpos_t // bs]`` at offset ``wpos_t % bs``;
    padding tokens are routed to the NULL page (page 0 — scratch by
    construction, never allocated to a request).

    cache k/v (L,P,KV,bs,dh); ks/vs (L,KV,C,dh); tok_tables (C, nb)
    per-token block-table rows; wpos (C,) virtual positions; valid_tok
    (C,) marks real tokens."""
    bs = cache["k"].shape[3]
    c = ks.shape[2]
    tok_tables = jnp.asarray(tok_tables, jnp.int32)
    nb = tok_tables.shape[1]
    wpos = jnp.asarray(wpos, jnp.int32)
    blk = jnp.clip(wpos // bs, 0, nb - 1)
    off = wpos % bs
    page = jnp.where(jnp.asarray(valid_tok, bool),
                     tok_tables[jnp.arange(c), blk], 0)   # 0 = NULL page

    def upd(buf, val):
        # advanced indices (page, offset) at axes 1 and 3 -> value (C, L,
        # KV, dh); duplicate NULL targets may race, NULL is scratch
        return buf.at[:, page, :, off, :].set(
            val.transpose(2, 0, 1, 3).astype(buf.dtype), mode="drop")

    out = dict(cache)
    if "k_scale" in cache:
        kq, ksc = quantize_kv(ks)
        vq, vsc = quantize_kv(vs)
        out["k"] = upd(cache["k"], kq)
        out["v"] = upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ksc)
        out["v_scale"] = upd(cache["v_scale"], vsc)
    else:
        out["k"] = upd(cache["k"], ks.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], vs.astype(cache["v"].dtype))
    return out


def prefill_to_pages(pages: Dict[str, jnp.ndarray],
                     prefill_cache: Dict[str, jnp.ndarray],
                     block_row: jnp.ndarray, n_blocks: int
                     ) -> Dict[str, jnp.ndarray]:
    """Scatter ONE request's prefilled dense cache into its pages.

    ``prefill_cache`` leaves are (L, 1, KV, S_pad, dh) with S_pad a multiple
    of the page size; the first ``n_blocks`` entries of ``block_row`` (nb,)
    receive the prompt K/V, page by page.  int8 prefills carry their scales
    through unchanged (same quantization as the dense path)."""
    bs = pages["k"].shape[3]
    out = dict(pages)
    for key in pages:
        src = prefill_cache[key]                  # (L, 1, KV, S_pad, d')
        L, _, KV, s_pad, dl = src.shape
        src = src.reshape(L, KV, s_pad // bs, bs, dl)[:, :, :n_blocks]
        src = src.transpose(0, 2, 1, 3, 4)        # (L, nb, KV, bs, d')
        out[key] = out[key].at[:, block_row[:n_blocks]].set(
            src.astype(out[key].dtype))
    return out


def copy_pages(pages: Dict[str, jnp.ndarray], src: jnp.ndarray,
               dst: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Copy physical pages ``src`` -> ``dst`` (1-D index arrays) across all
    layers — the copy-on-write step when a new sharer takes a private copy
    of a donor's partially-filled tail page."""
    return {key: buf.at[:, dst].set(buf[:, src]) for key, buf in pages.items()}


def paged_valid_mask(pos: jnp.ndarray, batch: int, n_virtual: int
                     ) -> jnp.ndarray:
    """Readable virtual positions for a paged decode step: [0, pos) per row.

    Masked-off entries also cover NULL/stale table entries: a position is
    only ever readable after this request wrote it (same stale-KV argument
    as the dense per-slot cache)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))
    return jnp.arange(n_virtual)[None, :] < pos[:, None]


def attn_decode_paged(q, cache_l: Dict[str, jnp.ndarray],
                      block_tables: jnp.ndarray, valid: jnp.ndarray,
                      dtype, extra_kv=None, *,
                      impl: Optional[str] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Decode attention through a block table.  q (B,H,d); cache_l per-layer
    pages {"k","v": (P,KV,bs,d)} READ-ONLY; valid (B, nb*bs); extra_kv the
    current token's (k, v) each (B,KV,d).

    ``impl="jnp"`` gathers pages with a jnp take and reuses the dense
    online-softmax (bit-identical to the dense path when nb*bs equals the
    dense cache length); ``impl="pallas"`` runs the paged flash-decode
    kernel (the TPU hot path — pages are DMA'd through the scalar-prefetched
    block table, never materialized contiguously).  Default comes from
    ``REPRO_PAGED_ATTN`` (jnp off-TPU, pallas on TPU).
    """
    b, h, d = q.shape
    impl = impl or default_paged_impl()
    qg = q.reshape(b, cache_l["k"].shape[1], h // cache_l["k"].shape[1], d
                   ).astype(jnp.float32)
    if impl == "pallas":
        from repro.kernels import ops as K           # deferred: no cycle
        interp = K.default_interpret() if interpret is None else interpret
        o, l, m = K.paged_flash_decode(
            q.astype(jnp.float32), cache_l["k"], cache_l["v"], block_tables,
            valid, cache_l.get("k_scale"), cache_l.get("v_scale"),
            interpret=interp, return_partials=True)
    elif impl == "jnp":
        n_kv = cache_l["k"].shape[1]
        bs = cache_l["k"].shape[2]
        nb = block_tables.shape[1]
        bt = jnp.asarray(block_tables, jnp.int32)

        def gather(key, scale_key):
            g = cache_l[key][bt]                     # (B, nb, KV, bs, d')
            if scale_key in cache_l:
                g = (g.astype(jnp.float32)
                     * cache_l[scale_key][bt].astype(jnp.float32)
                     ).astype(jnp.bfloat16)          # bf16 dequant (§Perf C3)
            return g.transpose(0, 2, 1, 3, 4).reshape(b, n_kv, nb * bs, -1)

        k = gather("k", "k_scale")
        v = gather("v", "v_scale")
        o, l, m = _decode_partial(qg, k, v, valid)
    else:
        raise ValueError(f"unknown paged attention impl {impl!r} "
                         "(expected 'jnp' or 'pallas')")
    o, l = _merge_extra_kv(qg, o, l, m, extra_kv, d)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(dtype)


def default_paged_impl() -> str:
    """jnp gather off-TPU, the Pallas paged kernel on TPU;
    ``REPRO_PAGED_ATTN=jnp|pallas`` overrides (parity tests force both)."""
    import os
    forced = os.environ.get("REPRO_PAGED_ATTN")
    if forced:
        return forced
    import jax as _jax
    return "pallas" if _jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Prefill attention

def _gqa_split(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, S, H, d) -> (B, S, KV, G, d)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attn_prefill_einsum(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Reference O(S^2)-memory attention. q (B,Sq,H,d); k,v (B,Sk,KV,d)."""
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    qg = _gqa_split(q, n_kv)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attn_prefill_blockwise(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           q_block: int = 512, kv_block: int = 512,
                           differentiable: bool = False) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure JAX (O(S*block) memory).

    Sequential lax.scan over Q blocks; inner fori_loop over KV blocks with a
    dynamic upper bound so causally-dead blocks are skipped (same FLOPs as a
    TPU flash kernel).  This is the scalable path used in the dry-run; the
    Pallas kernel in repro/kernels/flash_attention.py is the TPU hot path.
    """
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    nq, nk = s // q_block, s // kv_block
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kT = k.astype(jnp.float32).transpose(0, 2, 3, 1)   # (B,KV,d,S)
    vT = v.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,KV,S,d)

    def q_step(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=1)
        qb = _gqa_split(qb.astype(jnp.float32), n_kv) * scale  # (B,qb,KV,G,d)
        qb = qb.transpose(0, 2, 3, 1, 4)                       # (B,KV,G,qb,d)
        qpos = qi * q_block + jnp.arange(q_block)

        def kv_step(ki, carry):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kT, ki * kv_block, kv_block, axis=3)
            vb = jax.lax.dynamic_slice_in_dim(vT, ki * kv_block, kv_block, axis=2)
            sc = jnp.einsum("bkgqd,bkds->bkgqs", qb, kb)
            kpos = ki * kv_block + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgqs,bksd->bkgqd", p, vb)
            return m_new, l_new, acc_new

        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, d), jnp.float32)
        if differentiable:
            # static-trip scan over ALL kv blocks (masked): reverse-mode safe.
            # Each block is rematerialized in the backward pass (otherwise the
            # scan stores every (bq x bk) probability block — the memory the
            # flash formulation exists to avoid).
            def kv_scan(carry, ki):
                return kv_step(ki, carry), None
            kv_scan = jax.checkpoint(kv_scan, prevent_cse=False)
            (m, l, acc), _ = jax.lax.scan(kv_scan, (m0, l0, a0), jnp.arange(nk))
        else:
            # dynamic bounds skip causally-dead blocks (flash-kernel FLOPs)
            if causal:
                hi = (qi * q_block + q_block + kv_block - 1) // kv_block
                hi = jnp.minimum(hi, nk)
            else:
                hi = nk
            lo = 0
            if window is not None:
                lo = jnp.maximum(qi * q_block - (window - 1), 0) // kv_block
            m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, q_block, h, d)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def attn_prefill(q, k, v, *, causal: bool = True, window: Optional[int] = None):
    impl = parallel.attn_impl()
    if impl == "blockwise":
        qb = min(512, q.shape[1])
        kb = min(512, k.shape[1])
        if q.shape[1] % qb == 0 and k.shape[1] % kb == 0:
            # training (remat on) needs the reverse-mode-safe static scan;
            # pure prefill keeps the dynamic-bound block skipping.
            return attn_prefill_blockwise(
                q, k, v, causal=causal, window=window, q_block=qb, kv_block=kb,
                differentiable=parallel.remat_enabled())
    return attn_prefill_einsum(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Decode attention (single query against a READ-ONLY cache + current token)
#
# The cache never flows through the layer scan as an output: each layer
# attends to the (stale-slot-masked) cache plus the freshly-projected
# (k, v) of the current token passed as ``extra_kv``; the single in-place
# cache write for all layers happens outside the scan (donated buffer).
# This removes the full-cache copy per decode step (§Perf iteration C2).

def _decode_partial(qg, k, v, valid):
    """Unnormalized online-softmax pieces over the cache.
    Returns (o_un (B,KV,G,d), l (B,KV,G), m (B,KV,G)).

    k/v may be bf16 (int8 caches are dequantized to bf16 to halve the
    transient copy — §Perf C3; the Pallas flash_decode kernel dequantizes
    per VMEM block on TPU so no HBM-sized temp exists at all there);
    contractions accumulate in f32."""
    d = qg.shape[-1]
    sc = jnp.einsum("bkgd,bksd->bkgs", qg.astype(k.dtype), k,
                    preferred_element_type=jnp.float32)
    sc = sc / jnp.sqrt(d).astype(jnp.float32)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)   # guard exp(-inf - -inf)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, l, m


def _decode_core(qg, k, v, valid) -> jnp.ndarray:
    o, l, m = _decode_partial(qg, k, v, valid)
    return o / jnp.maximum(l, 1e-30)[..., None]


def _merge_extra_kv(qg, o, l, m, extra_kv, d):
    """Fold the current token's (k, v) column into unnormalized online-
    softmax partials (o, l, m).  Shared by the dense and paged paths."""
    if extra_kv is None:
        return o, l
    k_x, v_x = extra_kv
    k_x = k_x.astype(jnp.float32)
    v_x = v_x.astype(jnp.float32)
    s_x = jnp.einsum("bkgd,bkd->bkg", qg, k_x) / jnp.sqrt(d).astype(jnp.float32)
    m_f = jnp.maximum(m, s_x)
    w_c = jnp.where(jnp.isfinite(m), jnp.exp(m - m_f), 0.0)
    w_x = jnp.exp(s_x - m_f)
    o = o * w_c[..., None] + w_x[..., None] * v_x[:, :, None, :]
    l = l * w_c + w_x
    return o, l


def attn_decode(q, cache_l, valid, dtype, extra_kv=None) -> jnp.ndarray:
    """q (B,H,d); cache_l per-layer dict (B,KV,S,d) READ-ONLY; valid (B,S);
    extra_kv: optional (k_new, v_new) each (B,KV,d) — the current token."""
    b, h, d = q.shape
    k, v = cache_kv(cache_l, jnp.bfloat16)   # bf16 dequant (§Perf C3)
    n_kv = k.shape[1]
    qg = q.reshape(b, n_kv, h // n_kv, d).astype(jnp.float32)
    ctx = parallel.current_ctx()
    seq_shardable = (ctx is not None and
                     k.shape[2] % ctx.mesh.shape[ctx.model_axis] == 0)
    if ctx is not None and ctx.flash_decode and seq_shardable:
        o, l, m = _flash_decode_sharded(ctx, qg, k, v, valid)
    else:
        o, l, m = _decode_partial(qg, k, v, valid)
    o, l = _merge_extra_kv(qg, o, l, m, extra_kv, d)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(dtype)


def _flash_decode_sharded(ctx, qg, k, v, valid):
    """Sequence-sharded flash-decode: KV cache sharded over the model axis on
    the sequence dim; each shard computes a partial online softmax which is
    combined with pmax/psum (one collective round instead of gathering the
    cache).  Returns unnormalized (o, l, m) so the caller can merge the
    current token's column."""
    mesh = ctx.mesh
    ax = ctx.model_axis
    dspec = ctx.rules.get("batch")

    def shard_fn(qg, k, v, valid):
        o_loc, l_loc, m_loc = _decode_partial(qg, k, v, valid)
        m_glb = jax.lax.pmax(m_loc, ax)
        scale = jnp.where(jnp.isfinite(m_loc), jnp.exp(m_loc - m_glb), 0.0)
        l_glb = jax.lax.psum(l_loc * scale, ax)
        o_glb = jax.lax.psum(o_loc * scale[..., None], ax)
        return o_glb, l_glb, m_glb

    from jax.sharding import PartitionSpec as P
    return parallel.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(dspec), P(dspec, None, ax), P(dspec, None, ax), P(dspec, ax)),
        out_specs=(P(dspec), P(dspec), P(dspec)),
        check_vma=False,
    )(qg, k, v, valid)
