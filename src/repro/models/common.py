"""Shared model building blocks (pure-JAX, no flax).

Parameters are declared once as ``Param`` leaves carrying shape, initializer
and *logical axes*; ``init_params`` instantiates them (works under
``jax.eval_shape`` for the allocation-free dry-run) and ``param_specs``
derives the matching ``PartitionSpec`` tree for any sharding policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

# ---------------------------------------------------------------------------
# Param declarations


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axes, len == ndim
    init: str = "normal"                  # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(p: Param, key) -> jnp.ndarray:
    dt = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    if p.init == "normal":
        fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1], 1)
        std = p.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dt)
    if p.init == "embed":
        return (jax.random.normal(key, p.shape, jnp.float32) * 0.02 * p.scale).astype(dt)
    if p.init == "small":
        return (jax.random.normal(key, p.shape, jnp.float32) * 0.02 * p.scale).astype(dt)
    raise ValueError(p.init)


def is_param(x) -> bool:
    return isinstance(x, Param)


def init_params(decls, rng) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_param)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [_leaf_init(p, k) for p, k in zip(leaves, keys)])


def param_specs(decls, rules: Dict[str, Any]):
    """Map logical axes -> PartitionSpec tree under a rules dict."""
    def one(p: Param) -> PartitionSpec:
        return PartitionSpec(*[rules.get(a) if a is not None else None for a in p.axes])
    return jax.tree.map(one, decls, is_leaf=is_param)


def param_shapes(decls):
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)),
                        decls, is_leaf=is_param)


def stack_decls(decls, n: int, axis_name: Optional[str] = None):
    """Add a leading stacked-layer dim to every declaration (for lax.scan)."""
    def one(p: Param) -> Param:
        return dataclasses.replace(p, shape=(n,) + p.shape, axes=(axis_name,) + p.axes)
    return jax.tree.map(one, decls, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Numerics helpers

def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_decls(cfg, name: str = "norm"):
    if cfg.norm == "rmsnorm":
        return {"scale": Param((cfg.d_model,), (None,), "ones")}
    return {"scale": Param((cfg.d_model,), (None,), "ones"),
            "bias": Param((cfg.d_model,), (None,), "zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary position embeddings (supports partial rotary)

def rope_frequencies(d_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rotary_pct: float = 1.0) -> jnp.ndarray:
    """x: (..., seq, n_heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    d_rot = int(d_head * rotary_pct)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = rope_frequencies(d_rot, theta)                      # (d_rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, d_rot/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def relu_sq(x):
    r = jax.nn.relu(x)
    return r * r


# ---------------------------------------------------------------------------
# Cross-entropy with vocab-sharded logits

def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (B,S,V) [possibly vocab-sharded], targets (B,S) int32.

    Written so a vocab-sharded logits tensor never gets all-gathered
    (§Perf iteration B1): the gold logit is extracted with a masked
    reduction over the vocab axis (shard-local + small all-reduce) instead
    of take_along_axis, and logsumexp reduces over the vocab axis the same
    way.  The f32 upcast happens per-element inside the reductions.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    hit = vocab_iota == targets[..., None]
    gold = jnp.sum(jnp.where(hit, lf, 0.0), axis=-1)
    loss = lse - gold
    if mask is not None:
        loss = loss * mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
