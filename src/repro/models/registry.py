"""Model registry: one uniform API over the six architecture families.

``build(cfg)`` returns a ``Model`` with pure functions:

    init(rng) -> params
    forward(params, batch) -> (logits, hidden, aux_loss)       # teacher forced
    loss(params, batch) -> (loss, metrics)
    prefill(params, batch, cache_len) -> (state, last_hidden, hidden)
    decode_step(params, token, state, pos) -> (logits, hidden, state)
    init_decode_state(batch, cache_len, abstract) -> state pytree
    make_batch(rng, shape) / batch_specs(shape) -> example inputs (real / SDS)

Decode-state geometry (ring-buffer SWA vs full cache vs recurrent state) is
resolved here from the config + input shape, so launchers and the serving
engine are architecture-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import InputShape, ModelConfig
from repro.models import attention as attn
from repro.models import common, hymba, rwkv6, transformer, whisper
from repro.models.common import init_params, param_specs, softmax_xent


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    decls: Any
    forward: Callable
    prefill: Callable
    decode_step: Callable
    init_decode_state: Callable
    decode_geometry: Callable    # shape -> (cache_len, window)
    # paged-KV serving (None for families without a paged cache layout):
    # (batch, num_blocks, block_size, max_blocks, abstract) -> state pytree
    # whose cache leaves are page pools + a per-row "block_tables" array
    init_paged_state: Optional[Callable] = None
    # chunked prefill (None for families without one): (cfg, params, tokens,
    # state, rows, pos_start, chunk_len, block_rows=None) -> state — the
    # serving engine's unified token-budget step schedules prompt prefill
    # through it in fixed-shape chunks instead of at admission time
    prefill_chunk: Optional[Callable] = None
    # PACKED chunked prefill (None for families without one): (cfg, params,
    # tokens (C,), state, seg (C,), slots (R,), starts (R,), lengths (R,),
    # block_rows=None) -> state — one fused chunk carrying tokens of up to
    # R requests (tail of one prompt + head of the next), block-diagonal
    # isolated; the single-segment call IS the unpacked chunk path, so the
    # unified step serves both through ONE executable
    prefill_packed: Optional[Callable] = None
    # speculative decode (None for families without it): the VERIFY pass
    # — ``prefill_packed`` with the LM head kept: (cfg, params, tokens (C,),
    # state, seg, slots, starts, lengths, block_rows=None) -> (logits
    # (C, vocab), hidden (C, d), state); position j of each segment scores
    # the next token after consuming draft token j
    verify_packed: Optional[Callable] = None
    # draft source for speculative decode: (cfg, params, state, token (B,),
    # pos (B,), k) -> (B, k - 1) int32 proposed continuations; the default
    # dense drafter repeats the last token (degenerate n-gram), the replay
    # model drafts from its own trajectory
    draft: Optional[Callable] = None
    # TREE speculative verify (None for families without it): the packed
    # verify pass over a candidate token TREE — (cfg, params, tokens (C,),
    # state, seg, slots, starts, lengths, depths (C,), ancestors (C,),
    # block_rows=None) -> (logits (C, vocab), hidden (C, d), ks, vs); the
    # cache write is DEFERRED (ks/vs returned instead) because same-depth
    # siblings share a position — the engine commits only the accepted
    # root-to-leaf path through ``commit_kv``
    verify_tree: Optional[Callable] = None
    # lands a deferred verify chunk's K/V: (cfg, state, ks, vs, slots, seg,
    # positions (C,), valid (C,), block_rows=None) -> state.  ``valid`` is
    # True exactly for the accepted path; families without a KV cache
    # (replay) no-op
    commit_kv: Optional[Callable] = None
    # tree draft source: (cfg, params, state, token (B,), pos (B,), width,
    # depth) -> (B, width, depth) int32 — the device-side FALLBACK when the
    # serving layer's shared draft cache misses (hits arrive as traced data
    # through the spec descriptor and override per slot)
    draft_tree: Optional[Callable] = None
    # True when ``draft`` is the degenerate repeat-last-token self-draft —
    # the signal for the serving layer to put the fleet-wide shared draft
    # cache in front of it (families with a real drafter, e.g. replay's
    # trajectory oracle, keep theirs unless a cache is injected explicitly)
    self_draft: bool = False

    @property
    def supports_paged(self) -> bool:
        return self.init_paged_state is not None

    @property
    def supports_chunked(self) -> bool:
        return self.prefill_chunk is not None and self.prefill_packed is not None

    @property
    def supports_spec(self) -> bool:
        return (self.verify_packed is not None and self.draft is not None
                and self.supports_chunked)

    @property
    def supports_tree(self) -> bool:
        return (self.verify_tree is not None and self.draft_tree is not None
                and self.commit_kv is not None and self.supports_spec)

    # ------------------------------------------------------------------
    def init(self, rng) -> Any:
        return init_params(self.decls, rng)

    def abstract_params(self):
        return common.param_shapes(self.decls)

    def specs(self, rules: Dict[str, Any]):
        return param_specs(self.decls, rules)

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, hidden, aux = self.forward(self.cfg, params, batch)
        targets = batch["targets"]
        logits = logits[:, -targets.shape[1]:]
        # exclude vocab padding from the softmax with an elementwise iota
        # mask — a scatter here would force GSPMD to all-gather the
        # vocab-sharded logits (§Perf iteration B1)
        vpad, v = self.cfg.padded_vocab(), self.cfg.vocab_size
        if vpad != v:
            pad = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1) >= v
            logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
        xent = softmax_xent(logits, targets, batch.get("mask"))
        loss = xent + aux
        return loss, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------------
    def text_len(self, shape: InputShape) -> int:
        """Tokens fed as text so total model sequence == shape.seq_len."""
        s = shape.seq_len
        if self.cfg.arch_type == "vlm":
            s -= self.cfg.frontend.n_tokens
        if self.cfg.n_meta_tokens:
            s -= self.cfg.n_meta_tokens
        return max(s, 8)

    def batch_specs(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        return self._batch(shape, abstract=True)

    def make_batch(self, rng, shape: InputShape) -> Dict[str, jnp.ndarray]:
        return self._batch(shape, abstract=False, rng=rng)

    def _batch(self, shape: InputShape, abstract: bool, rng=None):
        cfg = self.cfg
        B = shape.global_batch
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)

        def toks(shp, key):
            if abstract:
                return jax.ShapeDtypeStruct(shp, i32)
            return jax.random.randint(key, shp, 0, cfg.vocab_size, i32)

        def dense_arr(shp, key):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dt)
            return jax.random.normal(key, shp, jnp.float32).astype(dt)

        keys = (jax.random.split(rng, 4) if rng is not None else [None] * 4)
        out: Dict[str, Any] = {}
        if shape.kind in ("train", "prefill"):
            st = self.text_len(shape)
            out["tokens"] = toks((B, st), keys[0])
            if shape.kind == "train":
                out["targets"] = toks((B, st), keys[1])
            if cfg.arch_type == "vlm":
                out["patch_embeds"] = dense_arr(
                    (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim), keys[2])
            if cfg.arch_type == "audio":
                out["frames"] = dense_arr(
                    (B, cfg.frontend.n_tokens, cfg.d_model), keys[2])
        else:  # decode
            out["token"] = toks((B,), keys[0])
            if abstract:
                out["pos"] = jax.ShapeDtypeStruct((), i32)
            else:
                out["pos"] = jnp.asarray(shape.seq_len - 1, i32)
        return out


# ---------------------------------------------------------------------------
# family adapters

# contexts up to this length decode with the NATIVE full cache; the
# sliding-window variant is only the documented long_500k carve-out
NATIVE_DECODE_MAX = 131_072


def _dense_geometry(cfg):
    def geom(shape: InputShape):
        if shape.kind != "decode":
            return shape.seq_len, None
        if (cfg.long_context_variant == "sliding"
                and shape.seq_len > NATIVE_DECODE_MAX):
            w = cfg.long_context_window
            return w, w
        return shape.seq_len, None
    return geom


def _build_dense(cfg: ModelConfig) -> Model:
    geom = _dense_geometry(cfg)

    def init_decode_state(batch: int, cache_len: int, abstract: bool = False):
        return attn.init_cache(cfg, batch, cache_len, abstract=abstract)

    def init_paged_state(batch: int, num_blocks: int, block_size: int,
                         max_blocks: int, abstract: bool = False):
        pages = attn.init_paged_cache(cfg, num_blocks, block_size,
                                      abstract=abstract)
        if abstract:
            bt = jax.ShapeDtypeStruct((batch, max_blocks), jnp.int32)
        else:
            bt = jnp.zeros((batch, max_blocks), jnp.int32)   # -> NULL page
        return dict(pages, block_tables=bt)

    def decode_step(cfg, params, token, state, pos, window=None,
                    write_mask=None):
        return transformer.decode_step(cfg, params, token, state, pos,
                                       window=window, write_mask=write_mask)

    return Model(cfg=cfg, decls=transformer.decls(cfg),
                 forward=transformer.forward,
                 prefill=transformer.prefill,
                 decode_step=decode_step,
                 init_decode_state=init_decode_state,
                 decode_geometry=geom,
                 init_paged_state=init_paged_state,
                 prefill_chunk=transformer.prefill_chunk,
                 prefill_packed=transformer.prefill_packed_chunk,
                 verify_packed=transformer.verify_packed_chunk,
                 draft=transformer.draft_tokens,
                 verify_tree=transformer.verify_packed_tree,
                 commit_kv=transformer.commit_packed_kv,
                 draft_tree=transformer.draft_tree_tokens,
                 self_draft=True)


def _build_rwkv(cfg: ModelConfig) -> Model:
    def init_decode_state(batch: int, cache_len: int, abstract: bool = False):
        st = rwkv6.init_state(cfg, batch)
        if abstract:
            st = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)
        return st

    def geom(shape: InputShape):
        return 1, None            # O(1) recurrent state

    def decode_step(cfg, params, token, state, pos, window=None,
                    write_mask=None):
        return rwkv6.decode_step(cfg, params, token, state, pos)

    return Model(cfg=cfg, decls=rwkv6.decls(cfg), forward=rwkv6.forward,
                 prefill=rwkv6.prefill, decode_step=decode_step,
                 init_decode_state=init_decode_state, decode_geometry=geom)


def _build_hymba(cfg: ModelConfig) -> Model:
    def init_decode_state(batch: int, cache_len: int, abstract: bool = False):
        st = hymba.init_state(cfg, batch, cache_len)
        if abstract:
            st = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)
        return st

    def geom(shape: InputShape):
        if shape.kind != "decode":
            return shape.seq_len, None
        w = cfg.sliding_window or shape.seq_len
        return min(w, shape.seq_len), w

    def decode_step(cfg, params, token, state, pos, window=None,
                    write_mask=None):
        return hymba.decode_step(cfg, params, token, state, pos)

    return Model(cfg=cfg, decls=hymba.decls(cfg), forward=hymba.forward,
                 prefill=hymba.prefill, decode_step=decode_step,
                 init_decode_state=init_decode_state, decode_geometry=geom)


def _build_whisper(cfg: ModelConfig) -> Model:
    def init_decode_state(batch: int, cache_len: int, abstract: bool = False):
        self_cache = attn.init_cache(cfg, batch, cache_len, abstract=abstract)
        f = cfg.frontend.n_tokens
        shp = (cfg.n_layers, batch, cfg.n_heads, f, cfg.d_head)
        dt = jnp.dtype(cfg.dtype)
        if abstract:
            mk = lambda: jax.ShapeDtypeStruct(shp, dt)
        else:
            mk = lambda: jnp.zeros(shp, dt)
        return {"self": self_cache, "cross_k": mk(), "cross_v": mk()}

    def geom(shape: InputShape):
        return shape.seq_len, None

    def decode_step(cfg, params, token, state, pos, window=None,
                    write_mask=None):
        return whisper.decode_step(cfg, params, token, state, pos)

    return Model(cfg=cfg, decls=whisper.decls(cfg), forward=whisper.forward,
                 prefill=whisper.prefill, decode_step=decode_step,
                 init_decode_state=init_decode_state, decode_geometry=geom)


def build(cfg: ModelConfig) -> Model:
    if cfg.arch_type in ("dense", "moe", "vlm"):
        return _build_dense(cfg)
    if cfg.arch_type == "ssm":
        return _build_rwkv(cfg)
    if cfg.arch_type == "hybrid":
        return _build_hymba(cfg)
    if cfg.arch_type == "audio":
        return _build_whisper(cfg)
    raise ValueError(cfg.arch_type)
