"""Decoder-only transformer covering the dense / moe / vlm families.

Layers are homogeneous and stacked (leading ``L`` dim) so the layer loop is a
``lax.scan`` — compile time and HLO size are O(1) in depth, which matters for
the 40-pair dry-run.  Optional activation checkpointing wraps the scanned
body.  The VLM variant consumes precomputed anyres patch embeddings through a
learned projector (vision tower stubbed per the assignment carve-out).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import parallel
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (Param, apply_norm, apply_rope, cdtype, gelu,
                                 norm_decls, stack_decls, swiglu)


# ---------------------------------------------------------------------------
# Declarations

def _attn_decls(cfg) -> Dict[str, Param]:
    d, qo, kvo = cfg.d_model, cfg.attn_out_dim, cfg.kv_out_dim
    out = {
        "wq": Param((d, qo), ("embed", "qkv")),
        "wk": Param((d, kvo), ("embed", "kv_qkv")),
        "wv": Param((d, kvo), ("embed", "kv_qkv")),
        "wo": Param((qo, d), ("qkv", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = Param((qo,), ("qkv",), "zeros")
        out["bk"] = Param((kvo,), ("kv_qkv",), "zeros")
        out["bv"] = Param((kvo,), ("kv_qkv",), "zeros")
    return out


def _mlp_decls(cfg) -> Dict[str, Param]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"w_gate": Param((d, f), ("embed", "mlp")),
                "w_up": Param((d, f), ("embed", "mlp")),
                "w_down": Param((f, d), ("mlp", "embed"))}
    return {"w_in": Param((d, f), ("embed", "mlp")),
            "b_in": Param((f,), ("mlp",), "zeros"),
            "w_out": Param((f, d), ("mlp", "embed")),
            "b_out": Param((d,), (None,), "zeros")}


def layer_decls(cfg) -> Dict[str, Any]:
    out = {"ln1": norm_decls(cfg), "ln2": norm_decls(cfg),
           "attn": _attn_decls(cfg)}
    out["mlp"] = moe_mod.moe_decls(cfg) if cfg.moe is not None else _mlp_decls(cfg)
    return out


def decls(cfg) -> Dict[str, Any]:
    vpad = cfg.padded_vocab()
    tree: Dict[str, Any] = {
        "embed": Param((vpad, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_norm": norm_decls(cfg),
        "layers": stack_decls(layer_decls(cfg), cfg.n_layers, "layers"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = Param((cfg.d_model, vpad), ("embed", "vocab"))
    if cfg.arch_type == "vlm":
        vd = cfg.frontend.embed_dim
        tree["projector"] = {
            "w1": Param((vd, cfg.d_model), (None, "embed")),
            "b1": Param((cfg.d_model,), (None,), "zeros"),
            "w2": Param((cfg.d_model, cfg.d_model), ("embed", "embed2")),
            "b2": Param((cfg.d_model,), (None,), "zeros"),
        }
    if cfg.n_meta_tokens:
        tree["meta_tokens"] = Param((cfg.n_meta_tokens, cfg.d_model),
                                    (None, "embed"), "embed")
    return tree


# ---------------------------------------------------------------------------
# Blocks

def mlp_apply(cfg, p, x):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        h = swiglu(x @ p["w_gate"].astype(dt), x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    h = x @ p["w_in"].astype(dt) + p["b_in"].astype(dt)
    h = gelu(h) if cfg.mlp == "gelu" else h
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


def _qkv(cfg, p, x):
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def layer_prefill(cfg, p, x, positions, window: Optional[int]):
    """x (B,S,d) -> (x', (k,v)) for the cache."""
    b, s, d = x.shape
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = _qkv(cfg, p["attn"], h)
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    q = parallel.constrain(q, "batch", None, "heads", None)
    o = attn.attn_prefill(q, k, v, causal=True, window=window)
    o = o.reshape(b, s, cfg.attn_out_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + parallel.constrain(o, "batch", None, None)
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        m, aux = moe_mod.moe_block(p["mlp"], h, cfg)
    else:
        m, aux = mlp_apply(cfg, p["mlp"], h), jnp.float32(0)
    x = x + parallel.constrain(m, "batch", None, None)
    # cache entries in (B, KV, S, dh) layout
    return x, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)), aux


def layer_decode(cfg, p, x, cache_l, pos, valid, block_tables=None):
    """x (B,d); cache_l per-layer (B,KV,S,dh) READ-ONLY — or, with
    ``block_tables`` (B,nb), per-layer pages (P,KV,bs,dh) read through the
    table; pos (B,) absolute positions; valid (B,S) masks readable cache
    entries (current slot excluded — the new token's (k, v) attends via
    extra_kv and is written into the cache once, outside the layer scan)."""
    b, d = x.shape
    h = apply_norm(cfg, p["ln1"], x[:, None, :])[:, 0]
    q, k, v = _qkv(cfg, p["attn"], h)
    q = q.reshape(b, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta, cfg.rotary_pct)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta, cfg.rotary_pct)[:, 0]
    if block_tables is not None:
        o = attn.attn_decode_paged(q, cache_l, block_tables, valid, x.dtype,
                                   extra_kv=(k, v))
    else:
        o = attn.attn_decode(q, cache_l, valid, x.dtype, extra_kv=(k, v))
    o = o.reshape(b, cfg.attn_out_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + o
    h = apply_norm(cfg, p["ln2"], x[:, None, :])
    if cfg.moe is not None:
        m, _ = moe_mod.moe_block(p["mlp"], h, cfg)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    return x + m[:, 0], (k, v)


def layer_prefill_chunk(cfg, p, x, cache_l, rows, block_rows, positions,
                        valid):
    """One layer of chunked prefill: x (Bc, C, d) at absolute ``positions``
    (Bc, C); the chunk attends to readable cache entries (``valid``) plus
    causally within itself.  Returns (x', (k, v)) with k/v (Bc, KV, C, dh)
    for the page-by-page cache write outside the scan."""
    b, c, d = x.shape
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = _qkv(cfg, p["attn"], h)
    q = q.reshape(b, c, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, c, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, c, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    o = attn.attn_prefill_chunk(q, k, v, cache_l, valid, x.dtype,
                                rows=rows, block_tables=block_rows)
    o = o.reshape(b, c, cfg.attn_out_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + o
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        m, _ = moe_mod.moe_block(p["mlp"], h, cfg)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    return x + m, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))


def prefill_chunk(cfg, params, tokens, state, rows, pos_start, chunk_len,
                  block_rows=None):
    """Chunked prefill: run C prompt tokens of each request through the
    stack and write their K/V into the request's resident cache, resuming
    at ``pos_start`` (the request's ``prefill_progress``).

    tokens (Bc, C) int32, zero-padded past ``chunk_len``; rows (Bc,) batch
    rows of ``state`` (dense stacked cache — or, when the state carries
    ``block_tables``, the paged pool written through ``block_rows``
    (Bc, nb), the request's physical pages).  ``pos_start``/``chunk_len``
    are traced scalars, so ONE compiled executable covers every chunk of
    every prompt length.  Queries attend to already-written positions
    [0, pos_start) plus causally within the chunk; padded positions beyond
    ``chunk_len`` have their K/V writes dropped (dense: out-of-range
    scatter; paged: routed to the NULL page).  Text-only prompts (no vlm /
    meta-token prefix — those keep the one-shot ``prefill`` path).
    Returns the updated state."""
    bc, c = tokens.shape
    pos_start = jnp.asarray(pos_start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    rows = jnp.asarray(rows, jnp.int32)
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(pos_start + jnp.arange(c)[None, :], (bc, c))
    paged = "block_tables" in state
    if paged:
        assert block_rows is not None, "paged prefill_chunk needs block rows"
        scanned = {k: v for k, v in state.items() if k != "block_tables"}
        n_virtual = block_rows.shape[1] * scanned["k"].shape[3]
    else:
        scanned = state
        n_virtual = state["k"].shape[3]
    valid = jnp.broadcast_to(jnp.arange(n_virtual)[None, :] < pos_start,
                             (bc, n_virtual))

    def body(x, xs):
        p_l, cache_l = xs
        x, kv = layer_prefill_chunk(cfg, p_l, x, cache_l, rows, block_rows,
                                    positions, valid)
        return x, kv

    _, (ks, vs) = jax.lax.scan(body, x, (params["layers"], scanned))
    # ks/vs (L, Bc, KV, C, dh): one write for all layers, outside the scan
    if paged:
        pages = attn.cache_write_chunk_paged(scanned, ks, vs, block_rows,
                                             pos_start, chunk_len)
        return dict(pages, block_tables=state["block_tables"])
    return attn.cache_write_chunk(state, ks, vs, rows, pos_start, chunk_len)


def layer_prefill_packed(cfg, p, x, cache_l, rows, seg_tables, positions,
                         seg, seg_starts, chunk_mask):
    """One layer of PACKED chunked prefill: x (1, C, d) holds C tokens of
    up to R requests at per-token absolute ``positions`` (C,); each token
    attends its own request's readable cache prefix plus its own segment's
    preceding chunk tokens (``chunk_mask``).  Returns (x', (k, v)) with
    k/v (KV, C, dh) for the per-token cache write outside the scan."""
    _, c, d = x.shape
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = _qkv(cfg, p["attn"], h)
    q = q.reshape(1, c, cfg.n_heads, cfg.d_head)
    k = k.reshape(1, c, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(1, c, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions[None], cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions[None], cfg.rope_theta, cfg.rotary_pct)
    o = attn.attn_prefill_packed(q[0], k[0], v[0], cache_l, seg, seg_starts,
                                 chunk_mask, x.dtype, rows=rows,
                                 seg_tables=seg_tables)
    o = o.reshape(1, c, cfg.attn_out_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + o
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        m, _ = moe_mod.moe_block(p["mlp"], h, cfg)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    return x + m, (k[0].transpose(1, 0, 2), v[0].transpose(1, 0, 2))


def _packed_chunk_core(cfg, params, tokens, state, seg, slots, starts,
                       lengths, block_rows=None, *, depths=None,
                       ancestors=None, write=True):
    """Shared body of ``prefill_packed_chunk`` / ``verify_packed_chunk`` /
    ``verify_packed_tree``: run one fused C-token packed chunk through the
    stack, scatter each token's K/V into its own request's resident cache,
    and return ``(new_state, x, ks, vs)`` with x (1, C, d) the post-stack
    activations (the layer scan computes them either way; prefill merely
    discards them) and ks/vs (L, KV, C, dh) the chunk's own K/V.

    The default shape of a segment is a causal CHAIN at positions
    starts[r] + 0..len-1.  ``depths``/``ancestors`` (C,) generalize it to
    a candidate TREE (speculative multi-draft verify): per-token position
    becomes starts[seg] + depths and the within-chunk mask follows the
    ancestor closure instead of layout order.  ``write=False`` DEFERS the
    cache write entirely (tree verify: same-depth siblings share a target
    position, so only the accepted root-to-leaf path may land — the
    caller commits it through ``commit_packed_kv`` once acceptance is
    known; within-chunk attention never reads the cache for chunk tokens,
    so the forward is write-order independent)."""
    c = tokens.shape[0]
    seg = jnp.asarray(seg, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(lengths)[:-1]])
    off = jnp.arange(c, dtype=jnp.int32) - offsets[seg]
    valid_tok = (off >= 0) & (off < lengths[seg])
    positions = starts[seg] + (off if depths is None
                               else jnp.asarray(depths, jnp.int32))  # (C,)
    rows = slots[seg]                                    # (C,)
    chunk_mask = attn.packed_chunk_mask(seg, valid_tok, ancestors=ancestors)
    x = embed_tokens(cfg, params, tokens[None])          # (1, C, d)
    paged = "block_tables" in state
    if paged:
        assert block_rows is not None, "paged packed prefill needs block rows"
        scanned = {k: v for k, v in state.items() if k != "block_tables"}
        seg_tables = jnp.asarray(block_rows, jnp.int32)        # (R, nb)
    else:
        scanned = state
        n_virtual = state["k"].shape[3]      # dense padding-drop sentinel
        seg_tables = None

    def body(x, xs):
        p_l, cache_l = xs
        x, kv = layer_prefill_packed(cfg, p_l, x, cache_l, rows, seg_tables,
                                     positions, seg, starts, chunk_mask)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], scanned))
    if not write:
        return state, x, ks, vs
    # ks/vs (L, KV, C, dh): one per-token write for all layers
    if paged:
        pages = attn.cache_write_packed_paged(scanned, ks, vs,
                                              seg_tables[seg],
                                              positions, valid_tok)
        return dict(pages, block_tables=state["block_tables"]), x, ks, vs
    wpos = jnp.where(valid_tok, positions, n_virtual)    # padding dropped
    return attn.cache_write_packed(state, ks, vs, rows, wpos), x, ks, vs


def prefill_packed_chunk(cfg, params, tokens, state, seg, slots, starts,
                         lengths, block_rows=None):
    """PACKED chunked prefill: run one fused C-token chunk carrying prompt
    tokens of up to R requests through the stack and scatter each token's
    K/V into ITS OWN request's resident cache.

    tokens (C,) int32 — the chunk, segments laid out contiguously in
    request order, zero-padded at the tail; seg (C,) int32 — segment id
    per token; slots (R,) batch rows; starts (R,) each segment's prefill
    progress (= its readable cache prefix AND the absolute position of its
    first chunk token); lengths (R,) tokens each segment contributes (0 =
    unused segment).  Dense states scatter through per-token (lane,
    position); a state carrying ``block_tables`` writes through
    ``block_rows`` (R, nb), each segment's reserved physical pages.  All
    of seg/slots/starts/lengths are traced data, so ONE compiled
    executable covers every packing shape of every prompt length — the
    single-segment call IS the unpacked chunk path.  Returns the updated
    state."""
    state, _, _, _ = _packed_chunk_core(cfg, params, tokens, state, seg,
                                        slots, starts, lengths,
                                        block_rows=block_rows)
    return state


def verify_packed_chunk(cfg, params, tokens, state, seg, slots, starts,
                        lengths, block_rows=None):
    """Speculative VERIFY pass: the packed-chunk forward with the language
    head kept.  Layout and cache semantics are ``prefill_packed_chunk``
    verbatim — each segment is one request's draft block (current token +
    proposed continuations) at absolute positions starts[r]..starts[r]+L-1,
    attending its own committed cache prefix plus causally within the
    block — but the post-stack activations feed final_norm + the LM head,
    so position j of each segment scores the model's next token after
    consuming draft token j.  Rejected positions need no undo: validity
    masks derived from ``pos`` hide them and the next verify block
    overwrites them in place before ``pos`` ever reaches them.  Returns
    (logits (C, vocab), hidden (C, d), new_state)."""
    state, x, _, _ = _packed_chunk_core(cfg, params, tokens, state, seg,
                                        slots, starts, lengths,
                                        block_rows=block_rows)
    h = apply_norm(cfg, params["final_norm"], x)[0]       # (C, d)
    logits = logits_from_hidden(cfg, params, h)
    return logits, h, state


def verify_packed_tree(cfg, params, tokens, state, seg, slots, starts,
                       lengths, depths, ancestors, block_rows=None):
    """TREE speculative verify: the packed-chunk forward where each
    segment carries a candidate token TREE instead of a chain.

    Layout stays the packed-chunk contract (segments contiguous, tails
    dropped by ``lengths``) but two per-token arrays reshape the segment:
    ``depths`` (C,) — each token's depth in its tree, so its absolute
    position is starts[r] + depth (same-depth siblings SHARE a position,
    exactly as the committed sequence would) — and ``ancestors`` (C,) —
    parent pointers into the chunk (roots self-pointing), so each token
    attends its own root path instead of everything before it.  Position
    j of each node scores the model's next token after consuming that
    node's root-to-node path.

    The cache write is DEFERRED: same-depth siblings would race on one
    (lane, position) target and a rejected sibling could shadow the
    accepted token, so nothing lands here — the caller computes
    acceptance from the logits and commits ONLY the accepted root-to-leaf
    path through ``commit_packed_kv``.  Validity masks still expose just
    [0, pos), so the missing writes are unobservable within the step.
    Returns (logits (C, vocab), hidden (C, d), ks, vs) with ks/vs
    (L, KV, C, dh) the chunk's uncommitted K/V."""
    _, x, ks, vs = _packed_chunk_core(cfg, params, tokens, state, seg,
                                      slots, starts, lengths,
                                      block_rows=block_rows, depths=depths,
                                      ancestors=ancestors, write=False)
    h = apply_norm(cfg, params["final_norm"], x)[0]       # (C, d)
    logits = logits_from_hidden(cfg, params, h)
    return logits, h, ks, vs


def commit_packed_kv(cfg, state, ks, vs, slots, seg, positions, valid,
                     block_rows=None):
    """Land a verify chunk's DEFERRED K/V (``verify_packed_tree``) into
    the resident caches: chunk token t writes its (lane, position) target
    iff ``valid[t]`` — the engine sets it True exactly for the accepted
    root-to-leaf path, whose targets are unique by construction (one node
    per depth), so the scatter is race-free.  ks/vs (L, KV, C, dh);
    slots (R,); seg (C,); positions (C,) absolute targets."""
    seg = jnp.asarray(seg, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    positions = jnp.asarray(positions, jnp.int32)
    valid = jnp.asarray(valid, bool)
    if "block_tables" in state:
        assert block_rows is not None, "paged tree commit needs block rows"
        scanned = {k: v for k, v in state.items() if k != "block_tables"}
        seg_tables = jnp.asarray(block_rows, jnp.int32)
        pages = attn.cache_write_packed_paged(scanned, ks, vs,
                                              seg_tables[seg],
                                              positions, valid)
        return dict(pages, block_tables=state["block_tables"])
    n_virtual = state["k"].shape[3]
    wpos = jnp.where(valid, positions, n_virtual)        # rejected dropped
    return attn.cache_write_packed(state, ks, vs, slots[seg], wpos)


def draft_tree_tokens(cfg, params, state, token, pos, width, depth):
    """Default tree self-draft, the ``draft_tokens`` fallback lifted to a
    (width, depth) tree: every branch repeats the last committed token.
    Only reached when the serving layer's shared draft cache MISSES (the
    cache is the real drafter for dense families); keeps the step total —
    a miss costs nothing and accepts whatever it happens to get right.
    token (B,) int32; returns (B, width, depth) int32."""
    b = token.shape[0]
    return jnp.broadcast_to(token[:, None, None], (b, width, depth))


def draft_tokens(cfg, params, state, token, pos, k):
    """Default self-draft: propose ``k - 1`` repeats of the last committed
    token (the degenerate n-gram drafter — zero extra forwards, zero extra
    state; acceptance pays for whatever it gets right).  Families with a
    cheaper oracle (e.g. the replay model, which drafts from its own
    trajectory) override this on ``Model.draft``.  token (B,) int32;
    returns (B, k - 1) int32 draft continuations."""
    b = token.shape[0]
    return jnp.broadcast_to(token[:, None], (b, k - 1))


# ---------------------------------------------------------------------------
# Embedding / logits

def embed_tokens(cfg, params, tokens):
    e = params["embed"].astype(cdtype(cfg))
    return jnp.take(e, tokens, axis=0)


def logits_from_hidden(cfg, params, h):
    dt = h.dtype
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(dt).T
    else:
        logits = h @ params["lm_head"].astype(dt)
    axes = ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)
    return parallel.constrain(logits, *axes)


def project_patches(cfg, params, patch_embeds):
    p = params["projector"]
    dt = cdtype(cfg)
    h = gelu(patch_embeds.astype(dt) @ p["w1"].astype(dt) + p["b1"].astype(dt))
    return h @ p["w2"].astype(dt) + p["b2"].astype(dt)


# ---------------------------------------------------------------------------
# Full forward passes

def _scan_layers(cfg, params, x, positions, window, collect_kv: bool = True):
    ctx = parallel.current_ctx()

    def body(x, p_l):
        x, kv, aux = layer_prefill(cfg, p_l, x, positions, window)
        return x, ((kv, aux) if collect_kv else (None, aux))

    if ctx is not None and ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (kvs, auxs) = jax.lax.scan(body, x, params["layers"])
    return x, kvs, jnp.sum(auxs)


def forward(cfg, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward. Returns (logits, hidden, aux_loss).

    batch: {"tokens": (B, S_text)} + optional {"patch_embeds"} (vlm).
    """
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    prefix = []
    if cfg.arch_type == "vlm":
        prefix.append(project_patches(cfg, params, batch["patch_embeds"]))
    if cfg.n_meta_tokens:
        meta = params["meta_tokens"].astype(x.dtype)
        prefix.append(jnp.broadcast_to(meta[None], (x.shape[0],) + meta.shape))
    if prefix:
        x = jnp.concatenate(prefix + [x], axis=1)
    x = parallel.constrain(x, "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, aux = _scan_layers(cfg, params, x, positions, cfg.sliding_window,
                             collect_kv=False)
    h = apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, h)
    return logits, h, aux


def prefill(cfg, params, batch, cache_len: int):
    """Run the prompt, build the KV cache. Returns (cache, last_hidden, h_all)."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.arch_type == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([project_patches(cfg, params, batch["patch_embeds"]), x], 1)
    if cfg.n_meta_tokens:
        meta = params["meta_tokens"].astype(x.dtype)
        x = jnp.concatenate(
            [jnp.broadcast_to(meta[None], (x.shape[0],) + meta.shape), x], 1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, kvs, _ = _scan_layers(cfg, params, x, positions, cfg.sliding_window)
    h = apply_norm(cfg, params["final_norm"], x)
    # place prefix kv into cache of length cache_len
    k, v = kvs                                    # (L,B,KV,S,dh)
    cache = attn.init_cache(cfg, b, cache_len)
    if "k_scale" in cache:
        kq, ks = attn.quantize_kv(k)
        vq, vs = attn.quantize_kv(v)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, axis=3)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, axis=3)
        cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, 0, axis=3)
        cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, 0, axis=3)
    else:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=3)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=3)
    return cache, h[:, -1], h


def decode_step(cfg, params, token, cache, pos, *, window: Optional[int] = None,
                write_mask: Optional[jnp.ndarray] = None):
    """One-token decode. token (B,); pos int32 — scalar (whole batch at one
    shared length: static batching) or (B,) vector (continuous batching:
    every batch row sits at its own absolute position).

    With ``window`` set, the cache is a ring buffer of size window and
    ``slot = pos % window``; otherwise slot = pos.  A cache carrying
    ``block_tables`` is PAGED: per-layer leaves are page pools (P,KV,bs,dh)
    and each row reads/writes through its block-table row (the table itself
    is device state owned by the serving engine).  ``write_mask`` (B,) bool
    drops the dense K/V write for False rows (parked / mid-prefill slots in
    the chunked serving engine, whose lanes hold chunk-written prompt K/V a
    no-op decode write must not clobber); paged rows ignore it — their
    parked writes already land in the NULL page.  Returns (logits, hidden,
    cache).
    """
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    x = embed_tokens(cfg, params, token)
    paged = "block_tables" in cache
    if paged:
        assert window is None, "paged decode has no ring-buffer SWA variant"
        bt = cache["block_tables"]
        pages = {k: v for k, v in cache.items() if k != "block_tables"}
        n_virtual = bt.shape[1] * pages["k"].shape[3]
        valid = attn.paged_valid_mask(pos, b, n_virtual)
        scanned = pages
    else:
        s_cache = cache["k"].shape[3]
        slot, valid = attn.decode_valid_mask(pos, b, s_cache, window)
        if write_mask is not None:
            # masked rows route their write out of range -> scatter drops it
            slot = jnp.where(write_mask, jnp.broadcast_to(slot, (b,)),
                             s_cache)
        bt = None
        scanned = cache
    positions = pos if pos.ndim == 1 else jnp.full((b,), pos, jnp.int32)

    def body(x, xs):
        p_l, cache_l = xs
        x, kv_new = layer_decode(cfg, p_l, x, cache_l, positions, valid,
                                 block_tables=bt)
        return x, kv_new

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], scanned))
    if paged:
        new_cache = dict(attn.cache_write_paged(pages, ks, vs, bt, pos),
                         block_tables=bt)
    else:
        new_cache = attn.cache_write_stacked(cache, ks, vs, slot)
    h = apply_norm(cfg, params["final_norm"], x[:, None, :])[:, 0]
    logits = logits_from_hidden(cfg, params, h)
    return logits, h, new_cache
