"""Hymba — hybrid-head layers: parallel attention + Mamba(SSM) heads.

[arXiv:2411.13676].  Each layer feeds the same normed input to (i) GQA
attention with a sliding window and (ii) a selective-SSM (Mamba-style) head
branch; the two branch outputs are RMS-normalized and mixed with learnable
per-branch scales.  128 learnable *meta tokens* are prepended to the prompt
and are always attendable (kept outside the SWA ring in decode).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import parallel
from repro.models import attention as attn
from repro.models.common import (Param, apply_norm, apply_rope, norm_decls,
                                 rmsnorm, stack_decls)
from repro.models.transformer import (_qkv, embed_tokens, logits_from_hidden,
                                      mlp_apply, _mlp_decls)


# ---------------------------------------------------------------------------
# Mamba branch

def mamba_decls(cfg) -> Dict[str, Param]:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ds = cfg.ssm.state_dim
    dtr = cfg.ssm.dt_rank or max(1, -(-d // 16))
    return {
        "w_in": Param((d, 2 * di), ("embed", "inner2")),
        "conv_w": Param((cfg.ssm.conv_dim, di), (None, "inner")),
        "conv_b": Param((di,), ("inner",), "zeros"),
        "w_x_dt": Param((di, dtr), ("inner", None)),
        "w_dt": Param((dtr, di), (None, "inner")),
        "b_dt": Param((di,), ("inner",), "zeros"),
        "w_B": Param((di, ds), ("inner", None)),
        "w_C": Param((di, ds), ("inner", None)),
        "A_log": Param((di, ds), ("inner", None), "small"),
        "D": Param((di,), ("inner",), "ones"),
        "w_out": Param((di, d), ("inner", "embed")),
    }


def mamba_init_state(cfg, batch: int):
    di = cfg.ssm.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, di), jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((batch, di, cfg.ssm.state_dim), jnp.float32)}


def _mamba_core(p, xin, conv_state, ssm_state, cfg):
    """xin (B,T,di) post-in-proj; returns (y (B,T,di), conv_state', ssm_state')."""
    b, t, di = xin.shape
    ds = cfg.ssm.state_dim
    dt_ = xin.dtype
    # depthwise causal conv over time
    xpad = jnp.concatenate([conv_state.astype(dt_), xin], axis=1)
    new_conv = xpad[:, -(cfg.ssm.conv_dim - 1):] if cfg.ssm.conv_dim > 1 else conv_state
    win = cfg.ssm.conv_dim
    idx = jnp.arange(t)[:, None] + jnp.arange(win)[None, :]       # (T, win)
    xwin = jnp.take(xpad, idx, axis=1)                            # (B,T,win,di)
    xc = jnp.einsum("btwd,wd->btd", xwin, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt_)
    # data-dependent dt, B, C
    dt_lr = (xc @ p["w_x_dt"].astype(dt_)) @ p["w_dt"].astype(dt_) + p["b_dt"].astype(dt_)
    dt_pos = jax.nn.softplus(dt_lr.astype(jnp.float32))           # (B,T,di)
    Bm = (xc @ p["w_B"].astype(dt_)).astype(jnp.float32)          # (B,T,ds)
    Cm = (xc @ p["w_C"].astype(dt_)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di,ds)
    xf = xc.astype(jnp.float32)

    def step(h, xs):
        dt_t, B_t, C_t, x_t = xs                                  # (B,di),(B,ds),(B,ds),(B,di)
        dA = jnp.exp(dt_t[..., None] * A[None])                   # (B,di,ds)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(dt_pos, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(xf, 1, 0))
    ssm_state, ys = jax.lax.scan(step, ssm_state, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * p["D"].astype(jnp.float32)[None, None]
    return y.astype(dt_), new_conv, ssm_state


def mamba_branch(cfg, p, x, state):
    """x (B,T,d) -> (out (B,T,d), new_state)."""
    dt_ = x.dtype
    xz = x @ p["w_in"].astype(dt_)
    di = cfg.ssm.expand * cfg.d_model
    xin, z = xz[..., :di], xz[..., di:]
    y, conv_s, ssm_s = _mamba_core(p, xin, state["conv"], state["ssm"], cfg)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    return y @ p["w_out"].astype(dt_), {"conv": conv_s, "ssm": ssm_s}


# ---------------------------------------------------------------------------
# Hybrid layer

def layer_decls(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    out = {
        "ln1": norm_decls(cfg), "ln2": norm_decls(cfg),
        "attn": {
            "wq": Param((d, cfg.attn_out_dim), ("embed", "qkv")),
            "wk": Param((d, cfg.kv_out_dim), ("embed", "kv_qkv")),
            "wv": Param((d, cfg.kv_out_dim), ("embed", "kv_qkv")),
            "wo": Param((cfg.attn_out_dim, d), ("qkv", "embed")),
        },
        "mamba": mamba_decls(cfg),
        "norm_attn": {"scale": Param((d,), (None,), "ones")},
        "norm_ssm": {"scale": Param((d,), (None,), "ones")},
        "beta": Param((2,), (None,), "ones"),
        "mlp": _mlp_decls(cfg),
    }
    return out


def decls(cfg) -> Dict[str, Any]:
    vpad = cfg.padded_vocab()
    return {
        "embed": Param((vpad, cfg.d_model), ("vocab", "embed"), "embed"),
        "meta_tokens": Param((cfg.n_meta_tokens, cfg.d_model), (None, "embed"), "embed"),
        "final_norm": norm_decls(cfg),
        "lm_head": Param((cfg.d_model, vpad), ("embed", "vocab")),
        "layers": stack_decls(layer_decls(cfg), cfg.n_layers, "layers"),
    }


def init_state(cfg, batch: int, cache_len: int):
    """Hybrid decode state: SWA ring KV cache + mamba states, per layer."""
    win = min(cache_len, cfg.sliding_window or cache_len)
    kv = attn.init_cache(cfg, batch, win)       # already (L, B, KV, W, dh)
    ms = mamba_init_state(cfg, batch)
    L = cfg.n_layers
    return {
        "kv": kv,
        "mamba": jax.tree.map(lambda a: jnp.zeros((L,) + a.shape, a.dtype), ms),
    }


def _layer_prefill(cfg, p, x, positions, mamba_state):
    b, s, d = x.shape
    h = apply_norm(cfg, p["ln1"], x)
    # attention branch (SWA; meta tokens are inside the sequence at prefill)
    q, k, v = _qkv(cfg, p["attn"], h)
    q = apply_rope(q.reshape(b, s, cfg.n_heads, cfg.d_head), positions,
                   cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k.reshape(b, s, cfg.n_kv_heads, cfg.d_head), positions,
                   cfg.rope_theta, cfg.rotary_pct)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    oa = attn.attn_prefill(q, k, v, causal=True, window=cfg.sliding_window)
    oa = oa.reshape(b, s, cfg.attn_out_dim) @ p["attn"]["wo"].astype(x.dtype)
    # mamba branch
    om, mamba_state = mamba_branch(cfg, p["mamba"], h, mamba_state)
    beta = p["beta"].astype(jnp.float32)
    fused = (beta[0] * rmsnorm(oa, p["norm_attn"]["scale"]).astype(jnp.float32)
             + beta[1] * rmsnorm(om, p["norm_ssm"]["scale"]).astype(jnp.float32))
    x = x + fused.astype(x.dtype)
    h = apply_norm(cfg, p["ln2"], x)
    x = x + mlp_apply(cfg, p["mlp"], h)
    return x, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)), mamba_state


def forward(cfg, params, batch):
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    meta = params["meta_tokens"].astype(x.dtype)
    x = jnp.concatenate([jnp.broadcast_to(meta[None], (x.shape[0],) + meta.shape), x], 1)
    x = parallel.constrain(x, "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    st0 = init_state(cfg, b, cache_len=1)["mamba"]
    ctx = parallel.current_ctx()

    def body(x, xs):
        p_l, st_l = xs
        x, _, _ = _layer_prefill(cfg, p_l, x, positions, st_l)
        return x, None

    if ctx is not None and ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["layers"], st0))
    h = apply_norm(cfg, params["final_norm"], x)
    return logits_from_hidden(cfg, params, h), h, jnp.float32(0)


def prefill(cfg, params, batch, cache_len: int):
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    meta = params["meta_tokens"].astype(x.dtype)
    x = jnp.concatenate([jnp.broadcast_to(meta[None], (x.shape[0],) + meta.shape), x], 1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    state = init_state(cfg, b, cache_len)
    win = state["kv"]["k"].shape[3]

    def body(x, xs):
        p_l, st_l = xs
        x, (k, v), m_st = _layer_prefill(cfg, p_l, x, positions, st_l)
        if s >= win:
            # keep the last `win` positions; entry j holds position s-win+j and
            # must land at ring slot (s-win+j) % win -> roll by (s-win) % win.
            kw = jnp.roll(k[:, :, -win:], (s - win) % win, axis=2)
            vw = jnp.roll(v[:, :, -win:], (s - win) % win, axis=2)
        else:
            # prompt shorter than the window: slots 0..s-1, zero-pad the rest
            pad = [(0, 0), (0, 0), (0, win - s), (0, 0)]
            kw, vw = jnp.pad(k, pad), jnp.pad(v, pad)
        cache_l = {"k": kw.astype(state["kv"]["k"].dtype),
                   "v": vw.astype(state["kv"]["v"].dtype)}
        return x, (cache_l, m_st)

    x, (kv, mamba) = jax.lax.scan(body, x, (params["layers"], state["mamba"]))
    h = apply_norm(cfg, params["final_norm"], x)
    return {"kv": kv, "mamba": mamba}, h[:, -1], h


def decode_step(cfg, params, token, state, pos):
    """One-token decode; pos counts from end of prompt (absolute, incl meta).
    ``pos`` is scalar or (B,) — per-row positions for continuous batching."""
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    x = embed_tokens(cfg, params, token)
    win = state["kv"]["k"].shape[3]
    slot, valid = attn.decode_valid_mask(pos, b, win, win)
    positions = pos if pos.ndim == 1 else jnp.full((b,), pos, jnp.int32)

    def body(x, xs):
        p_l, kv_l, m_l = xs
        h = apply_norm(cfg, p_l["ln1"], x[:, None, :])[:, 0]
        q, k, v = _qkv(cfg, p_l["attn"], h)
        q = apply_rope(q.reshape(b, 1, cfg.n_heads, cfg.d_head),
                       positions[:, None], cfg.rope_theta, cfg.rotary_pct)[:, 0]
        k = apply_rope(k.reshape(b, 1, cfg.n_kv_heads, cfg.d_head),
                       positions[:, None], cfg.rope_theta, cfg.rotary_pct)[:, 0]
        v = v.reshape(b, cfg.n_kv_heads, cfg.d_head)
        oa = attn.attn_decode(q, kv_l, valid, x.dtype, extra_kv=(k, v))
        oa = oa.reshape(b, cfg.attn_out_dim) @ p_l["attn"]["wo"].astype(x.dtype)
        om, m_l = mamba_branch(cfg, p_l["mamba"], h[:, None, :], m_l)
        om = om[:, 0]
        beta = p_l["beta"].astype(jnp.float32)
        fused = (beta[0] * rmsnorm(oa, p_l["norm_attn"]["scale"]).astype(jnp.float32)
                 + beta[1] * rmsnorm(om, p_l["norm_ssm"]["scale"]).astype(jnp.float32))
        x = x + fused.astype(x.dtype)
        h2 = apply_norm(cfg, p_l["ln2"], x[:, None, :])
        x = x + mlp_apply(cfg, p_l["mlp"], h2)[:, 0]
        return x, ((k, v), m_l)

    x, ((ks, vs), mamba) = jax.lax.scan(
        body, x, (params["layers"], state["kv"], state["mamba"]))
    kv = attn.cache_write_stacked(state["kv"], ks, vs, slot)
    h = apply_norm(cfg, params["final_norm"], x[:, None, :])[:, 0]
    logits = logits_from_hidden(cfg, params, h)
    return logits, h, {"kv": kv, "mamba": mamba}
