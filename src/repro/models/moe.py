"""Mixture-of-Experts block: top-k token-choice routing.

Two execution paths:

* ``dense``  — oracle path: every expert runs on every token, outputs are
  gate-weighted.  O(E x) compute, used for reduced smoke configs and as the
  correctness reference for the EP path.
* ``ep``     — expert-parallel path (production): experts are sharded over
  the ``model`` mesh axis; activations are replicated over that axis (as
  they are under tensor parallelism), so *dispatch is local*: each shard
  gathers the top-capacity tokens for its own experts, runs the expert FFN,
  scatter-adds into the output and psum-combines over the model axis.
  Capacity-dropping semantics follow GShard/Switch.

Router aux losses (load-balance + z-loss) are returned for the train loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import parallel
from repro.models.common import Param, swiglu


def moe_decls(cfg) -> Dict[str, Param]:
    E, d, f = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": Param((d, E), (None, None), "small"),
        "w_gate": Param((E, d, f), ("experts", "embed", "mlp")),
        "w_up": Param((E, d, f), ("experts", "embed", "mlp")),
        "w_down": Param((E, f, d), ("experts", "mlp", "embed")),
    }


def _router(params, x, cfg):
    """x (T, d) -> probs (T, E), aux losses."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top = jax.lax.top_k(probs, cfg.moe.top_k)
    gates, idx = top                                  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # GShard load-balance loss + z-loss
    E = cfg.moe.n_experts
    me = jnp.mean(probs, axis=0)                      # mean prob per expert
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.moe.router_aux_coef
    z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = aux + z * cfg.moe.router_z_coef
    return probs, gates, idx, aux


def _expert_ffn(w_gate, w_up, w_down, x):
    """x (..., C, d) with stacked expert dim leading on weights."""
    h = swiglu(jnp.einsum("ecd,edf->ecf", x, w_gate),
               jnp.einsum("ecd,edf->ecf", x, w_up))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_dense(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: run all experts on all tokens. x (B,S,d)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, gates, idx, aux = _router(params, xt, cfg)
    E = cfg.moe.n_experts
    dt = x.dtype
    xe = jnp.broadcast_to(xt[None], (E, b * s, d)).astype(dt)
    ye = _expert_ffn(params["w_gate"].astype(dt), params["w_up"].astype(dt),
                     params["w_down"].astype(dt), xe)       # (E, T, d)
    comb = jnp.zeros((b * s, E), jnp.float32)
    comb = jax.vmap(lambda c, i, g: c.at[i].add(g))(comb, idx, gates)
    y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), comb)
    return y.reshape(b, s, d).astype(dt), aux


def moe_ep(params, x, cfg, ctx: parallel.ParallelContext) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel shard_map path. x (B,S,d) sharded batch->data axes,
    replicated over model; expert weights sharded experts->model."""
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    ax = ctx.model_axis
    n_shards = ctx.mesh.shape[ax]
    E_loc = E // n_shards
    dspec = ctx.rules.get("batch")
    b, s, d = x.shape
    dt = x.dtype

    def shard_fn(router, w_gate, w_up, w_down, x):
        bl = x.shape[0]
        T = bl * s
        xt = x.reshape(T, d)
        pr = {"router": router}
        probs, gates, idx, aux = _router(pr, xt, cfg)
        cap = int((T * k / E) * cfg.moe.capacity_factor) + 1
        shard = jax.lax.axis_index(ax)
        # score of each token for each *local* expert (0 if not routed there)
        local_ids = shard * E_loc + jnp.arange(E_loc)             # (E_loc,)
        sel = (idx[None] == local_ids[:, None, None])             # (E_loc, T, k)
        score = jnp.sum(jnp.where(sel, gates[None], 0.0), axis=-1)  # (E_loc, T)
        routed = jnp.any(sel, axis=-1)                            # (E_loc, T)
        # top-capacity tokens per local expert (capacity dropping)
        top_scores, top_idx = jax.lax.top_k(
            jnp.where(routed, score, -1.0), min(cap, T))          # (E_loc, C)
        keep = top_scores > 0.0
        xc = jnp.take(xt, top_idx.reshape(-1), axis=0)
        xc = xc.reshape(E_loc, -1, d).astype(dt)                  # (E_loc, C, d)
        yc = _expert_ffn(w_gate.astype(dt), w_up.astype(dt), w_down.astype(dt), xc)
        yc = yc.astype(jnp.float32) * (top_scores * keep)[..., None]
        out = jnp.zeros((T, d), jnp.float32)
        out = out.at[top_idx.reshape(-1)].add(yc.reshape(-1, d))
        # combine expert partials in bf16: halves the per-layer all-reduce
        # bytes (§Perf iteration B2); each shard's partial is an f32
        # accumulation, only the cross-shard combine is bf16.
        out = jax.lax.psum(out.astype(dt), ax).astype(jnp.float32)
        # aux is identical across model shards (router inputs replicated) but
        # differs across data shards -> average it so it is fully replicated.
        for a in ctx.data_axes:
            if ctx.mesh.shape[a] > 1:
                aux = jax.lax.pmean(aux, a)
        return out.reshape(bl, s, d).astype(dt), aux

    y, aux = parallel.shard_map(
        shard_fn, mesh=ctx.mesh,
        in_specs=(P(), P(ax), P(ax), P(ax), P(dspec)),
        out_specs=(P(dspec), P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
    return y, aux


def moe_block(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ctx = parallel.current_ctx()
    if ctx is not None and ctx.ep_moe:
        return moe_ep(params, x, cfg, ctx)
    return moe_dense(params, x, cfg)
