"""Whisper backbone — encoder-decoder transformer. [arXiv:2212.04356]

The mel-spectrogram + conv1d frontend is a STUB per the assignment carve-out:
``input_specs`` provides precomputed frame embeddings (B, n_frames, d_model).
We implement the transformer: bidirectional encoder with sinusoidal
positions, causal decoder with learned positions and cross-attention.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import parallel
from repro.models import attention as attn
from repro.models.common import Param, apply_norm, gelu, norm_decls, stack_decls
from repro.models.transformer import _qkv

MAX_TARGET_POSITIONS = 32768  # decoder learned positions (extended from 448)


def _attn_decls(cfg):
    d, qo = cfg.d_model, cfg.attn_out_dim
    return {"wq": Param((d, qo), ("embed", "qkv")),
            "wk": Param((d, qo), ("embed", "kv_qkv")),
            "wv": Param((d, qo), ("embed", "kv_qkv")),
            "wo": Param((qo, d), ("qkv", "embed")),
            "bq": Param((qo,), ("qkv",), "zeros"),
            "bk": Param((qo,), ("kv_qkv",), "zeros"),
            "bv": Param((qo,), ("kv_qkv",), "zeros")}


def _mlp_decls(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {"w_in": Param((d, f), ("embed", "mlp")),
            "b_in": Param((f,), ("mlp",), "zeros"),
            "w_out": Param((f, d), ("mlp", "embed")),
            "b_out": Param((d,), (None,), "zeros")}


def enc_layer_decls(cfg):
    return {"ln1": norm_decls(cfg), "attn": _attn_decls(cfg),
            "ln2": norm_decls(cfg), "mlp": _mlp_decls(cfg)}


def dec_layer_decls(cfg):
    return {"ln1": norm_decls(cfg), "self_attn": _attn_decls(cfg),
            "ln2": norm_decls(cfg), "cross_attn": _attn_decls(cfg),
            "ln3": norm_decls(cfg), "mlp": _mlp_decls(cfg)}


def decls(cfg) -> Dict[str, Any]:
    vpad = cfg.padded_vocab()
    return {
        "embed": Param((vpad, cfg.d_model), ("vocab", "embed"), "embed"),
        "pos_embed": Param((MAX_TARGET_POSITIONS, cfg.d_model), (None, "embed"), "embed"),
        "enc_layers": stack_decls(enc_layer_decls(cfg), cfg.n_encoder_layers, "layers"),
        "enc_norm": norm_decls(cfg),
        "dec_layers": stack_decls(dec_layer_decls(cfg), cfg.n_layers, "layers"),
        "final_norm": norm_decls(cfg),
    }


def _sinusoid(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(cfg, p, xq, xkv, causal: bool) -> jnp.ndarray:
    b, sq, d = xq.shape
    dt = xq.dtype
    q = (xq @ p["wq"].astype(dt) + p["bq"].astype(dt))
    k = (xkv @ p["wk"].astype(dt) + p["bk"].astype(dt))
    v = (xkv @ p["wv"].astype(dt) + p["bv"].astype(dt))
    q = q.reshape(b, sq, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, xkv.shape[1], cfg.n_heads, cfg.d_head)
    v = v.reshape(b, xkv.shape[1], cfg.n_heads, cfg.d_head)
    o = attn.attn_prefill(q, k, v, causal=causal)
    return o.reshape(b, sq, cfg.attn_out_dim) @ p["wo"].astype(dt)


def _mlp(cfg, p, x):
    dt = x.dtype
    h = gelu(x @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


def encode(cfg, params, frames) -> jnp.ndarray:
    """frames (B, n_frames, d_model) from the stubbed conv frontend."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model).astype(dt)[None]
    x = parallel.constrain(x, "batch", None, None)

    def body(x, p_l):
        h = apply_norm(cfg, p_l["ln1"], x)
        x = x + _mha(cfg, p_l["attn"], h, h, causal=False)
        h = apply_norm(cfg, p_l["ln2"], x)
        return x + _mlp(cfg, p_l["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def forward(cfg, params, batch):
    """Teacher-forced training forward. batch: frames (B,F,d), tokens (B,S)."""
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    x = x + params["pos_embed"][:s].astype(dt)[None]
    x = parallel.constrain(x, "batch", None, None)
    ctx = parallel.current_ctx()

    def body(x, p_l):
        h = apply_norm(cfg, p_l["ln1"], x)
        x = x + _mha(cfg, p_l["self_attn"], h, h, causal=True)
        h = apply_norm(cfg, p_l["ln2"], x)
        x = x + _mha(cfg, p_l["cross_attn"], h, enc, causal=False)
        h = apply_norm(cfg, p_l["ln3"], x)
        return x + _mlp(cfg, p_l["mlp"], h), None

    if ctx is not None and ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    h = apply_norm(cfg, params["final_norm"], x)
    logits = h @ params["embed"].astype(h.dtype).T     # tied embeddings
    return parallel.constrain(logits, "batch", None, "vocab"), h, jnp.float32(0)


# ---------------------------------------------------------------------------
# Serving: cross-KV computed once at prefill; decoder self-cache grows.

def prefill(cfg, params, batch, cache_len: int):
    enc = encode(cfg, params, batch["frames"])
    b = enc.shape[0]
    dt = jnp.dtype(cfg.dtype)

    def cross_kv(carry, p_l):
        k = (enc @ p_l["cross_attn"]["wk"].astype(dt) + p_l["cross_attn"]["bk"].astype(dt))
        v = (enc @ p_l["cross_attn"]["wv"].astype(dt) + p_l["cross_attn"]["bv"].astype(dt))
        f = enc.shape[1]
        return carry, (k.reshape(b, f, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3),
                       v.reshape(b, f, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3))

    _, (ck, cv) = jax.lax.scan(cross_kv, 0, params["dec_layers"])
    self_cache = attn.init_cache(cfg, b, cache_len)
    state = {"self": self_cache, "cross_k": ck, "cross_v": cv}
    # run BOS through decode to produce the first hidden
    return state, None, enc


def decode_step(cfg, params, token, state, pos):
    """``pos`` is scalar or (B,) — per-row positions for continuous batching."""
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    dt = jnp.dtype(cfg.dtype)
    positions = pos if pos.ndim == 1 else jnp.full((b,), pos, jnp.int32)
    x = jnp.take(params["embed"].astype(dt), token, axis=0)
    x = x + jnp.take(params["pos_embed"].astype(dt), positions, axis=0)
    s_cache = state["self"]["k"].shape[3]
    slot, valid = attn.decode_valid_mask(pos, b, s_cache)

    def body(x, xs):
        p_l, cache_l, ck_l, cv_l = xs
        h = apply_norm(cfg, p_l["ln1"], x[:, None, :])[:, 0]
        pa = p_l["self_attn"]
        q = (h @ pa["wq"].astype(dt) + pa["bq"].astype(dt)).reshape(b, cfg.n_heads, cfg.d_head)
        k = (h @ pa["wk"].astype(dt) + pa["bk"].astype(dt)).reshape(b, cfg.n_heads, cfg.d_head)
        v = (h @ pa["wv"].astype(dt) + pa["bv"].astype(dt)).reshape(b, cfg.n_heads, cfg.d_head)
        o = attn.attn_decode(q, cache_l, valid, x.dtype, extra_kv=(k, v))
        x = x + o.reshape(b, cfg.attn_out_dim) @ pa["wo"].astype(dt)
        # cross attention against precomputed enc KV
        h = apply_norm(cfg, p_l["ln2"], x[:, None, :])[:, 0]
        pc = p_l["cross_attn"]
        q = (h @ pc["wq"].astype(dt) + pc["bq"].astype(dt)).reshape(b, cfg.n_heads, cfg.d_head)
        f = ck_l.shape[2]
        cvalid = jnp.ones((b, f), bool)
        o = attn.attn_decode(q, {"k": ck_l, "v": cv_l}, cvalid, x.dtype)
        x = x + o.reshape(b, cfg.attn_out_dim) @ pc["wo"].astype(dt)
        h = apply_norm(cfg, p_l["ln3"], x[:, None, :])[:, 0]
        x = x + _mlp(cfg, p_l["mlp"], h)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], state["self"], state["cross_k"], state["cross_v"]))
    self_cache = attn.cache_write_stacked(state["self"], ks, vs, slot)
    h = apply_norm(cfg, params["final_norm"], x[:, None, :])[:, 0]
    logits = h @ params["embed"].astype(h.dtype).T
    state = {"self": self_cache, "cross_k": state["cross_k"], "cross_v": state["cross_v"]}
    return logits, h, state
