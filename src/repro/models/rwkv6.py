"""RWKV6 "Finch" — attention-free WKV recurrence with data-dependent decay.

[arXiv:2404.05892].  Per head h with key/value dims d:
    out_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
where w_t = exp(-exp(w0 + tanh(x_w A) B)) is the data-dependent decay.
Token shift uses learned per-channel interpolation (ddlerp low-rank term on
the decay path, the signature RWKV6 component).

The pure ``lax.scan`` recurrence here is the reference; the chunked Pallas
kernel lives in repro/kernels/rwkv6_scan.py.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import parallel
from repro.models.common import Param, layernorm, relu_sq, stack_decls

DECAY_LORA = 64


def layer_decls(cfg) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    H, dh = cfg.n_heads, cfg.ssm.head_dim
    assert H * dh == d
    ln = lambda: {"scale": Param((d,), (None,), "ones"),
                  "bias": Param((d,), (None,), "zeros")}
    return {
        "ln1": ln(), "ln2": ln(),
        "tm": {
            "mu": Param((5, d), (None, None), "small"),       # r,k,v,w,g shifts
            "w0": Param((d,), (None,), "small"),
            "wA": Param((d, DECAY_LORA), ("embed", None), "small"),
            "wB": Param((DECAY_LORA, d), (None, "embed"), "small"),
            "u": Param((H, dh), ("heads", None), "small"),
            "Wr": Param((d, d), ("embed", "qkv")),
            "Wk": Param((d, d), ("embed", "qkv")),
            "Wv": Param((d, d), ("embed", "qkv")),
            "Wg": Param((d, d), ("embed", "qkv")),
            "Wo": Param((d, d), ("qkv", "embed")),
            "gn_scale": Param((d,), (None,), "ones"),
            "gn_bias": Param((d,), (None,), "zeros"),
        },
        "cm": {
            "mu_k": Param((d,), (None,), "small"),
            "mu_r": Param((d,), (None,), "small"),
            "Wk": Param((d, f), ("embed", "mlp")),
            "Wv": Param((f, d), ("mlp", "embed")),
            "Wr": Param((d, d), ("embed", "embed2")),
        },
    }


def decls(cfg) -> Dict[str, Any]:
    vpad = cfg.padded_vocab()
    return {
        "embed": Param((vpad, cfg.d_model), ("vocab", "embed"), "embed"),
        "ln0": {"scale": Param((cfg.d_model,), (None,), "ones"),
                "bias": Param((cfg.d_model,), (None,), "zeros")},
        "final_norm": {"scale": Param((cfg.d_model,), (None,), "ones"),
                       "bias": Param((cfg.d_model,), (None,), "zeros")},
        "lm_head": Param((cfg.d_model, vpad), ("embed", "vocab")),
        "layers": stack_decls(layer_decls(cfg), cfg.n_layers, "layers"),
    }


def _group_norm(x, scale, bias, n_groups, eps=64e-5):
    """x (..., d) grouped into n_groups."""
    shp = x.shape
    xg = x.reshape(shp[:-1] + (n_groups, shp[-1] // n_groups)).astype(jnp.float32)
    mu = jnp.mean(xg, -1, keepdims=True)
    var = jnp.var(xg, -1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(shp)
    return (x * scale + bias).astype(jnp.float32)


def decay_from_x(tm, xw):
    """Data-dependent decay (the RWKV6 novelty). xw (..., d) -> w in (0,1)."""
    dt = xw.dtype
    lora = jnp.tanh(xw @ tm["wA"].astype(dt)) @ tm["wB"].astype(dt)
    logw = tm["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def wkv_scan(r, k, v, w, u, state0):
    """Reference WKV recurrence.  r,k,v,w: (B,T,H,dh); u: (H,dh);
    state0: (B,H,dh,dh).  Returns out (B,T,H,dh), state_T."""
    def step(S, xs):
        r_t, k_t, v_t, w_t = xs          # (B,H,dh)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,dhk,dhv)
        att = (u[None, :, :, None] * kv) + S
        out = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        S = w_t[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state_T, out = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(out, 0, 1), state_T


def _shift(x, x_prev):
    """Token shift: returns tensor of previous-token values.
    x (B,T,d); x_prev (B,d) carried state."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def time_mix(cfg, tm, x, x_prev, state0):
    """x (B,T,d). Returns (out, new_x_prev, new_state)."""
    b, t, d = x.shape
    H, dh = cfg.n_heads, cfg.ssm.head_dim
    xs = _shift(x, x_prev)
    mu = tm["mu"].astype(x.dtype)
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xw = x + (xs - x) * mu[3]
    xg = x + (xs - x) * mu[4]
    dt = x.dtype
    r = (xr @ tm["Wr"].astype(dt)).reshape(b, t, H, dh)
    k = (xk @ tm["Wk"].astype(dt)).reshape(b, t, H, dh)
    v = (xv @ tm["Wv"].astype(dt)).reshape(b, t, H, dh)
    g = xg @ tm["Wg"].astype(dt)
    w = decay_from_x(tm, xw).reshape(b, t, H, dh)
    out, state = wkv_scan(r, k, v, w, tm["u"].astype(jnp.float32), state0)
    out = _group_norm(out.reshape(b, t, d), tm["gn_scale"].astype(jnp.float32),
                      tm["gn_bias"].astype(jnp.float32), H)
    out = out.astype(dt) * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    return out @ tm["Wo"].astype(dt), x[:, -1], state


def channel_mix(cfg, cm, x, x_prev):
    xs = _shift(x, x_prev)
    dt = x.dtype
    xk = x + (xs - x) * cm["mu_k"].astype(dt)
    xr = x + (xs - x) * cm["mu_r"].astype(dt)
    kk = relu_sq(xk @ cm["Wk"].astype(dt))
    r = jax.nn.sigmoid((xr @ cm["Wr"].astype(dt)).astype(jnp.float32)).astype(dt)
    return r * (kk @ cm["Wv"].astype(dt)), x[:, -1]


def init_state(cfg, batch: int):
    """Recurrent state per layer stack: WKV state + token-shift states."""
    H, dh = cfg.n_heads, cfg.ssm.head_dim
    L, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": jnp.zeros((L, batch, H, dh, dh), jnp.float32),
        "tm_x": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
        "cm_x": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
    }


def _layer(cfg, p, x, st):
    h = layernorm(x, p["ln1"]["scale"], p["ln1"]["bias"])
    out, tm_x, wkv = time_mix(cfg, p["tm"], h, st["tm_x"], st["wkv"])
    x = x + out
    h = layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    out, cm_x = channel_mix(cfg, p["cm"], h, st["cm_x"])
    x = x + out
    return x, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x}


def forward(cfg, params, batch):
    """Training forward over full sequences. Returns (logits, hidden, aux)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"].astype(jnp.dtype(cfg.dtype)), tokens, axis=0)
    x = layernorm(x, params["ln0"]["scale"], params["ln0"]["bias"])
    x = parallel.constrain(x, "batch", None, None)
    b = x.shape[0]
    st0 = init_state(cfg, b)
    ctx = parallel.current_ctx()

    def body(x, xs):
        p_l, st_l = xs
        x, _ = _layer(cfg, p_l, x, st_l)
        return x, None

    if ctx is not None and ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["layers"], st0))
    h = layernorm(x, params["final_norm"]["scale"], params["final_norm"]["bias"])
    logits = h @ params["lm_head"].astype(h.dtype)
    return parallel.constrain(logits, "batch", None, "vocab"), h, jnp.float32(0)


def prefill(cfg, params, batch, cache_len: int = 0):
    """Returns (state, last_hidden, hidden_all); cache_len unused (O(1) state)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"].astype(jnp.dtype(cfg.dtype)), tokens, axis=0)
    x = layernorm(x, params["ln0"]["scale"], params["ln0"]["bias"])
    b = x.shape[0]
    st0 = init_state(cfg, b)

    def body(x, xs):
        p_l, st_l = xs
        return _layer(cfg, p_l, x, st_l)

    x, st = jax.lax.scan(body, x, (params["layers"], st0))
    h = layernorm(x, params["final_norm"]["scale"], params["final_norm"]["bias"])
    return st, h[:, -1], h


def decode_step(cfg, params, token, state, pos):
    """One-token decode: state carries WKV + shift states; O(1) in context."""
    x = jnp.take(params["embed"].astype(jnp.dtype(cfg.dtype)), token, axis=0)
    x = layernorm(x, params["ln0"]["scale"], params["ln0"]["bias"])
    x = x[:, None, :]                                   # (B,1,d)

    def body(x, xs):
        p_l, st_l = xs
        return _layer(cfg, p_l, x, st_l)

    x, st = jax.lax.scan(body, x, (params["layers"], state))
    h = layernorm(x[:, 0], params["final_norm"]["scale"], params["final_norm"]["bias"])
    logits = h @ params["lm_head"].astype(h.dtype)
    return logits, h, st
