"""repro — ORCA (Online Reasoning Calibration) reproduced as a production
JAX training/serving framework.

Layers: repro.core (the paper's contribution), repro.models (architecture
zoo), repro.kernels (Pallas TPU kernels), repro.data / repro.trajectories /
repro.optim / repro.checkpoint / repro.serving (substrates),
repro.launch (meshes, dry-run, drivers), repro.roofline (perf analysis).
"""

__version__ = "1.1.0"


def __getattr__(name):
    # lazy facade alias: `from repro import orca` == `import repro.api`
    if name in ("orca", "api"):
        import repro.api as _api
        return _api
    raise AttributeError(name)
