from repro.roofline.analysis import (CollectiveStats, RooflineReport,
                                     build_report, parse_collectives)
from repro.roofline.analytic import estimate, non_embedding_params
from repro.roofline.constants import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

__all__ = ["CollectiveStats", "RooflineReport", "build_report",
           "parse_collectives", "estimate", "non_embedding_params",
           "HBM_BW", "ICI_LINK_BW", "PEAK_FLOPS_BF16"]
