"""Roofline terms from a compiled dry-run artifact.

compute term    = FLOPs / (chips * peak)
memory term     = bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

FLOPs/bytes: analytic model (primary — XLA cost_analysis does not scale
while-loop bodies by trip count and our layer loop is a scan) with
cost_analysis reported alongside as a cross-check.

collective_bytes: parsed from the compiled/optimized HLO text — every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction's OUTPUT shape bytes, with instructions inside non-entry
computations (the layer-scan while body) multiplied by n_layers.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.configs import InputShape, ModelConfig
from repro.roofline import analytic
from repro.roofline.constants import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[2,16,128]{2,1,0}' or a
    tuple '(f32[8,128], f32[8,128])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_total: float
    by_op: Dict[str, float]
    count: int


def parse_collectives(hlo_text: str, loop_multiplier: int = 1
                      ) -> CollectiveStats:
    """Sum output bytes of collective ops in optimized HLO.

    Instructions living in non-ENTRY computations are assumed to be inside
    the layer-scan while body and are multiplied by ``loop_multiplier``
    (documented assumption: this framework only emits collectives at top
    level or in the per-layer body).
    """
    by_op: Dict[str, float] = {}
    count = 0
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
            continue
        if re.match(r"^%?\S+ \(", stripped) and stripped.endswith("{"):
            # new (non-entry) computation definition
            in_entry = False
            continue
        for op in _COLL_OPS:
            # match `= <shape> all-gather(...)` style instructions
            marker = f" {op}("
            alt = f" {op}-start("
            if marker in stripped or alt in stripped:
                lhs = stripped.split("=", 1)
                if len(lhs) != 2:
                    continue
                shape_part = lhs[1].strip().split(op)[0]
                nbytes = _shape_bytes(shape_part)
                mult = 1 if in_entry else loop_multiplier
                by_op[op] = by_op.get(op, 0.0) + nbytes * mult
                count += 1
                break
    return CollectiveStats(sum(by_op.values()), by_op, count)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    # raw numbers
    flops_analytic: float
    bytes_analytic: float
    model_flops: float
    flops_ratio: float                 # MODEL_FLOPS / analytic FLOPs
    collective_bytes: float
    collective_by_op: Dict[str, float]
    # cross-checks from the compiled artifact
    cost_analysis_flops: Optional[float] = None
    cost_analysis_bytes: Optional[float] = None
    per_device_memory_bytes: Optional[float] = None
    note: str = ""

    def terms(self) -> Dict[str, float]:
        return {"compute": self.t_compute, "memory": self.t_memory,
                "collective": self.t_collective}

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)


def build_report(cfg: ModelConfig, shape: InputShape, mesh_name: str,
                 chips: int, hlo_text: str,
                 cost: Optional[dict] = None,
                 memory_stats: Optional[dict] = None,
                 note: str = "") -> RooflineReport:
    est = analytic.estimate(cfg, shape)
    coll = parse_collectives(hlo_text, loop_multiplier=cfg.n_layers)
    t_c = est.flops / (chips * PEAK_FLOPS_BF16)
    t_m = est.bytes / (chips * HBM_BW)
    t_x = coll.bytes_total / (chips * ICI_LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    ca_flops = cost.get("flops") if cost else None
    ca_bytes = cost.get("bytes accessed") if cost else None
    mem = None
    if memory_stats:
        mem = memory_stats.get("bytes")
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dominant,
        flops_analytic=est.flops, bytes_analytic=est.bytes,
        model_flops=est.model_flops,
        flops_ratio=est.model_flops / max(est.flops, 1.0),
        collective_bytes=coll.bytes_total, collective_by_op=coll.by_op,
        cost_analysis_flops=ca_flops, cost_analysis_bytes=ca_bytes,
        per_device_memory_bytes=mem, note=note)
