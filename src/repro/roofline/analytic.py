"""Analytic FLOP / byte models per (architecture x input shape).

Primary source for the roofline compute/memory terms.  XLA's
``cost_analysis`` does NOT multiply while-loop bodies by trip count, and the
whole transformer runs inside a layer-scan in this framework, so raw
cost_analysis under-reports by ~n_layers; we report both and flag the gap
(EXPERIMENTS.md §Roofline).

MODEL_FLOPS convention (the "useful FLOPs" the assignment asks for):
train 6*N*D, prefill 2*N*D, decode 2*N_active per token — N excludes
embeddings, D = tokens processed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs import InputShape, ModelConfig


def _block_params(cfg: ModelConfig) -> Dict[str, float]:
    d = cfg.d_model
    attn = d * cfg.attn_out_dim + 2 * d * cfg.kv_out_dim + cfg.attn_out_dim * d
    ffn_one = (3 if cfg.mlp == "swiglu" else 2) * d * cfg.d_ff
    out = {"attn": attn, "ffn_one": ffn_one}
    if cfg.arch_type == "ssm":
        di = d
        out["attn"] = 6 * d * d          # r,k,v,g,w(out) projections
    if cfg.arch_type == "hybrid" and cfg.ssm is not None:
        di = cfg.ssm.expand * d
        out["mamba"] = 2 * d * di + di * d + di * (cfg.ssm.state_dim * 2)
    return out


def non_embedding_params(cfg: ModelConfig, active: bool = False) -> float:
    bp = _block_params(cfg)
    ffn = bp["ffn_one"]
    if cfg.moe is not None:
        ffn = ffn * (cfg.moe.top_k if active else cfg.moe.n_experts)
    block = bp["attn"] + ffn + bp.get("mamba", 0.0)
    n = cfg.n_layers * block
    if cfg.is_encoder_decoder:
        n += cfg.n_encoder_layers * (bp["attn"] + bp["ffn_one"])
        n += cfg.n_layers * bp["attn"]          # cross attention
    return float(n)


def attention_context(cfg: ModelConfig, shape: InputShape) -> float:
    """Effective attended length per query token."""
    s = shape.seq_len
    if shape.kind == "decode":
        if cfg.arch_type == "ssm":
            return 0.0
        if cfg.arch_type == "hybrid":
            return float(cfg.sliding_window or s)
        from repro.models.registry import NATIVE_DECODE_MAX
        if cfg.long_context_variant == "sliding" and s > NATIVE_DECODE_MAX:
            return float(cfg.long_context_window)
        return float(s)
    # train / prefill: causal average s/2, or window
    w = cfg.sliding_window
    return float(min(w, s) if w else s / 2)


@dataclasses.dataclass
class FlopBytes:
    flops: float
    bytes: float
    model_flops: float


def estimate(cfg: ModelConfig, shape: InputShape) -> FlopBytes:
    n_full = non_embedding_params(cfg)
    n_act = non_embedding_params(cfg, active=True)
    b = shape.global_batch
    s = shape.seq_len
    ctx = attention_context(cfg, shape)
    hd = cfg.attn_out_dim
    wbytes_train = 4        # f32 master weights
    wbytes_serve = 2        # bf16

    if shape.kind == "train":
        tokens = b * s
        mm = 6.0 * n_act * tokens
        attn = 6.0 * 2.0 * cfg.n_layers * b * s * ctx * hd
        flops = mm + attn
        # fwd+bwd read params, optimizer rw (m, v, p in f32)
        n_store = non_embedding_params(cfg)     # all experts stored
        bytes_ = (3 * n_store * wbytes_train            # fwd/bwd/update reads
                  + 3 * n_store * 4 * 2                 # adam m,v + param rw
                  + tokens * cfg.d_model * 4 * 2 * cfg.n_layers * 0.25)  # remat acts
        return FlopBytes(flops, bytes_, 6.0 * n_act * tokens)
    if shape.kind == "prefill":
        tokens = b * s
        mm = 2.0 * n_act * tokens
        attn = 2.0 * 2.0 * cfg.n_layers * b * s * ctx * hd
        flops = mm + attn
        cache = 2 * cfg.n_layers * b * cfg.kv_out_dim * s * \
            (1 if cfg.kv_cache_dtype == "int8" else 2)
        bytes_ = n_full * wbytes_serve + tokens * cfg.d_model * 2 * 4 + cache
        return FlopBytes(flops, bytes_, 2.0 * n_act * tokens)
    # decode: one token for the whole batch
    mm = 2.0 * n_act * b
    attn = 2.0 * 2.0 * cfg.n_layers * b * ctx * hd
    flops = mm + attn
    cache_entry = (1 if cfg.kv_cache_dtype == "int8" else 2)
    cache_read = 2 * cfg.n_layers * b * cfg.kv_out_dim * ctx * cache_entry
    state_bytes = 0.0
    if cfg.arch_type == "ssm":
        state_bytes = cfg.n_layers * b * cfg.n_heads * cfg.ssm.head_dim ** 2 * 4 * 2
    if cfg.arch_type == "hybrid" and cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        state_bytes = cfg.n_layers * b * di * cfg.ssm.state_dim * 4 * 2
    bytes_ = n_act * wbytes_serve + cache_read + state_bytes
    return FlopBytes(flops, bytes_, 2.0 * n_act * b)
