"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link (~ICI); DCN pod axis slower
