"""The deployed stopping procedure A_lambda and its risk/savings metrics
(paper Section 3.4, Algorithm 2, Section 4.1 Metrics).

Because the inference-time inner updates are label-free and causal, the
score trajectory s_1..s_T of the deployed procedure does not depend on the
threshold; tau_lambda is a simple first-crossing functional of the smoothed
trajectory.  This lets us evaluate the WHOLE grid from one pass — exactly
the structure LTT needs (calibrating the full adaptive procedure).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import calibration as C


def trajectory_lengths(mask: np.ndarray) -> np.ndarray:
    mask = np.asarray(mask, bool)
    return mask.sum(axis=1).astype(np.int64)


def stop_times(scores: np.ndarray, grid: Sequence[float],
               mask: Optional[np.ndarray] = None,
               burn_in: int = 10) -> np.ndarray:
    """First crossing tau_lambda = min{t : s_t >= lambda} per problem/threshold.

    scores: (N, T) smoothed deployed-procedure scores.
    Returns (N, m) stop indices in [0, T_i]; T_i (budget exhausted) if the
    threshold is never crossed. Index semantics: stopping at index t means
    the answer after step t+1 is emitted; tau == T_i means full budget.

    ``burn_in``: stopping is disabled for the first ``burn_in`` steps of each
    trajectory (the probe's online adaptation warm-up; part of the deployed
    decision rule, hence covered by the LTT calibration of the whole
    procedure).  Applied identically to every probe being compared.
    """
    scores = np.asarray(scores, np.float64)
    n, t = scores.shape
    if mask is None:
        lens = np.full((n,), t, np.int64)
        valid = np.ones_like(scores, bool)
    else:
        valid = np.asarray(mask, bool)
        lens = trajectory_lengths(valid)
    if burn_in > 0:
        valid = valid.copy()
        valid[:, :burn_in] = False
    grid = np.asarray(list(grid), np.float64)
    crossed = (scores[:, :, None] >= grid[None, None, :]) & valid[:, :, None]
    first = np.argmax(crossed, axis=1)                       # 0 if never
    any_cross = crossed.any(axis=1)
    return np.where(any_cross, first, lens[:, None])


def procedure_risk(tau: np.ndarray, labels: np.ndarray,
                   mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Binary loss R = 1{stopped early at a still-incorrect step}.

    tau (N, m); labels (N, T) cumulative.  Stopping at tau < T_i with
    label[tau] == 0 is an error; running to the budget is never charged
    (matches the paper: "only stopping too early leads to an error").
    """
    labels = np.asarray(labels) > 0.5
    n, t = labels.shape
    if mask is None:
        lens = np.full((n,), t, np.int64)
    else:
        lens = trajectory_lengths(mask)
    tau_c = np.minimum(tau, t - 1)
    lab_at_tau = np.take_along_axis(labels, tau_c, axis=1)
    early = tau < lens[:, None]
    return (early & ~lab_at_tau).astype(np.float64)


def step_savings(steps_used: np.ndarray, budget: np.ndarray) -> np.ndarray:
    """THE savings metric: per-problem fraction of the step budget not spent,
    ``1 - steps_used / budget`` (clipped at 0).

    Shared by the offline evaluation (``savings``, budget = per-trajectory
    length T_i) and the serving engine / scheduler (budget =
    max_new_tokens // tokens_per_step), so served savings and
    offline-evaluated savings are directly comparable.
    """
    steps_used = np.asarray(steps_used, np.float64)
    budget = np.asarray(budget, np.float64)
    return np.maximum(1.0 - steps_used / np.maximum(budget, 1.0), 0.0)


def savings(tau: np.ndarray, mask: Optional[np.ndarray] = None,
            lengths: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-problem savings 1 - (tau+1)/T aggregated per threshold (mean).

    tau == T means zero savings.  Matches the paper's step-level metric
    (Fig. 4 reports the same per-problem distribution).
    """
    if lengths is None:
        assert mask is not None
        lengths = trajectory_lengths(mask)
    steps_used = np.minimum(tau + 1, lengths[:, None])
    per_problem = step_savings(steps_used, lengths[:, None])
    return per_problem.mean(axis=0)


@dataclasses.dataclass
class EvalResult:
    delta: float
    lam: float
    savings: float
    error: float
    ltt: C.LTTResult

    def row(self) -> Dict[str, float]:
        return {"delta": self.delta, "lambda": self.lam,
                "savings": self.savings, "error": self.error}


def calibrate_and_evaluate(cal_scores, cal_labels, cal_mask,
                           test_scores, test_labels, test_mask,
                           *, delta: float, eps: float = 0.05,
                           grid: Optional[np.ndarray] = None) -> EvalResult:
    """Full LTT pipeline: calibrate lambda* on the calibration split, then
    report test savings/error of the deployed procedure at lambda*."""
    grid = C.default_grid() if grid is None else grid
    tau_cal = stop_times(cal_scores, grid, cal_mask)
    risk_cal = procedure_risk(tau_cal, cal_labels, cal_mask)
    res = C.ltt_calibrate(risk_cal, grid, delta=delta, eps=eps)
    lam = res.lam
    if math.isinf(lam):
        # never stop early: zero savings, zero stopping risk
        return EvalResult(delta, lam, 0.0, 0.0, res)
    tau = stop_times(test_scores, [lam], test_mask)
    err = procedure_risk(tau, test_labels, test_mask).mean(axis=0)[0]
    sav = savings(tau, test_mask)[0]
    return EvalResult(delta, lam, float(sav), float(err), res)


def sweep_deltas(cal, test, deltas: Sequence[float], eps: float = 0.05,
                 grid: Optional[np.ndarray] = None):
    """cal/test: (scores, labels, mask) triples. Returns list of EvalResult."""
    return [calibrate_and_evaluate(*cal, *test, delta=d, eps=eps, grid=grid)
            for d in deltas]


# ---------------------------------------------------------------------------
# self-consistency group consensus (group-serving subsystem)
#
# A group of N samples of one prompt votes at every reasoning step: each
# sample's vote is its latest answer hash weighted by its latest smoothed
# probe score (the probe's confidence IS the weight — no extra model).  The
# consensus procedure A^g_lambda stops the whole group the first time the
# top answer's weight share crosses lambda.  Like the per-sample procedure,
# the vote trajectory does not depend on the threshold, so one pass
# evaluates the entire LTT grid.


def weighted_vote(scores: np.ndarray, answers: np.ndarray,
                  active: np.ndarray):
    """Confidence-weighted majority vote over a group's current answers.

    scores/answers/active: (n,) per-sample latest smoothed probe score,
    latest answer hash, and liveness (a sample with no recorded score yet
    does not vote).  Returns ``(answer, agreement)`` where agreement is the
    top answer's weight share in [0, 1].  Ties break toward the SMALLER
    answer hash so the served and offline procedures agree bit-for-bit.
    """
    scores = np.asarray(scores, np.float64)
    answers = np.asarray(answers, np.int64)
    active = np.asarray(active, bool)
    w = np.clip(scores, 0.0, None) * active
    total = float(w.sum())
    if total <= 0.0:
        return -1, 0.0
    uniq = np.unique(answers[active])            # sorted: first max wins tie
    weight = np.array([float(w[(answers == a) & active].sum())
                       for a in uniq])
    best = int(np.argmax(weight))
    return int(uniq[best]), float(weight[best] / total)


def consensus_trace(scores: np.ndarray, answers: np.ndarray,
                    lengths: np.ndarray,
                    per_sample_tau: Optional[np.ndarray] = None):
    """Per-step (answer_t, agreement_t) of one group's weighted vote.

    scores/answers: (n, T) per-sample trajectories; lengths: (n,).  Sample
    i's vote at step t is FROZEN at index ``min(t, freeze_i)``: after its
    own ORCA stop (``per_sample_tau``) or budget end the sample keeps voting
    its final answer with its final confidence — exactly what the scheduler
    sees from an evicted sibling's recorded history.  Returns
    ``(answer (Tg,), agreement (Tg,))`` with Tg = max(lengths).
    """
    scores = np.asarray(scores, np.float64)
    answers = np.asarray(answers, np.int64)
    lengths = np.asarray(lengths, np.int64)
    n = scores.shape[0]
    freeze = lengths - 1
    if per_sample_tau is not None:
        freeze = np.minimum(np.asarray(per_sample_tau, np.int64), freeze)
    t_grp = int(lengths.max())
    ans = np.full((t_grp,), -1, np.int64)
    agr = np.zeros((t_grp,), np.float64)
    rows = np.arange(n)
    active = lengths > 0
    for t in range(t_grp):
        idx = np.minimum(t, freeze)
        ans[t], agr[t] = weighted_vote(scores[rows, idx],
                                       answers[rows, idx], active)
    return ans, agr


def consensus_stop_times(agreement: np.ndarray, grid: Sequence[float],
                         burn_in: int = 10) -> np.ndarray:
    """First consensus crossing per threshold: min{t >= burn_in :
    agreement_t >= g}, or Tg (= len(agreement), never fired) per threshold.
    Same first-crossing/burn-in semantics as the per-sample ``stop_times``.
    """
    agreement = np.asarray(agreement, np.float64)
    t_grp = agreement.shape[0]
    valid = np.ones((t_grp,), bool)
    valid[:burn_in] = False
    grid = np.asarray(list(grid), np.float64)
    crossed = (agreement[:, None] >= grid[None, :]) & valid[:, None]
    first = np.argmax(crossed, axis=0)
    return np.where(crossed.any(axis=0), first, t_grp)


def consensus_risk(tau_g: np.ndarray, answer_trace: np.ndarray,
                   truth: int) -> np.ndarray:
    """Group-level binary loss: 1{consensus fired AND its answer is wrong}.

    Conservative relative to the per-sample loss: a wrong consensus is
    charged even when it fires at the final step (the group COMMITS to the
    vote; there is no "ran to budget" escape once it fires).  Never firing
    is never charged — the fleet then falls back to per-sample stops.
    """
    tau_g = np.asarray(tau_g, np.int64)
    t_grp = answer_trace.shape[0]
    fired = tau_g < t_grp
    ans = answer_trace[np.minimum(tau_g, t_grp - 1)]
    return (fired & (ans != truth)).astype(np.float64)
