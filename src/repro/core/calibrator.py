"""Unified ``Calibrator`` protocol: fit -> scores -> calibrate -> threshold.

Every probe the paper compares (the meta-learned TTT probe, the static
PCA+logreg baseline) is a *calibrated stopping procedure*: it scores step
embeddings, gets LTT-calibrated on a held-out split and yields a threshold
lambda* that the serving engine deploys.  Before this module each caller
re-plumbed that path by hand (``TrainedProbe`` vs ``StaticProbe`` vs ad-hoc
driver glue); now both implementations expose one protocol and the
``repro.api`` facade composes them with the serving stack.

    cal = TTTCalibrator(epochs=25).fit(train, mode="supervised")
    lam = cal.calibrate(cal_split, delta=0.1)      # LTT lambda*
    s   = cal.scores(test_split)                   # deployed smoothed scores
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import calibration as C
from repro.core import stopping as S
from repro.core.probe import ProbeConfig
from repro.trajectories import TrajectorySet


@runtime_checkable
class Calibrator(Protocol):
    """The unified probe-side API the facade and drivers are written against."""
    method: str                    # "ttt" | "static"
    mode: str                      # label mode bound at fit() time

    def fit(self, train: TrajectorySet, mode: str) -> "Calibrator":
        """Train on ``train`` with "supervised" or "consistent" labels."""
        ...

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        """Deployed-procedure smoothed scores, (N, T) masked."""
        ...

    def calibrate(self, cal: TrajectorySet, delta: float,
                  eps: float = 0.05) -> float:
        """LTT-calibrate lambda* at risk level delta (FWER eps)."""
        ...

    def threshold(self) -> float:
        """The calibrated lambda* (inf => never stop early)."""
        ...


class _LTTMixin:
    """Shared calibrate/threshold: LTT over the deployed score trajectories,
    with labels in the SAME mode the probe was fitted with (label-free
    deployment for the consistent mode)."""
    mode: str = ""
    _lam: Optional[float] = None
    _ltt: Optional[C.LTTResult] = None

    def calibrate(self, cal: TrajectorySet, delta: float, eps: float = 0.05,
                  grid: Optional[np.ndarray] = None) -> float:
        from repro.core.pipeline import make_labels
        if not self.mode:
            raise RuntimeError("fit() must run before calibrate()")
        grid = C.default_grid() if grid is None else grid
        labels = make_labels(cal, self.mode)
        s = self.scores(cal)
        tau = S.stop_times(s, grid, cal.mask)
        risk = S.procedure_risk(tau, labels, cal.mask)
        self._ltt = C.ltt_calibrate(risk, grid, delta=delta, eps=eps)
        self._lam = self._ltt.lam
        return self._lam

    def threshold(self) -> float:
        if self._lam is None:
            raise RuntimeError("calibrate() must run before threshold()")
        return self._lam

    @property
    def ltt(self) -> Optional[C.LTTResult]:
        return self._ltt


@dataclasses.dataclass
class TTTCalibrator(_LTTMixin):
    """The paper's probe: meta-trained TTT fast-weight scorer (Algorithm 1).

    Thin stateful wrapper over ``repro.core.pipeline.train_ttt_probe`` —
    identical numbers, protocol-shaped.  ``serving_params()`` hands the
    (ProbeConfig, theta) pair to the fused serving engine.
    """
    pc: Optional[ProbeConfig] = None
    epochs: int = 40
    batch_size: int = 64
    outer_lr: float = 1e-2
    seed: int = 0
    epoch_select: bool = True
    verbose: bool = False
    method: str = dataclasses.field(default="ttt", init=False)
    mode: str = dataclasses.field(default="", init=False)
    probe: Optional[object] = dataclasses.field(default=None, init=False)

    def fit(self, train: TrajectorySet, mode: str) -> "TTTCalibrator":
        from repro.core.pipeline import train_ttt_probe
        pc = self.pc or ProbeConfig(d_phi=train.phis.shape[-1])
        self.probe = train_ttt_probe(
            train, mode, pc, epochs=self.epochs, batch_size=self.batch_size,
            outer_lr=self.outer_lr, seed=self.seed,
            epoch_select=self.epoch_select, verbose=self.verbose)
        self.pc, self.mode = pc, mode
        return self

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        if self.probe is None:
            raise RuntimeError("fit() must run before scores()")
        return self.probe.scores(ts)

    def serving_params(self):
        """(ProbeConfig, theta) for the fused serve step / scheduler.

        Validated round-trip into the kernel state: the serving engine
        initializes each slot's fast weights from ``theta["W0"]/["b0"]``
        (``serving.engine.init_probe_state``) and the Pallas
        ``serving_probe_step`` consumes exactly (W (B, feat_dim), b (B,)),
        so a shape mismatch here would only surface as a cryptic kernel
        error mid-serve — check it at the seam instead.
        """
        if self.probe is None:
            raise RuntimeError("fit() must run before serving_params()")
        pc, theta = self.probe.pc, self.probe.theta
        w0 = np.asarray(theta["W0"])
        if w0.shape != (pc.feat_dim,):
            raise ValueError(
                f"theta['W0'] {w0.shape} does not round-trip into the "
                f"kernel's per-slot state (expected ({pc.feat_dim},))")
        return pc, theta


@dataclasses.dataclass
class StaticCalibrator(_LTTMixin):
    """The static baseline: PCA + logistic regression, no online adaptation.

    Protocol-shaped wrapper over ``fit_static_probe`` (Wu et al., 2025 —
    the paper's "Static Probe" row).  ``serving_params`` flattens
    PCA+logreg into an equivalent frozen linear probe (eta = 0, so the
    kernel's score-then-update never moves the weights), which lets the
    SAME fused serving engine deploy the static baseline — the validity
    regression suite runs both probes through one hot path.
    """
    n_components: int = 64
    epochs: int = 200
    lr: float = 1e-2
    smooth_window: int = 10
    seed: int = 0
    method: str = dataclasses.field(default="static", init=False)
    mode: str = dataclasses.field(default="", init=False)
    probe: Optional[object] = dataclasses.field(default=None, init=False)

    def fit(self, train: TrajectorySet, mode: str) -> "StaticCalibrator":
        from repro.core.pipeline import make_labels
        from repro.core.static_probe import fit_static_probe
        self.probe = fit_static_probe(
            train.phis, make_labels(train, mode), train.mask,
            n_components=self.n_components, epochs=self.epochs, lr=self.lr,
            smooth_window=self.smooth_window, seed=self.seed)
        self.mode = mode
        return self

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        if self.probe is None:
            raise RuntimeError("fit() must run before scores()")
        return self.probe.scores(ts.phis, ts.mask)

    def serving_params(self):
        """Flatten PCA + logreg into kernel state for the fused engine.

        s = sigma(w . P^T (phi - mu) + b) == sigma(W_eff . phi + b_eff)
        with W_eff = P w and b_eff = b - mu . P w; eta = 0 freezes the
        kernel's inner update, so the served scores equal the offline
        ``scores()`` path (round-trip asserted by the validity suite).
        """
        if self.probe is None:
            raise RuntimeError("fit() must run before serving_params()")
        p = self.probe
        w_eff = p.components @ p.w                     # (d,)
        b_eff = float(p.b - p.mean @ w_eff)
        pc = ProbeConfig(d_phi=int(p.mean.shape[0]), variant="noqk",
                         eta=0.0, smooth_window=p.smooth_window)
        theta = {"W0": jnp.asarray(w_eff, jnp.float32),
                 "b0": jnp.asarray(b_eff, jnp.float32)}
        return pc, theta


_REGISTRY = {"ttt": TTTCalibrator, "static": StaticCalibrator}


def make_calibrator(method: str, **kwargs) -> Calibrator:
    """Factory over the registered Calibrator implementations."""
    try:
        cls = _REGISTRY[method]
    except KeyError:
        raise ValueError(f"unknown calibrator {method!r}; "
                         f"known: {sorted(_REGISTRY)}") from None
    return cls(**kwargs)
