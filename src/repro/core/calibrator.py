"""Unified ``Calibrator`` protocol: fit -> scores -> calibrate -> threshold.

Every probe the paper compares (the meta-learned TTT probe, the static
PCA+logreg baseline) is a *calibrated stopping procedure*: it scores step
embeddings, gets LTT-calibrated on a held-out split and yields a threshold
lambda* that the serving engine deploys.  Before this module each caller
re-plumbed that path by hand (``TrainedProbe`` vs ``StaticProbe`` vs ad-hoc
driver glue); now both implementations expose one protocol and the
``repro.api`` facade composes them with the serving stack.

    cal = TTTCalibrator(epochs=25).fit(train, mode="supervised")
    lam = cal.calibrate(cal_split, delta=0.1)      # LTT lambda*
    s   = cal.scores(test_split)                   # deployed smoothed scores
"""
from __future__ import annotations

import dataclasses
from typing import (List, NamedTuple, Optional, Protocol, Sequence,
                    runtime_checkable)

import jax.numpy as jnp
import numpy as np

from repro.core import calibration as C
from repro.core import stopping as S
from repro.core.probe import ProbeConfig
from repro.trajectories import TrajectorySet


@runtime_checkable
class Calibrator(Protocol):
    """The unified probe-side API the facade and drivers are written against."""
    method: str                    # "ttt" | "static"
    mode: str                      # label mode bound at fit() time

    def fit(self, train: TrajectorySet, mode: str) -> "Calibrator":
        """Train on ``train`` with "supervised" or "consistent" labels."""
        ...

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        """Deployed-procedure smoothed scores, (N, T) masked."""
        ...

    def calibrate(self, cal: TrajectorySet, delta: float,
                  eps: float = 0.05) -> float:
        """LTT-calibrate lambda* at risk level delta (FWER eps)."""
        ...

    def threshold(self) -> float:
        """The calibrated lambda* (inf => never stop early)."""
        ...


class _LTTMixin:
    """Shared calibrate/threshold: LTT over the deployed score trajectories,
    with labels in the SAME mode the probe was fitted with (label-free
    deployment for the consistent mode)."""
    mode: str = ""
    _lam: Optional[float] = None
    _ltt: Optional[C.LTTResult] = None

    def calibrate(self, cal: TrajectorySet, delta: float, eps: float = 0.05,
                  grid: Optional[np.ndarray] = None) -> float:
        from repro.core.pipeline import make_labels
        if not self.mode:
            raise RuntimeError("fit() must run before calibrate()")
        grid = C.default_grid() if grid is None else grid
        labels = make_labels(cal, self.mode)
        s = self.scores(cal)
        tau = S.stop_times(s, grid, cal.mask)
        risk = S.procedure_risk(tau, labels, cal.mask)
        self._ltt = C.ltt_calibrate(risk, grid, delta=delta, eps=eps)
        self._lam = self._ltt.lam
        return self._lam

    def threshold(self) -> float:
        if self._lam is None:
            raise RuntimeError("calibrate() must run before threshold()")
        return self._lam

    @property
    def ltt(self) -> Optional[C.LTTResult]:
        return self._ltt


@dataclasses.dataclass
class TTTCalibrator(_LTTMixin):
    """The paper's probe: meta-trained TTT fast-weight scorer (Algorithm 1).

    Thin stateful wrapper over ``repro.core.pipeline.train_ttt_probe`` —
    identical numbers, protocol-shaped.  ``serving_params()`` hands the
    (ProbeConfig, theta) pair to the fused serving engine.
    """
    pc: Optional[ProbeConfig] = None
    epochs: int = 40
    batch_size: int = 64
    outer_lr: float = 1e-2
    seed: int = 0
    epoch_select: bool = True
    verbose: bool = False
    method: str = dataclasses.field(default="ttt", init=False)
    mode: str = dataclasses.field(default="", init=False)
    probe: Optional[object] = dataclasses.field(default=None, init=False)

    def fit(self, train: TrajectorySet, mode: str) -> "TTTCalibrator":
        from repro.core.pipeline import train_ttt_probe
        pc = self.pc or ProbeConfig(d_phi=train.phis.shape[-1])
        self.probe = train_ttt_probe(
            train, mode, pc, epochs=self.epochs, batch_size=self.batch_size,
            outer_lr=self.outer_lr, seed=self.seed,
            epoch_select=self.epoch_select, verbose=self.verbose)
        self.pc, self.mode = pc, mode
        return self

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        if self.probe is None:
            raise RuntimeError("fit() must run before scores()")
        return self.probe.scores(ts)

    def serving_params(self):
        """(ProbeConfig, theta) for the fused serve step / scheduler.

        Validated round-trip into the kernel state: the serving engine
        initializes each slot's fast weights from ``theta["W0"]/["b0"]``
        (``serving.engine.init_probe_state``) and the Pallas
        ``serving_probe_step`` consumes exactly (W (B, feat_dim), b (B,)),
        so a shape mismatch here would only surface as a cryptic kernel
        error mid-serve — check it at the seam instead.
        """
        if self.probe is None:
            raise RuntimeError("fit() must run before serving_params()")
        pc, theta = self.probe.pc, self.probe.theta
        w0 = np.asarray(theta["W0"])
        if w0.shape != (pc.feat_dim,):
            raise ValueError(
                f"theta['W0'] {w0.shape} does not round-trip into the "
                f"kernel's per-slot state (expected ({pc.feat_dim},))")
        return pc, theta


@dataclasses.dataclass
class StaticCalibrator(_LTTMixin):
    """The static baseline: PCA + logistic regression, no online adaptation.

    Protocol-shaped wrapper over ``fit_static_probe`` (Wu et al., 2025 —
    the paper's "Static Probe" row).  ``serving_params`` flattens
    PCA+logreg into an equivalent frozen linear probe (eta = 0, so the
    kernel's score-then-update never moves the weights), which lets the
    SAME fused serving engine deploy the static baseline — the validity
    regression suite runs both probes through one hot path.
    """
    n_components: int = 64
    epochs: int = 200
    lr: float = 1e-2
    smooth_window: int = 10
    seed: int = 0
    method: str = dataclasses.field(default="static", init=False)
    mode: str = dataclasses.field(default="", init=False)
    probe: Optional[object] = dataclasses.field(default=None, init=False)

    def fit(self, train: TrajectorySet, mode: str) -> "StaticCalibrator":
        from repro.core.pipeline import make_labels
        from repro.core.static_probe import fit_static_probe
        self.probe = fit_static_probe(
            train.phis, make_labels(train, mode), train.mask,
            n_components=self.n_components, epochs=self.epochs, lr=self.lr,
            smooth_window=self.smooth_window, seed=self.seed)
        self.mode = mode
        return self

    def scores(self, ts: TrajectorySet) -> np.ndarray:
        if self.probe is None:
            raise RuntimeError("fit() must run before scores()")
        return self.probe.scores(ts.phis, ts.mask)

    def serving_params(self):
        """Flatten PCA + logreg into kernel state for the fused engine.

        s = sigma(w . P^T (phi - mu) + b) == sigma(W_eff . phi + b_eff)
        with W_eff = P w and b_eff = b - mu . P w; eta = 0 freezes the
        kernel's inner update, so the served scores equal the offline
        ``scores()`` path (round-trip asserted by the validity suite).
        """
        if self.probe is None:
            raise RuntimeError("fit() must run before serving_params()")
        p = self.probe
        w_eff = p.components @ p.w                     # (d,)
        b_eff = float(p.b - p.mean @ w_eff)
        pc = ProbeConfig(d_phi=int(p.mean.shape[0]), variant="noqk",
                         eta=0.0, smooth_window=p.smooth_window)
        theta = {"W0": jnp.asarray(w_eff, jnp.float32),
                 "b0": jnp.asarray(b_eff, jnp.float32)}
        return pc, theta


# ---------------------------------------------------------------------------
# self-consistency group consensus (group-serving subsystem)


class GroupTrace(NamedTuple):
    """One calibration group: per-sample score/answer trajectories + truth."""
    scores: np.ndarray     # (n, T) smoothed deployed-procedure scores
    answers: np.ndarray    # (n, T) per-step answer hashes
    lengths: np.ndarray    # (n,) trajectory lengths
    truth: int             # the group's reference answer hash (-1: none)


@dataclasses.dataclass
class GroupCalibrator:
    """Conformal consensus stop for self-consistency groups.

    Aggregates a group's per-sample probe scores into one stop decision:
    at each reasoning step the samples vote their latest answer hash,
    weighted by their latest smoothed probe score, and the group stops the
    first time the top answer's weight share clears ``lam`` (after
    ``burn_in`` steps, with at least ``min_votes`` live voters).  ``lam``
    is LTT-calibrated over group-level risk (wrong consensus fired), so
    P(group risk <= delta) >= 1 - eps at the GROUP level — groups, not
    samples, are the exchangeable calibration unit.

    Serving parity: the scheduler's per-step ``decide`` uses each sample's
    LATEST recorded (score, answer).  Under gang admission with non-chunked
    prefill the samples advance in lockstep, so the served decision
    sequence equals the offline ``consensus_trace`` bit-for-bit — the same
    contract the per-sample procedure keeps.
    """
    min_votes: int = 2
    burn_in: int = 10
    lam: Optional[float] = None      # consensus threshold (inf: never fire)
    delta: Optional[float] = None    # risk level lam was calibrated at
    _ltt: Optional[C.LTTResult] = dataclasses.field(default=None, init=False)

    def calibrate(self, groups: Sequence[GroupTrace], delta: float,
                  eps: float = 0.05, grid: Optional[np.ndarray] = None,
                  per_sample_lam: Optional[float] = None,
                  per_sample_burn_in: Optional[int] = None) -> float:
        """LTT-calibrate the consensus threshold over ``groups``.

        ``per_sample_lam``: the deployed per-sample ORCA threshold — each
        sample's vote freezes at its own stop, exactly as served (pass the
        engine's lambda*; None = samples vote to their full length).
        """
        grid = C.default_grid() if grid is None else grid
        psb = self.burn_in if per_sample_burn_in is None else per_sample_burn_in
        risks = []
        for g in groups:
            if g.scores.shape[0] < self.min_votes:
                risks.append(np.zeros((len(grid),)))   # can never fire
                continue
            tau_i = None
            if per_sample_lam is not None and np.isfinite(per_sample_lam):
                mask = (np.arange(g.scores.shape[1])[None, :]
                        < np.asarray(g.lengths)[:, None])
                tau_i = S.stop_times(g.scores, [per_sample_lam], mask,
                                     burn_in=psb)[:, 0]
            ans_t, agr_t = S.consensus_trace(g.scores, g.answers, g.lengths,
                                             per_sample_tau=tau_i)
            tau_g = S.consensus_stop_times(agr_t, grid, self.burn_in)
            risks.append(S.consensus_risk(tau_g, ans_t, int(g.truth)))
        self._ltt = C.ltt_calibrate(np.stack(risks), grid, delta=delta,
                                    eps=eps)
        self.lam = float(self._ltt.lam)
        self.delta = float(delta)
        return self.lam

    def threshold(self) -> float:
        if self.lam is None:
            raise RuntimeError(
                "GroupCalibrator has no threshold — run calibrate(...) "
                "first, or construct it with an explicit lam=")
        return self.lam

    @property
    def ltt(self) -> Optional[C.LTTResult]:
        return self._ltt

    def decide(self, scores: Sequence[Sequence[float]],
               answers: Sequence[Sequence[int]]):
        """One serving-time consensus check over a group's recorded
        per-sample histories (each sample votes its latest entry).

        Returns ``(fire, answer, agreement)``; ``fire`` is gated on
        ``min_votes`` live voters and the consensus ``burn_in``.
        """
        lam = self.threshold()
        active = np.array([len(s) > 0 for s in scores], bool)
        t = max((len(s) for s in scores), default=0) - 1
        s = np.array([s[-1] if len(s) else 0.0 for s in scores], np.float64)
        a = np.array([a[-1] if len(a) else -1 for a in answers], np.int64)
        ans, agr = S.weighted_vote(s, a, active)
        fire = (int(active.sum()) >= self.min_votes and t >= self.burn_in
                and agr >= lam)
        return fire, ans, agr


def groups_from_trajectories(ts: TrajectorySet, scores: np.ndarray,
                             group_size: int, *, seed: int = 0,
                             answers: Optional[np.ndarray] = None
                             ) -> List[GroupTrace]:
    """Chunk a TrajectorySet into self-consistency calibration groups.

    A seeded permutation is cut into consecutive groups of ``group_size``
    (remainder dropped) — iid trajectories make the groups exchangeable, so
    LTT at the group level stays valid.  ``answers`` overrides the per-step
    answer hashes (default ``ts.answers``); the group truth is the
    confidence-weighted-vote answer over final steps of SOLVED samples
    (-1, unmatchable, when no sample solves the problem).
    """
    if group_size < 1:
        raise ValueError(f"group_size must be >= 1, got {group_size}")
    answers = ts.answers if answers is None else answers
    order = np.random.RandomState(seed).permutation(len(ts))
    groups = []
    for g0 in range(0, len(order) - group_size + 1, group_size):
        idx = order[g0:g0 + group_size]
        lengths = ts.lengths[idx]
        final = answers[idx, lengths - 1]
        solved = np.array([bool(ts.correct[i].any()) for i in idx])
        if solved.any():
            truth, _ = S.weighted_vote(np.ones_like(final, np.float64),
                                       final, solved)
        else:
            truth = -1
        groups.append(GroupTrace(scores=scores[idx], answers=answers[idx],
                                 lengths=lengths, truth=int(truth)))
    return groups


_REGISTRY = {"ttt": TTTCalibrator, "static": StaticCalibrator}


def make_calibrator(method: str, **kwargs) -> Calibrator:
    """Factory over the registered Calibrator implementations."""
    try:
        cls = _REGISTRY[method]
    except KeyError:
        raise ValueError(f"unknown calibrator {method!r}; "
                         f"known: {sorted(_REGISTRY)}") from None
    return cls(**kwargs)
