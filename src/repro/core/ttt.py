"""TTT inner/outer loops (paper Section 3.2-3.3, Algorithm 1).

Inner loop (per reasoning trajectory, *score-then-update*):
    s_t  = sigma(W_{t-1} . z_Q(phi_t) + b_{t-1})
    l    = (sigma(W_{t-1} . z_K(phi_t) + b_{t-1}) - C_t)^2
    W_t  = W_{t-1} - eta * grad_W l           (online gradient descent)

At inference C_t = 0 for every non-stopping step (self-supervised novelty
detector, Appendix B).  In meta-training the inner labels follow
``inner_label_mode``: "zero" keeps training == inference dynamics (default),
"true" uses the trajectory labels as in Eq. (6).

Outer loop (Algorithm 1): unroll the inner updates along the trajectory and
minimize sum_t (s_t - C_t^true)^2 through the unroll (optionally truncated
BPTT), training Theta_outer = (theta_QK, W0, b0, [eta]).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe as P
from repro.core.probe import ProbeConfig


class UnrollOut(NamedTuple):
    scores: jnp.ndarray          # raw (unsmoothed) probe scores, (T,)
    fast_final: Tuple[jnp.ndarray, jnp.ndarray]


def inner_unroll(pc: ProbeConfig, theta, phis: jnp.ndarray,
                 inner_labels: Optional[jnp.ndarray] = None,
                 mask: Optional[jnp.ndarray] = None,
                 kernel: Optional[Callable] = None) -> UnrollOut:
    """Unroll the TTT inner loop over one trajectory.

    phis: (T, d_phi); inner_labels: (T,) or None (=> all zeros, inference
    mode); mask: (T,) validity for padded trajectories.  ``kernel`` optionally
    swaps the step loop for the fused Pallas implementation.
    """
    T = phis.shape[0]
    eta = P.inner_lr(pc, theta)
    zq, zk = P.features(pc, theta, phis)              # (T, f)
    c = jnp.zeros((T,), jnp.float32) if inner_labels is None else inner_labels
    m = jnp.ones((T,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    if kernel is not None:
        scores, W_f, b_f = kernel(zq, zk, c, m, theta["W0"], theta["b0"], eta)
        return UnrollOut(scores, (W_f, b_f))

    trunc = pc.bptt_truncation

    def step(fast, xs):
        zq_t, zk_t, c_t, m_t, t = xs
        s_t = P.score(fast, zq_t)
        gW, gb = P.brier_grad(fast, zk_t, c_t)
        W, b = fast
        W_new = W - eta * m_t * gW
        b_new = b - eta * m_t * gb
        if trunc > 0:
            cut = (t % trunc) == 0
            W_new = jnp.where(cut, jax.lax.stop_gradient(W_new), W_new)
            b_new = jnp.where(cut, jax.lax.stop_gradient(b_new), b_new)
        return (W_new, b_new), s_t

    fast0 = P.fast_init(pc, theta)
    xs = (zq, zk, c, m, jnp.arange(T))
    fast_T, scores = jax.lax.scan(step, fast0, xs)
    return UnrollOut(scores, fast_T)


def batched_unroll(pc: ProbeConfig, theta, phis, inner_labels=None, mask=None,
                   kernel: Optional[Callable] = None):
    """phis (N, T, d_phi) -> scores (N, T)."""
    fn = lambda p, c, m: inner_unroll(pc, theta, p, c, m, kernel=kernel).scores
    c = (jnp.zeros(phis.shape[:2], jnp.float32)
         if inner_labels is None else inner_labels)
    m = jnp.ones(phis.shape[:2], jnp.float32) if mask is None else mask
    return jax.vmap(fn)(phis, c, m)


# ---------------------------------------------------------------------------
# Outer (meta) objective — Algorithm 1

def outer_loss(pc: ProbeConfig, theta, phis, labels, mask=None) -> jnp.ndarray:
    """Mean over problems of sum_t m_t (s_t - C_t^true)^2.

    phis (N,T,d); labels (N,T) in {0,1}; mask (N,T).
    Inner updates use zeros ("zero" mode) or the true labels ("true" mode).
    """
    inner = None if pc.inner_label_mode == "zero" else labels
    scores = batched_unroll(pc, theta, phis, inner_labels=inner, mask=mask)
    m = jnp.ones_like(scores) if mask is None else mask.astype(scores.dtype)
    per_problem = jnp.sum(m * jnp.square(scores - labels), axis=-1)
    return jnp.mean(per_problem)


def make_outer_step(pc: ProbeConfig, optimizer) -> Callable:
    """jit'd meta-training step: (theta, opt_state, batch) -> (theta', opt', loss)."""

    @jax.jit
    def step(theta, opt_state, phis, labels, mask):
        loss, grads = jax.value_and_grad(
            lambda th: outer_loss(pc, th, phis, labels, mask))(theta)
        updates, opt_state = optimizer.update(grads, opt_state, theta)
        theta = jax.tree.map(lambda p, u: p + u, theta, updates)
        return theta, opt_state, loss

    return step


def meta_train(pc: ProbeConfig, theta, optimizer, phis, labels, mask,
               *, epochs: int, batch_size: int, rng,
               eval_fn: Optional[Callable] = None,
               verbose: bool = False):
    """Full outer-loop training (Algorithm 1). Returns (theta, history)."""
    n = phis.shape[0]
    opt_state = optimizer.init(theta)
    step = make_outer_step(pc, optimizer)
    history = []
    for epoch in range(epochs):
        rng, prm = jax.random.split(rng)
        order = jax.random.permutation(prm, n)
        losses = []
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            theta, opt_state, loss = step(theta, opt_state, phis[idx],
                                          labels[idx], mask[idx])
            losses.append(float(loss))
        rec = {"epoch": epoch + 1, "loss": sum(losses) / max(len(losses), 1)}
        if eval_fn is not None:
            rec.update(eval_fn(theta))
        history.append(rec)
        if verbose:
            print(f"[meta] epoch {rec['epoch']:3d} loss {rec['loss']:.4f}")
    return theta, history


# ---------------------------------------------------------------------------
# Deployment-time score trajectories (the "deployed procedure" scores)

def deployed_scores(pc: ProbeConfig, theta, phis, mask=None,
                    kernel: Optional[Callable] = None) -> jnp.ndarray:
    """Scores produced by the deployed procedure (C_t = 0 inner updates),
    smoothed with the configured rolling window.  phis (N,T,d) -> (N,T).

    Note updating past the stopping time does not change s_1..s_tau (updates
    are causal and label-free), so one pass serves every threshold lambda —
    this is what makes LTT calibration of the full adaptive procedure cheap.
    """
    raw = batched_unroll(pc, theta, phis, inner_labels=None, mask=mask,
                         kernel=kernel)
    return P.smooth_scores(raw, pc.smooth_window)
