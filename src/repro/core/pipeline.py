"""End-to-end ORCA pipeline: meta-train -> LTT-calibrate -> evaluate.

This is the high-level API the examples and benchmark tables are written
against.  It consumes ``TrajectorySet``s (synthetic or extracted from a real
model by the serving engine) and produces the paper's (savings, error)
metrics for the TTT probe and the static baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as C
from repro.core import labels as L
from repro.core import stopping as S
from repro.core import ttt
from repro.core.probe import ProbeConfig, init_outer
from repro.optim import Adam
from repro.trajectories import TrajectorySet


def make_labels(ts: TrajectorySet, mode: str) -> np.ndarray:
    if mode == "supervised":
        return L.supervised_labels(ts.correct, ts.mask)
    if mode == "consistent":
        return L.consistent_labels(ts.answers, ts.mask)
    raise ValueError(mode)


@dataclasses.dataclass
class TrainedProbe:
    pc: ProbeConfig
    theta: Dict[str, jnp.ndarray]
    history: List[Dict[str, float]]

    def scores(self, ts: TrajectorySet, kernel=None) -> np.ndarray:
        s = ttt.deployed_scores(self.pc, self.theta,
                                jnp.asarray(ts.phis), jnp.asarray(ts.mask),
                                kernel=kernel)
        return np.asarray(s) * ts.mask


def train_ttt_probe(train: TrajectorySet, mode: str, pc: ProbeConfig,
                    *, epochs: int = 40, batch_size: int = 64,
                    outer_lr: float = 1e-2, seed: int = 0,
                    epoch_select: bool = True, select_delta: float = 0.1,
                    verbose: bool = False) -> TrainedProbe:
    """Meta-train the TTT probe (Algorithm 1) with the paper's epoch-selection
    protocol (§C.4 / Table 10): every epoch, the deployed procedure is scored
    on a held-out validation slice of the TRAIN split and the epoch with the
    best LTT-calibrated savings at ``select_delta`` is kept.  This is what
    keeps the meta-learned probe out of the saturated over-trained regime
    (the QK variant "peaks early and overfits" — Table 10).

    NOTE: the paper meta-trains per-prompt (outer lr 1e-3, 20 epochs x 3000
    prompts = 60K updates); we train with batched outer steps, so the
    equivalent regime is fewer, larger steps at a higher lr (DESIGN.md §7).
    """
    labels_all = make_labels(train, mode)
    if epoch_select:
        n = len(train)
        n_val = max(8, n // 10)
        rs = np.random.RandomState(seed)
        order = rs.permutation(n)
        val_idx, tr_idx = order[:n_val], order[n_val:]
        val, tr = train.subset(val_idx), train.subset(tr_idx)
        labels = labels_all[tr_idx]
        val_labels = labels_all[val_idx]
    else:
        tr, labels, val = train, labels_all, None
    theta = init_outer(pc, jax.random.PRNGKey(seed))
    opt = Adam(lr=outer_lr, clip_norm=1.0)

    best = {"savings": -1.0, "theta": theta, "epoch": 0}

    def eval_fn(th):
        if val is None:
            return {}
        s = np.asarray(ttt.deployed_scores(pc, th, jnp.asarray(val.phis),
                                           jnp.asarray(val.mask))) * val.mask
        r = S.calibrate_and_evaluate(s, val_labels, val.mask,
                                     s, val_labels, val.mask,
                                     delta=select_delta)
        if r.savings > best["savings"]:
            best.update(savings=r.savings, theta=jax.tree.map(lambda x: x, th))
        return {"val_savings": r.savings, "val_error": r.error}

    theta, hist = ttt.meta_train(
        pc, theta, opt, jnp.asarray(tr.phis), jnp.asarray(labels),
        jnp.asarray(tr.mask), epochs=epochs, batch_size=batch_size,
        rng=jax.random.PRNGKey(seed + 1), verbose=verbose,
        eval_fn=eval_fn if epoch_select else None)
    if epoch_select and best["savings"] >= 0:
        theta = best["theta"]
    return TrainedProbe(pc, theta, hist)


@dataclasses.dataclass
class ProcedureEval:
    method: str
    mode: str
    results: List[S.EvalResult]

    def at(self, delta: float) -> S.EvalResult:
        for r in self.results:
            if abs(r.delta - delta) < 1e-9:
                return r
        raise KeyError(delta)


def evaluate_probe(scores_cal: np.ndarray, cal: TrajectorySet,
                   scores_test: np.ndarray, test: TrajectorySet,
                   mode: str, deltas: Sequence[float],
                   eps: float = 0.05, method: str = "ttt") -> ProcedureEval:
    """Calibrate on ``cal`` (labels in the SAME mode the probe was trained
    with — label-free deployment for the consistent mode) and evaluate risk
    against supervised ground truth on ``test`` (what the paper reports)."""
    lab_cal = make_labels(cal, mode)
    lab_test = L.supervised_labels(test.correct, test.mask)
    results = S.sweep_deltas(
        (scores_cal, lab_cal, cal.mask),
        (scores_test, lab_test, test.mask),
        deltas, eps=eps)
    return ProcedureEval(method, mode, results)


def run_orca(train: TrajectorySet, cal: TrajectorySet, test: TrajectorySet,
             *, mode: str = "supervised", pc: Optional[ProbeConfig] = None,
             deltas: Sequence[float] = (0.05, 0.1, 0.15, 0.2),
             epochs: int = 40, eps: float = 0.05, seed: int = 0,
             include_static: bool = True, verbose: bool = False
             ) -> Dict[str, ProcedureEval]:
    """DEPRECATED shim over the ``repro.api`` facade (kept so existing
    callers and the seed tests keep passing; same numbers by construction).

    New code:  ``orca.fit(train, mode) -> orca.evaluate(cal, test)``.
    Returns {"ttt": ProcedureEval, "static": ..., "_probe": TrainedProbe,
    "_static": StaticProbe} exactly as before.
    """
    import warnings

    from repro import api
    warnings.warn(
        "run_orca is a deprecated shim: call repro.api.fit / "
        "repro.api.evaluate directly (same numbers by construction)",
        DeprecationWarning, stacklevel=2)
    pc = pc or ProbeConfig(d_phi=train.phis.shape[-1])
    ttt_cal = api.fit(train, mode=mode, method="ttt", pc=pc, epochs=epochs,
                      seed=seed, verbose=verbose)
    out: Dict[str, ProcedureEval] = {}
    out["ttt"] = api.evaluate(ttt_cal, cal, test, deltas=deltas, eps=eps)
    out["_probe"] = ttt_cal.probe  # type: ignore
    if include_static:
        static_cal = api.fit(train, mode=mode, method="static")
        out["static"] = api.evaluate(static_cal, cal, test, deltas=deltas,
                                     eps=eps)
        out["_static"] = static_cal.probe  # type: ignore
    return out
