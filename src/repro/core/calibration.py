"""Conformal / Learn-then-Test calibration (paper Section 3.4, Appendix A).

LTT calibrates the *decision rule*: for an ordered grid of thresholds
lambda_1 > ... > lambda_m (conservative -> aggressive), test the mean-risk
null H_j : r(lambda_j) >= delta with one-sided binomial p-values on the
calibration set, apply fixed-sequence testing (FWER control at eps), and
select the most aggressive rejected threshold lambda*.  Guarantee (Thm A.2):
P(r(lambda*) <= delta) >= 1 - eps.

Also includes the split-conformal quantile (Eq. 4) used for prediction-set
style baselines.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

try:  # exact-ish binomial CDF via the regularized incomplete beta function
    from scipy.stats import binom as _scipy_binom  # pragma: no cover
    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False


def binom_cdf(k: int, n: int, p: float) -> float:
    """P(Binom(n, p) <= k), numerically-stable log-space summation."""
    if k < 0:
        return 0.0
    if k >= n:
        return 1.0
    if _HAVE_SCIPY:
        return float(_scipy_binom.cdf(k, n, p))
    # log-space cumulative sum
    logp, log1p_ = math.log(p), math.log1p(-p)
    # log C(n, i) built incrementally
    log_terms = []
    log_c = 0.0
    for i in range(0, k + 1):
        if i > 0:
            log_c += math.log(n - i + 1) - math.log(i)
        log_terms.append(log_c + i * logp + (n - i) * log1p_)
    mx = max(log_terms)
    return float(min(1.0, math.exp(mx) * sum(math.exp(t - mx) for t in log_terms)))


def binomial_pvalue(emp_risk: float, n: int, delta: float) -> float:
    """p^BT = P(Binom(n, delta) <= n * Rhat)  (Eq. 15)."""
    k = int(math.floor(emp_risk * n + 1e-9))
    return binom_cdf(k, n, delta)


def hoeffding_pvalue(emp_risk: float, n: int, delta: float) -> float:
    """Valid p-value for bounded (not necessarily binary) risks (Rmk A.4)."""
    if emp_risk >= delta:
        return 1.0
    return float(math.exp(-2.0 * n * (delta - emp_risk) ** 2))


@dataclasses.dataclass(frozen=True)
class LTTResult:
    lam: float                   # selected lambda* (inf => never stop early)
    rejected: np.ndarray         # bool per grid element
    pvalues: np.ndarray
    emp_risk: np.ndarray
    grid: np.ndarray


def ltt_calibrate(risk_matrix: np.ndarray, grid: Sequence[float],
                  delta: float, eps: float = 0.05,
                  pvalue: str = "binomial") -> LTTResult:
    """Fixed-sequence LTT over a threshold grid.

    risk_matrix: (n_cal, m) binary loss of the deployed procedure run at each
    grid threshold (column j <-> grid[j]).  ``grid`` must be sorted
    conservative -> aggressive (descending thresholds).
    """
    risk_matrix = np.asarray(risk_matrix, np.float64)
    grid = np.asarray(list(grid), np.float64)
    assert np.all(np.diff(grid) <= 1e-12), "grid must be descending (conservative first)"
    n, m = risk_matrix.shape
    assert m == len(grid)
    emp = risk_matrix.mean(axis=0)
    pfun = binomial_pvalue if pvalue == "binomial" else hoeffding_pvalue
    pvals = np.array([pfun(emp[j], n, delta) for j in range(m)])
    rejected = np.zeros(m, bool)
    lam = math.inf                      # sentinel: no rejection => never stop
    for j in range(m):                  # fixed-sequence testing
        if pvals[j] <= eps:
            rejected[j] = True
            lam = float(grid[j])
        else:
            break
    return LTTResult(lam=lam, rejected=rejected, pvalues=pvals,
                     emp_risk=emp, grid=grid)


def conformal_quantile(scores: Sequence[float], eps: float) -> float:
    """Split-conformal quantile (Eq. 4): Quantile_{ceil((n+1)(1-eps))/(n+1)}."""
    u = np.sort(np.asarray(list(scores), np.float64))
    n = len(u)
    k = math.ceil((n + 1) * (1.0 - eps))
    if k > n:
        return math.inf
    return float(u[k - 1])


def default_grid(lo: float = 0.5, hi: float = 0.995, m: int = 100) -> np.ndarray:
    """Descending threshold grid (conservative -> aggressive = high -> low)."""
    return np.linspace(hi, lo, m)
