"""Online recalibration under deployment drift (beyond-paper extension).

The paper's guarantee is marginal over the calibration distribution
(Remark A.3) and Appendix B notes that if the deployment policy or prompt
distribution changes one should re-calibrate.  This module makes that
operational: a rolling-window recalibrator that

  * keeps the most recent W deployed outcomes (score trajectory + label
    feedback, which in consistent-label mode is available label-free),
  * re-runs LTT on the window every ``every`` problems,
  * falls back to never-stop (lambda = inf) whenever the window's evidence
    cannot certify delta — inheriting LTT's finite-sample validity on any
    window that is exchangeable with the near-future.

This restores low risk under distribution shift at the cost of savings
during the adaptation transient — the system-level complement to the
probe-level adaptation ORCA already does.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.core import calibration as C
from repro.core import stopping as S


@dataclasses.dataclass
class RecalibratorConfig:
    delta: float = 0.1
    eps: float = 0.05
    window: int = 200            # problems kept for recalibration
    every: int = 25              # recalibrate cadence
    min_window: int = 50         # below this: never stop early
    burn_in: int = 10


class OnlineRecalibrator:
    """Streaming LTT: feed one problem at a time, read lambda* before each."""

    def __init__(self, cfg: RecalibratorConfig,
                 grid: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.grid = C.default_grid() if grid is None else grid
        self._scores: Deque[np.ndarray] = deque(maxlen=cfg.window)
        self._labels: Deque[np.ndarray] = deque(maxlen=cfg.window)
        self._seen = 0
        self.lam = math.inf
        self.history: List[Tuple[int, float]] = []

    def observe(self, scores: np.ndarray, labels: np.ndarray):
        """scores/labels: (T,) one deployed problem's smoothed trajectory and
        its (possibly consistency-mode) cumulative labels."""
        self._scores.append(np.asarray(scores, np.float64))
        self._labels.append(np.asarray(labels, np.float64))
        self._seen += 1
        if self._seen % self.cfg.every == 0:
            self._recalibrate()

    def _recalibrate(self):
        n = len(self._scores)
        if n < self.cfg.min_window:
            self.lam = math.inf
            return
        t_max = max(len(s) for s in self._scores)
        sc = np.zeros((n, t_max))
        lb = np.zeros((n, t_max))
        mk = np.zeros((n, t_max), bool)
        for i, (s, l) in enumerate(zip(self._scores, self._labels)):
            sc[i, :len(s)] = s
            lb[i, :len(l)] = l
            mk[i, :len(s)] = True
        tau = S.stop_times(sc, self.grid, mk, burn_in=self.cfg.burn_in)
        risk = S.procedure_risk(tau, lb, mk)
        res = C.ltt_calibrate(risk, self.grid, delta=self.cfg.delta,
                              eps=self.cfg.eps)
        self.lam = res.lam
        self.history.append((self._seen, self.lam))

    def decide(self, smoothed_scores: np.ndarray) -> int:
        """Stopping step for a new problem at the current lambda* (T if none)."""
        t = len(smoothed_scores)
        if math.isinf(self.lam):
            return t
        idx = np.where((smoothed_scores >= self.lam)
                       & (np.arange(t) >= self.cfg.burn_in))[0]
        return int(idx[0]) if len(idx) else t
