"""ORCA probe: architecture variants of the calibration scorer.

The probe scores a reasoning-step embedding phi_t in R^{d_phi}:

    s_t = sigma( W . z_Q(phi_t) + b )          (score view)
    l_t = ( sigma( W . z_K(phi_t) + b ) - C_t )^2   (update view, Brier)

Fast weights (W, b) are updated online at inference (repro.core.ttt); the
feature maps z_Q / z_K and the initialization (W0, b0, eta) are slow weights
meta-learned in the outer loop.

Variants (paper Section 3.3 + Table 6):
  * no-QK        — z = phi (online-adaptive logistic regression, d_phi+1 params)
  * QK           — z_Q = theta_Q phi, z_K = theta_K phi, d_h-dim subspace
  * +layernorm   — LayerNorm on the projected features
  * +residual    — z = LN(proj) + proj
  * +shared QK   — theta_K == theta_Q
  * +mlp         — one hidden GELU layer on the projection
  * learnable eta — inner lr trained in the outer loop (log-parameterized)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    d_phi: int
    variant: str = "noqk"        # noqk | qk
    d_h: int = 128
    layernorm: bool = False
    residual: bool = False
    shared_qk: bool = False
    mlp: bool = False
    learnable_eta: bool = False
    eta: float = 0.01            # inner learning rate (init if learnable)
    inner_label_mode: str = "zero"   # zero (inference-consistent) | true
    bptt_truncation: int = 0     # 0 = full backprop through the unroll
    smooth_window: int = 10      # rolling-mean smoothing of the score traj

    @property
    def feat_dim(self) -> int:
        return self.d_phi if self.variant == "noqk" else self.d_h


def init_outer(pc: ProbeConfig, rng) -> Dict[str, jnp.ndarray]:
    """Slow weights Theta_outer = (theta_{Q,K}, W0, b0, [eta])."""
    keys = jax.random.split(rng, 8)
    d = pc.feat_dim
    theta: Dict[str, jnp.ndarray] = {
        "W0": jax.random.normal(keys[0], (d,), jnp.float32) / math.sqrt(d),
        "b0": jnp.zeros((), jnp.float32),
    }
    if pc.variant == "qk":
        scale = 1.0 / math.sqrt(pc.d_phi)
        theta["theta_q"] = jax.random.normal(keys[1], (pc.d_phi, pc.d_h)) * scale
        if not pc.shared_qk:
            theta["theta_k"] = jax.random.normal(keys[2], (pc.d_phi, pc.d_h)) * scale
        if pc.layernorm:
            theta["ln_scale"] = jnp.ones((pc.d_h,))
            theta["ln_bias"] = jnp.zeros((pc.d_h,))
        if pc.mlp:
            theta["mlp_w"] = jax.random.normal(keys[3], (pc.d_h, pc.d_h)) / math.sqrt(pc.d_h)
            theta["mlp_b"] = jnp.zeros((pc.d_h,))
    if pc.learnable_eta:
        theta["log_eta"] = jnp.asarray(math.log(pc.eta), jnp.float32)
    return theta


def inner_lr(pc: ProbeConfig, theta) -> jnp.ndarray:
    if pc.learnable_eta:
        return jnp.exp(theta["log_eta"])
    return jnp.asarray(pc.eta, jnp.float32)


def _maybe_ln(pc: ProbeConfig, theta, z):
    if not pc.layernorm:
        return z
    mu = jnp.mean(z, -1, keepdims=True)
    var = jnp.var(z, -1, keepdims=True)
    zn = (z - mu) * jax.lax.rsqrt(var + 1e-6)
    return zn * theta["ln_scale"] + theta["ln_bias"]


def features(pc: ProbeConfig, theta, phi) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """phi (..., d_phi) -> (z_Q, z_K), each (..., feat_dim)."""
    phi = phi.astype(jnp.float32)
    if pc.variant == "noqk":
        return phi, phi
    zq = phi @ theta["theta_q"]
    zk = zq if pc.shared_qk else phi @ theta.get("theta_k", theta["theta_q"])
    if pc.layernorm or pc.residual:
        zq_n = _maybe_ln(pc, theta, zq)
        zk_n = _maybe_ln(pc, theta, zk)
        if pc.residual:
            zq, zk = zq_n + zq, zk_n + zk
        else:
            zq, zk = zq_n, zk_n
    if pc.mlp:
        zq = jax.nn.gelu(zq @ theta["mlp_w"] + theta["mlp_b"])
        zk = jax.nn.gelu(zk @ theta["mlp_w"] + theta["mlp_b"])
    return zq, zk


def score(fast: Tuple[jnp.ndarray, jnp.ndarray], z) -> jnp.ndarray:
    """fast = (W, b); z (..., feat) -> sigma(W.z + b)."""
    W, b = fast
    return jax.nn.sigmoid(z @ W + b)


def brier_grad(fast, z, c) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Analytic gradient of (sigma(W.z+b) - c)^2 wrt (W, b)."""
    s = score(fast, z)
    coeff = 2.0 * (s - c) * s * (1.0 - s)
    return coeff * z, coeff


def score_rows(W, b, z) -> jnp.ndarray:
    """Row-wise fast weights: sigma(sum(z * W, -1) + b).

    W/z (..., f) with matching leading axes, b (...,) — each row scored by
    its OWN (W_i, b_i), the layout of the serving engine's vector per-slot
    state and of the Pallas kernels' VMEM-resident state."""
    return jax.nn.sigmoid(jnp.sum(z * W, axis=-1) + b)


def score_then_update(W, b, zq, zk, c, m, eta
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """THE inner-loop step (Algorithm 2 lines 8-16), row-wise state.

    Score the Q view with the current fast weights, then apply one masked
    Brier-gradient update on the K view.  This single definition is what the
    Pallas kernels (``repro.kernels.ttt_probe``), their jnp oracles
    (``repro.kernels.ref``) and the serving engine all execute — the paper's
    validity argument needs the calibrated path and the served path to be the
    same procedure, so the formula lives in exactly one place.

    W (..., f), b/c/m (...,); zq/zk (..., f); eta scalar.  ``m`` freezes the
    update (padding, non-boundary tokens, stopped slots); the score is still
    emitted.  Returns (s_q, W', b').
    """
    s_q = score_rows(W, b, zq)
    s_k = score_rows(W, b, zk)
    coeff = 2.0 * (s_k - c) * s_k * (1.0 - s_k)
    upd = eta * m
    W_new = W - jnp.asarray(upd)[..., None] * (jnp.asarray(coeff)[..., None] * zk)
    b_new = b - upd * coeff
    return s_q, W_new, b_new


def fast_init(pc: ProbeConfig, theta) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return theta["W0"], theta["b0"]


def smooth_scores(scores: jnp.ndarray, window: int) -> jnp.ndarray:
    """Causal rolling mean over the step axis (last axis)."""
    if window <= 1:
        return scores
    c = jnp.cumsum(scores, axis=-1)
    shifted = jnp.concatenate(
        [jnp.zeros_like(c[..., :window]), c[..., :-window]], axis=-1)
    t = jnp.arange(scores.shape[-1])
    denom = jnp.minimum(t + 1, window).astype(scores.dtype)
    return (c - shifted) / denom
