"""ORCA core: the paper's contribution.

probe        — probe variants (no-QK / QK / +LN / +residual / +MLP / eta)
ttt          — inner-loop unroll + outer meta-training (Algorithm 1)
calibration  — LTT (binomial p-values + fixed-sequence testing), conformal
stopping     — deployed procedure A_lambda, risk / savings metrics (Alg. 2)
labels       — supervised / consistent step labels
static_probe — PCA + logistic-regression baseline (Thought Calibration)
pipeline     — end-to-end train -> calibrate -> evaluate convenience API
"""
from repro.core.probe import ProbeConfig, init_outer, smooth_scores
from repro.core.ttt import (batched_unroll, deployed_scores, inner_unroll,
                            meta_train, outer_loss)
from repro.core.calibration import (LTTResult, binomial_pvalue,
                                    conformal_quantile, default_grid,
                                    ltt_calibrate)
from repro.core.stopping import (EvalResult, calibrate_and_evaluate,
                                 procedure_risk, savings, step_savings,
                                 stop_times, sweep_deltas)
from repro.core.labels import (consistent_labels, supervised_labels,
                               transition_time)
from repro.core.static_probe import StaticProbe, fit_static_probe
from repro.core.calibrator import (Calibrator, StaticCalibrator,
                                   TTTCalibrator, make_calibrator)

__all__ = [
    "ProbeConfig", "init_outer", "smooth_scores", "batched_unroll",
    "deployed_scores", "inner_unroll", "meta_train", "outer_loss",
    "LTTResult", "binomial_pvalue", "conformal_quantile", "default_grid",
    "ltt_calibrate", "EvalResult", "calibrate_and_evaluate", "procedure_risk",
    "savings", "step_savings", "stop_times", "sweep_deltas",
    "consistent_labels", "supervised_labels", "transition_time",
    "StaticProbe", "fit_static_probe", "Calibrator", "StaticCalibrator",
    "TTTCalibrator", "make_calibrator",
]
