"""Step-label construction (paper Section 3.2 / 4.1).

Two modes, both monotonized into the cumulative form [0..0,1..1] the paper
assumes (Appendix B, "Detecting the reasoning breakthrough"):

  * supervised — C_t = 1{ans(y_t) correct}; transition at the FIRST correct
    attempt ("step labels are cumulative, flip after first correct attempt").
  * consistent — C_t = 1{ans(y_t) == ans(y_T)}; monotonized by suffix
    stability: transition at the first step after which the answer never
    changes away from the full-budget answer.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def supervised_labels(correct: np.ndarray, mask: Optional[np.ndarray] = None
                      ) -> np.ndarray:
    """correct (N, T) binary per-step correctness -> cumulative labels."""
    correct = np.asarray(correct, bool)
    if mask is not None:
        correct = correct & np.asarray(mask, bool)
    return (np.cumsum(correct, axis=-1) > 0).astype(np.float32)


def consistent_labels(answers: np.ndarray, mask: Optional[np.ndarray] = None
                      ) -> np.ndarray:
    """answers (N, T) int answer ids per step -> suffix-stable labels.

    C_t = 1 iff ans_s == ans_T for all s >= t (within the mask).
    """
    answers = np.asarray(answers)
    n, t = answers.shape
    if mask is None:
        final = answers[:, -1]
        eq = answers == final[:, None]
    else:
        mask = np.asarray(mask, bool)
        last_idx = np.maximum(mask.shape[1] - 1 - np.argmax(mask[:, ::-1], axis=1), 0)
        final = answers[np.arange(n), last_idx]
        eq = (answers == final[:, None]) | ~mask
    # suffix-AND: stable from t to the end
    stable = np.flip(np.cumprod(np.flip(eq, axis=1), axis=1), axis=1)
    out = stable.astype(np.float32)
    if mask is not None:
        out = out * mask
    return out


def transition_time(labels: np.ndarray, mask: Optional[np.ndarray] = None
                    ) -> np.ndarray:
    """First index with label 1; T (i.e. len) if the problem never flips."""
    labels = np.asarray(labels) > 0.5
    if mask is not None:
        labels = labels & np.asarray(mask, bool)
    t = labels.shape[1]
    has = labels.any(axis=1)
    first = np.argmax(labels, axis=1)
    return np.where(has, first, t).astype(np.int64)
