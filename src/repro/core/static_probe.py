"""Static linear probe baseline (Wu et al., 2025): PCA + logistic regression.

Built from scratch (no sklearn): PCA via SVD of the centered step-embedding
matrix; logistic regression trained full-batch with Adam.  At inference the
probe applies a single forward pass per step (no online adaptation), followed
by the same rolling-window smoothing as the TTT probe, and is calibrated with
the same LTT procedure — this is the paper's "Static Probe" row.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.probe import smooth_scores
from repro.optim import Adam


@dataclasses.dataclass
class StaticProbe:
    mean: np.ndarray          # (d,)
    components: np.ndarray    # (d, k)
    w: np.ndarray             # (k,)
    b: float
    smooth_window: int = 10

    def scores(self, phis: np.ndarray, mask: Optional[np.ndarray] = None
               ) -> np.ndarray:
        """phis (N, T, d) -> smoothed scores (N, T)."""
        z = (phis - self.mean) @ self.components
        s = 1.0 / (1.0 + np.exp(-(z @ self.w + self.b)))
        s = np.asarray(smooth_scores(jnp.asarray(s), self.smooth_window))
        if mask is not None:
            s = s * mask
        return s


def fit_pca(x: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    mean = x.mean(axis=0)
    xc = x - mean
    # economical SVD on (n, d)
    _, _, vt = np.linalg.svd(xc, full_matrices=False)
    return mean, vt[:k].T.astype(np.float64)


def fit_static_probe(phis: np.ndarray, labels: np.ndarray,
                     mask: Optional[np.ndarray] = None, *, n_components: int = 64,
                     epochs: int = 200, lr: float = 1e-2,
                     smooth_window: int = 10, seed: int = 0) -> StaticProbe:
    """phis (N, T, d), labels (N, T) -> fitted PCA+LogReg probe."""
    n, t, d = phis.shape
    flat = phis.reshape(n * t, d).astype(np.float64)
    y = labels.reshape(n * t).astype(np.float64)
    if mask is not None:
        keep = np.asarray(mask, bool).reshape(n * t)
        flat, y = flat[keep], y[keep]
    k = min(n_components, d, flat.shape[0])
    mean, comps = fit_pca(flat, k)
    z = jnp.asarray((flat - mean) @ comps, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)

    params = {"w": jnp.zeros((k,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    opt = Adam(lr=lr, clip_norm=None)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            logit = z @ p["w"] + p["b"]
            return jnp.mean(
                jnp.maximum(logit, 0) - logit * yj + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, state = opt.update(grads, state, params)
        return jax.tree.map(lambda p, u: p + u, params, upd), state, loss

    for _ in range(epochs):
        params, state, _ = step(params, state)
    return StaticProbe(mean=mean, components=comps,
                       w=np.asarray(params["w"], np.float64),
                       b=float(params["b"]), smooth_window=smooth_window)
