"""Tables 6 + 7 — probe architecture variants and QK projection dimension
(supervised, delta=0.1), with MATH-500 OOD savings per variant."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.probe import ProbeConfig

VARIANTS = [
    ("qk", dict(variant="qk")),
    ("qk+ln", dict(variant="qk", layernorm=True)),
    ("qk+ln+res", dict(variant="qk", layernorm=True, residual=True)),
    ("qk+shared", dict(variant="qk", shared_qk=True)),
    ("qk+learn-eta", dict(variant="qk", learnable_eta=True)),
    ("qk+mlp", dict(variant="qk", mlp=True)),
    ("noqk", dict()),
]
DHS = (32, 64, 128)


def run() -> list:
    train, cal, test = C.corpus()
    math = C.ood("math500")
    rows = []
    for name, kw in VARIANTS:
        d_h = min(kw.pop("d_h", 128), C.D_PHI)
        pc = ProbeConfig(d_phi=C.D_PHI, d_h=d_h, **kw)
        probe = C.get_probe(train, "supervised", pc, tag=f"var-{name}")
        r_id = C.eval_rows(name, "supervised", probe.scores(cal), cal,
                           probe.scores(test), test, deltas=(0.1,))[0]
        r_ood = C.eval_rows(name, "supervised", probe.scores(cal), cal,
                            probe.scores(math), math, deltas=(0.1,))[0]
        rows.append({"variant": name, "d_h": d_h if "qk" in name else 0,
                     "savings": r_id["savings"], "error": r_id["error"],
                     "math500_savings": r_ood["savings"]})
    for d_h in DHS:
        if d_h >= C.D_PHI:
            continue
        pc = ProbeConfig(d_phi=C.D_PHI, variant="qk", d_h=d_h)
        probe = C.get_probe(train, "supervised", pc, tag=f"dh{d_h}")
        r = C.eval_rows(f"qk-dh{d_h}", "supervised", probe.scores(cal), cal,
                        probe.scores(test), test, deltas=(0.1,))[0]
        rows.append({"variant": f"qk-dh{d_h}", "d_h": d_h,
                     "savings": r["savings"], "error": r["error"],
                     "math500_savings": float("nan")})
    C.print_table("Tables 6+7: architecture variants / projection dim "
                  "(paper: no-QK .475 best; small d_h competitive)", rows,
                  ["variant", "d_h", "savings", "error", "math500_savings"])
    C.save_rows("table7_variants", rows)
    return rows


if __name__ == "__main__":
    run()
