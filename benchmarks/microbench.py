"""Microbenchmarks: wall-time per call for the hot primitives on this host
(CPU; TPU numbers come from the roofline model).  Emits name,us_per_call."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import ttt
from repro.core.probe import ProbeConfig, init_outer
from repro.kernels import flash_decode, make_unroll_kernel


def timeit(fn, *args, reps: int = 5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    n, t, f = 16, 96, C.D_PHI
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    phis = jax.random.normal(ks[0], (n, t, f))
    mask = jnp.ones((n, t))
    pc = ProbeConfig(d_phi=f)
    theta = init_outer(pc, jax.random.PRNGKey(1))

    us = timeit(lambda: ttt.deployed_scores(pc, theta, phis, mask))
    rows.append({"name": f"ttt_unroll_scan(core) n{n}xT{t}xf{f}",
                 "us_per_call": us, "derived": f"{n*t/us:.2f} steps/us"})
    kern = make_unroll_kernel(t_chunk=32)
    us = timeit(lambda: ttt.deployed_scores(pc, theta, phis, mask, kernel=kern))
    rows.append({"name": f"ttt_unroll_pallas(interp) n{n}xT{t}xf{f}",
                 "us_per_call": us, "derived": "interpret-mode (CPU)"})

    b, h, kv, s, d = 2, 8, 8, 2048, 64
    q = jax.random.normal(ks[1], (b, h, d))
    k = jax.random.normal(ks[2], (b, kv, s, d))
    v = jax.random.normal(ks[3], (b, kv, s, d))
    valid = jnp.ones((b, s), bool)
    from repro.kernels import ref as R
    us = timeit(lambda: R.flash_decode_ref(q, k, v, valid))
    rows.append({"name": f"decode_attn_ref b{b}h{h}s{s}", "us_per_call": us,
                 "derived": f"{2*b*h*s*d*2/us/1e6:.2f} GFLOP/s"})

    C.print_table("Microbenchmarks (host CPU)", rows,
                  ["name", "us_per_call", "derived"])
    C.save_rows("microbench", rows)
    return rows


if __name__ == "__main__":
    run()
