"""Table 2 — in-distribution early-stopping: savings/error across risk
levels, supervised + consistent labels, TTT (no-QK, QK) vs static probe."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.probe import ProbeConfig


def run() -> list:
    train, cal, test = C.corpus()
    rows = []
    for mode in ("supervised", "consistent"):
        static = C.get_static(train, mode)
        rows += C.eval_rows("static", mode,
                            static.scores(cal.phis, cal.mask), cal,
                            static.scores(test.phis, test.mask), test)
        for name, pc in [
            ("ttt-noqk", ProbeConfig(d_phi=C.D_PHI)),
            ("ttt-qk128", ProbeConfig(d_phi=C.D_PHI, variant="qk",
                                      d_h=min(128, C.D_PHI))),
        ]:
            probe = C.get_probe(train, mode, pc)
            rows += C.eval_rows(name, mode, probe.scores(cal), cal,
                                probe.scores(test), test)
    C.print_table("Table 2: in-distribution (paper: TTT no-QK .475 vs "
                  "static .380 @ delta=0.1 supervised)", rows,
                  ["method", "mode", "delta", "savings", "error", "lambda"])
    C.save_rows("table2_indist", rows)
    return rows


if __name__ == "__main__":
    run()
