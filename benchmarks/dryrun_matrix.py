"""Run the full dry-run matrix: 10 archs x 4 shapes x {single, multi} meshes.

Each pair runs in a fresh subprocess (the 512-device XLA flag must be set
before jax init, and compilations are memory-heavy).  Results land in
``results/dryrun/<arch>__<shape>__<mesh>.json``; failures keep stderr tails.

    PYTHONPATH=src python -m benchmarks.dryrun_matrix [--workers 2]
        [--mesh single|multi|both] [--only arch1,arch2] [--shapes a,b]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

from repro.configs import ALIASES, INPUT_SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
           os.environ.get("REPRO_DRYRUN_DIR", "dryrun"))


def run_one(arch: str, shape: str, multi: bool, timeout: int = 3600) -> dict:
    mesh = "2x16x16" if multi else "16x16"
    os.makedirs(RESULTS, exist_ok=True)
    slug = f"{arch.replace('.', '_').replace('/', '_')}__{shape}__{mesh}"
    out_json = os.path.join(RESULTS, slug + ".json")
    if os.path.exists(out_json):
        with open(out_json) as f:
            prior = json.load(f)
        if prior.get("status") in ("ok", "skip"):
            return prior
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", out_json]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        res = {"arch": arch, "shape": shape, "mesh": mesh,
               "status": "timeout", "wall_s": timeout}
        with open(out_json, "w") as f:
            json.dump(res, f, indent=1)
        return res
    if proc.returncode != 0 or not os.path.exists(out_json):
        res = {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
               "wall_s": round(time.time() - t0, 1),
               "stderr_tail": proc.stderr[-4000:]}
        with open(out_json, "w") as f:
            json.dump(res, f, indent=1)
        return res
    with open(out_json) as f:
        res = json.load(f)
    res["wall_s"] = round(time.time() - t0, 1)
    with open(out_json, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--only", default=None)
    ap.add_argument("--shapes", default=None)
    args = ap.parse_args(argv)
    archs = sorted(ALIASES) if not args.only else args.only.split(",")
    shapes = sorted(INPUT_SHAPES) if not args.shapes else args.shapes.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    work = [(a, s, m) for m in meshes for a in archs for s in shapes]
    print(f"[matrix] {len(work)} dry-runs, {args.workers} workers")
    failures = 0
    with ThreadPoolExecutor(args.workers) as ex:
        futs = {ex.submit(run_one, a, s, m): (a, s, m) for a, s, m in work}
        for fut in futs:
            pass
        done = 0
        for fut, key in list(futs.items()):
            res = fut.result()
            done += 1
            ok = res["status"] in ("ok", "skip")
            failures += not ok
            mark = "OK " if res["status"] == "ok" else (
                "SKP" if res["status"] == "skip" else "ERR")
            print(f"[{done:3d}/{len(work)}] {mark} {key[0]:24s} {key[1]:12s} "
                  f"{'multi' if key[2] else 'single'}  {res.get('wall_s','?')}s")
    print(f"[matrix] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
