"""Roofline table: aggregate results/dryrun/*.json into the per-(arch x
shape x mesh) three-term report (deliverable g)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common as C

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all() -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> list:
    rows = []
    for d in load_all():
        base = {"arch": d.get("arch"), "shape": d.get("shape"),
                "mesh": d.get("mesh"), "status": d.get("status")}
        r = d.get("roofline")
        if r:
            base.update({
                "t_compute_s": r["t_compute"], "t_memory_s": r["t_memory"],
                "t_collective_s": r["t_collective"], "dominant": r["dominant"],
                "model_flops": r["model_flops"],
                "flops_ratio": r["flops_ratio"],
                "coll_GB": r["collective_bytes"] / 1e9,
                "mem_per_dev_GB": (r.get("per_device_memory_bytes") or 0) / 1e9,
            })
        if d.get("status") == "skip":
            base["dominant"] = d.get("reason", "")[:40]
        rows.append(base)
    rows.sort(key=lambda r: (r["mesh"] or "", r["arch"] or "", r["shape"] or ""))
    C.print_table("Roofline: per (arch x shape x mesh) terms from the "
                  "dry-run (seconds per step)", rows,
                  ["mesh", "arch", "shape", "status", "dominant",
                   "t_compute_s", "t_memory_s", "t_collective_s",
                   "flops_ratio", "coll_GB", "mem_per_dev_GB"])
    C.save_rows("roofline_report", rows)
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    err = sum(1 for r in rows if r["status"] not in ("ok", "skip"))
    print(f"# dry-run matrix: {ok} ok, {skip} documented skips, {err} errors")
    return rows


if __name__ == "__main__":
    run()
