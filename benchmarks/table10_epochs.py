"""Table 10 — epoch selection: validation savings across meta-epochs for
no-QK vs QK (paper: no-QK stable, QK peaks early and overfits)."""
from __future__ import annotations


from benchmarks import common as C
from repro.core.probe import ProbeConfig


def run() -> list:
    train, _, _ = C.corpus()
    rows = []
    for name, pc in [
        ("noqk", ProbeConfig(d_phi=C.D_PHI)),
        ("qk128", ProbeConfig(d_phi=C.D_PHI, variant="qk",
                              d_h=min(128, C.D_PHI))),
    ]:
        probe = C.get_probe(train, "supervised", pc, tag=f"ep-{name}")
        for h in probe.history:
            if "val_savings" in h:
                rows.append({"probe": name, "epoch": h["epoch"],
                             "val_savings": h["val_savings"],
                             "val_error": h.get("val_error", float("nan")),
                             "loss": h["loss"]})
    # print a decimated view
    shown = [r for r in rows if r["epoch"] % 5 == 0 or r["epoch"] == 1]
    C.print_table("Table 10: savings vs meta-epoch (epoch selection per "
                  "paper §C.4)", shown,
                  ["probe", "epoch", "val_savings", "val_error", "loss"])
    C.save_rows("table10_epochs", rows)
    return rows


if __name__ == "__main__":
    run()
