"""Table 5 — core mechanism ablation (supervised, delta=0.1):

  full TTT (meta-learn + online updates)      <- the method
  standard supervised training, no updates    <- same arch, no TTT
  random init + online updates                <- no meta-training
  random init, no updates                     <- neither
  static probe (PCA + logreg)                 <- baseline
"""
from __future__ import annotations


import jax
import numpy as np

from benchmarks import common as C
from repro.core import ttt
from repro.core.probe import ProbeConfig, init_outer
from repro.core.pipeline import evaluate_probe

import jax.numpy as jnp


def _scores(pc, theta, ts):
    s = ttt.deployed_scores(pc, theta, jnp.asarray(ts.phis),
                            jnp.asarray(ts.mask))
    return np.asarray(s) * ts.mask


def run() -> list:
    train, cal, test = C.corpus()
    mode = "supervised"
    rows = []

    def add(name, pc, theta):
        ev = evaluate_probe(_scores(pc, theta, cal), cal,
                            _scores(pc, theta, test), test, mode, (0.1,))
        rows.append({"config": name, **ev.results[0].row()})

    # full TTT (meta-learned, online updates at inference)
    pc = ProbeConfig(d_phi=C.D_PHI)
    probe = C.get_probe(train, mode, pc)
    add("full-ttt(noqk)", pc, probe.theta)
    # standard training: same architecture trained with eta=0 (no unroll
    # dynamics) and deployed without online updates
    pc_std = ProbeConfig(d_phi=C.D_PHI, eta=0.0)
    probe_std = C.get_probe(train, mode, pc_std, tag="standard")
    add("standard(noqk)", pc_std, probe_std.theta)
    # meta-learned but deployed WITHOUT updates (isolates the online part)
    add("meta-no-update", pc_std, probe.theta)
    # no meta-training: random init + online updates
    theta0 = init_outer(pc, jax.random.PRNGKey(123))
    add("no-meta*", pc, theta0)
    # neither
    add("no-meta-no-update*", pc_std, theta0)
    # static baseline
    static = C.get_static(train, mode)
    ev = evaluate_probe(static.scores(cal.phis, cal.mask), cal,
                        static.scores(test.phis, test.mask), test, mode, (0.1,))
    rows.append({"config": "static(PCA+logreg)", **ev.results[0].row()})

    C.print_table("Table 5: mechanism ablation @ delta=0.1 (paper: full TTT "
                  ".475 > standard .239, no-meta .254, static .380)", rows,
                  ["config", "savings", "error", "lambda"])
    C.save_rows("table5_ablation", rows)
    return rows


if __name__ == "__main__":
    run()
