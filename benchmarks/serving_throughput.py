"""Continuous vs static batching, and paged vs dense KV, at the SAME
calibrated lambda*.

    PYTHONPATH=src:. python benchmarks/serving_throughput.py [--check]

Three comparisons, all serving the identical calibrated procedure (same
probe theta, same lambda*, same burn-in) so per-request stop decisions must
be IDENTICAL across paths (asserted):

  * ``OrcaScheduler`` (continuous batching, ORCA-stop eviction) vs the
    static-batch ``ServingEngine`` baseline;
  * the fused Pallas probe step vs the PR-1 jnp probe (``probe_impl="ref"``);
  * paged KV (block-pool admission + prefix sharing) vs the dense per-slot
    cache on a SHARED-PREFIX workload — ``--prefix-samples`` self-consistency
    samples per prompt — at EQUAL KV HBM budget: the paged pool holds exactly
    as many token-slots as the dense engine's lanes, but shares each resident
    prompt's full pages and reclaims pages on every ORCA stop, so it runs
    more concurrent requests through the same bytes;
  * chunked prefill vs admission-time prefill on a MIXED long-prompt /
    short-decode workload at EQUAL KV HBM (identical paged pool): the
    unified token-budget step packs resident decode tokens + one prompt
    chunk per iteration, so the per-step decode-stall tail (p99) and TTFT
    collapse — admission prefill stalls every resident decode for a whole
    batch-1 full-prompt prefill (and compiles per prompt length);
  * PACKED vs single-request chunks on the same mixed fleet at EQUAL
    ``token_budget`` and EQUAL KV HBM: the packed composer fuses the tail
    of one prompt with the head of the next into one block-diagonal chunk,
    so short prompt tails stop leaving budget on the table — higher
    requests/s and lower TTFT at byte-identical stop decisions (the
    ``packed_vs_single_chunk`` gate metric).  Rows carry the per-priority-
    class TTFT/queue-wait percentiles (c0_* latency class, c1_* batch);
  * GROUPED consensus serving vs N independent samples at EQUAL KV HBM
    (identical paged pool): each prompt's self-consistency samples are
    gang-admitted as one ``RequestGroup`` sharing prompt pages, and the
    consensus stop cancels all still-running siblings the moment the
    confidence-weighted answer vote clears the threshold — pages freed
    mid-flight, slots refilled from the queue (the
    ``group_consensus_vs_independent`` gate metric); gang scheduling with
    the consensus OFF must not move a single stop decision (asserted);
  * OVERLOAD with involuntary preemption vs waiting, on an undersized pool
    (pages for exactly ``--slots`` residents): batch-class requests fill
    the fleet, then urgent class-0 requests arrive — with preemption the
    scheduler spills a lower-class resident's KV pages AND probe state to
    host RAM and admits the urgent request immediately; without it the
    urgent request queues behind a full house.  Class-0 p99 TTFT improves
    (the ``preemption_ttft_p99_class0`` gate metric is the no-preempt /
    preempt ratio), every spill is restored, and the stop decisions are
    byte-identical across both runs (asserted — the spill/restore round
    trip is exact, so the calibrated procedure is preemption-invariant).

  * FLEET serving: 2 simulated hosts vs 1 host at EQUAL TOTAL KV pages on
    the shared-prefix workload — the ``FleetRouter`` splits the page
    budget across per-host pools, places same-prompt traffic on the host
    already holding the donor pages (prefix-affine placement — follower
    prefills collapse to page-table copies, ``prefill_skips``), and steps
    the hosts concurrently (the jitted fused step releases the GIL).
    Doubling the concurrent slots at the same bytes cuts QUEUEING
    latency structurally — a wave-k request waits k-1 wave-times, and the
    fleet halves the wave count — so p99 TTFT drops even on one core
    (the ``fleet_ttft_p99_gain`` gate metric).  The requests/s ratio
    (``fleet_vs_single_host``) additionally captures the concurrent-
    stepping win, which needs >= 2 physical cores: host threads share one
    JAX runtime, so on a single-core build box the pair is throughput-
    parity (total step work is conserved) and the committed ratio is ~1x
    with a tolerant floor — multi-core CI runners see the parallel win on
    top.  Per-request stop decisions are byte-identical to the single
    host under every placement (asserted — the fleet invariant).

  * SPECULATIVE draft-verify decode vs one-token decode on the
    decode-bound replay fleet at EQUAL KV HBM (identical engine shape,
    only ``spec_tokens`` differs): each RUNNING slot contributes a verify
    segment of drafted tokens to the same unified step, the replay model
    drafts from its own trajectory (100% acceptance — the throughput
    ceiling), rejected-draft rollback and the accepted-length-masked
    probe keep stop decisions byte-identical (asserted).  Decode tokens/s
    multiplies by the accepted length per step (the
    ``spec_vs_one_token`` gate metric, >= 1.3x enforced).

  * TREE speculative decode vs LINEAR spec vs one-token decode on a
    PARTIAL-ACCEPTANCE replay fleet at EQUAL KV HBM (same engine shape
    and cache for all three; the replay drafter corrupts each drafted
    token with ``--draft-wrong-rate``): where a linear draft chain dies
    at its first wrong token, the tree verifies W independent chains
    under per-token ancestor masks in the SAME fused step and commits
    the LONGEST accepted root-to-leaf path — expected accepted length
    per step goes from sum_i p^i to E[max over W chains], which is the
    whole point of multi-draft verification.  Stop decisions stay
    byte-identical across all three (asserted); the
    ``tree_vs_linear_spec`` gate enforces >= 1.15x FEWER SEQUENTIAL
    ENGINE STEPS than the PR-9 linear path at equal committed tokens —
    the deterministic quantity tree verification shrinks.  Wall-clock
    decode tokens/s is printed/recorded with a tolerant floor only: on
    this single-core CPU build box the tree's larger packed chunk
    (1+W*D verify nodes per slot vs k) costs real compute per step,
    muting the wall gain to ~1.1-1.2x, whereas bandwidth-bound decode
    on real accelerators pays ~nothing for the extra in-flight tokens
    and realizes the step ratio (same box caveat as the fleet
    requests/s row).

``--check`` is the CI perf-regression gate: re-run, then compare against the
committed ``results/serving_throughput.json`` baseline — stop decisions must
be byte-identical and every tracked metric must stay within the tolerance
stored IN the baseline file (re-baseline by re-running without ``--check``
and committing the JSON).  Exits nonzero on regression.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

import jax

from repro import api as orca
from repro.configs import get_config
from repro.core.probe import ProbeConfig
from repro.launch.serve import model_inputs, trajectories_from_model
from repro.models import build
from repro.serving import (OrcaScheduler, ServeConfig, ServingEngine,
                           make_group, make_request, replay_model,
                           replay_params, replay_requests,
                           serve_queue_static)

from benchmarks.common import QUICK, RESULTS, print_table

BASELINE = os.path.join(RESULTS, "serving_throughput.json")


def kv_bytes_dense(cfg, n_slots: int, cache_len: int) -> int:
    item = 1 if cfg.kv_cache_dtype == "int8" else \
        np.dtype(cfg.dtype).itemsize
    return 2 * cfg.n_layers * n_slots * cfg.n_kv_heads * cache_len \
        * cfg.d_head * item


def kv_bytes_paged(cfg, num_blocks: int, block_size: int) -> int:
    """Bytes of ALL physical pages — including page 0, the NULL page, which
    is real HBM even though it is never allocated to a request."""
    return kv_bytes_dense(cfg, num_blocks, block_size)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=0,
                    help="queue size (default 4x slots)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--tokens-per-step", type=int, default=4)
    ap.add_argument("--train-trajectories", type=int, default=24)
    ap.add_argument("--delta", type=float, default=0.25)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--reps", type=int, default=2 if QUICK else 3,
                    help="timed repetitions per path (best kept)")
    ap.add_argument("--seed", type=int, default=0)
    # shared-prefix (self-consistency) workload for the paged-vs-dense row
    ap.add_argument("--prefix-prompts", type=int, default=2)
    ap.add_argument("--prefix-samples", type=int, default=6,
                    help="self-consistency samples decoded per prompt")
    ap.add_argument("--prefix-prompt-len", type=int, default=48)
    ap.add_argument("--prefix-max-new", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--paged-slots", type=int, default=6,
                    help="batch rows for the paged engine (pages, not "
                         "slots, are its memory budget)")
    # mixed long-prompt/short-decode workload for the chunked-prefill row
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="prefill chunk width for the mixed workload "
                         "(0 -> 8 quick / 16 full)")
    ap.add_argument("--mixed-max-new", type=int, default=16)
    # self-consistency group workload for the consensus-stop row
    ap.add_argument("--group-prompts", type=int, default=3)
    ap.add_argument("--group-size", type=int, default=3,
                    help="gang-admitted samples per group")
    ap.add_argument("--group-max-new", type=int, default=24)
    # shared-prefix fleet workload for the 2-hosts-vs-1 row
    ap.add_argument("--fleet-prompts", type=int, default=4)
    ap.add_argument("--fleet-hosts", type=int, default=2)
    # decode-bound replay fleet for the speculative-decode row
    ap.add_argument("--spec-tokens", type=int, default=6,
                    help="verify-block length for the spec-decode row")
    ap.add_argument("--spec-trajectories", type=int, default=16)
    ap.add_argument("--spec-steps", type=int, default=64,
                    help="replay trajectory length (decode-bound: every "
                         "token is one reasoning step)")
    # partial-acceptance workload for the tree-vs-linear-spec row triple
    ap.add_argument("--spec-tree", default="3.3",
                    help="tree shape 'W.D' for the tree-spec row")
    ap.add_argument("--draft-wrong-rate", type=float, default=0.45,
                    help="per-token draft corruption rate of the "
                         "partial-acceptance replay drafter")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: compare against the committed baseline "
                         "instead of overwriting it; nonzero exit on "
                         "regression")
    ap.add_argument("--out", default="",
                    help="output JSON (default: the committed baseline, or "
                         "results/serving_throughput_fresh.json with "
                         "--check)")
    args = ap.parse_args(argv)
    n_requests = args.requests or 4 * args.slots
    out_path = args.out or (
        os.path.join(RESULTS, "serving_throughput_fresh.json")
        if args.check else BASELINE)

    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[throughput] {cfg.name}: {n_requests} requests through "
          f"{args.slots} slots")

    ts = trajectories_from_model(model, params, args.train_trajectories,
                                 args.prompt_len, args.max_new_tokens,
                                 args.tokens_per_step, args.seed)
    half = len(ts) // 2
    train, cal = ts.subset(np.arange(half)), ts.subset(np.arange(half, len(ts)))
    calib = orca.fit(train, mode="consistent", method="ttt",
                     pc=ProbeConfig(d_phi=cfg.d_model, smooth_window=4),
                     epochs=args.epochs, epoch_select=False, seed=args.seed)
    # demo fallback keeps eviction observable on tiny random-weight models
    lam = orca.calibrated_lambda(calib, cal, args.delta, fallback=0.99)
    print(f"[throughput] calibrated lambda* = {lam:.3f}")

    batch = model_inputs(cfg, jax.random.PRNGKey(args.seed + 1), n_requests,
                         args.prompt_len)
    pc, theta = calib.serving_params()
    scfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.max_new_tokens, lam=float(lam),
                       burn_in=2)

    extra_keys = [k for k in batch if k != "tokens"]

    def queue_requests():
        return [make_request(batch["tokens"][i],
                             extra={k: batch[k][i:i + 1] for k in extra_keys})
                for i in range(n_requests)]

    # each path gets one untimed warm-up pass (jit + Pallas tracing), then
    # best-of-N timed passes — steady-state throughput is what serving
    # cares about, and best-of-N tames shared-machine timing noise
    def best_of(run, n=args.reps):
        results = [run() for _ in range(n)]
        return min(results, key=lambda r: r[-1].wall_time_s
                   if isinstance(r, tuple) else r.wall_time_s)

    # --- static-batch baseline -------------------------------------------
    eng = ServingEngine(model, params, pc, theta, scfg)
    serve_queue_static(eng, batch, args.prompt_len, args.slots)
    base = best_of(lambda: serve_queue_static(eng, batch, args.prompt_len,
                                              args.slots))

    # --- continuous batching ---------------------------------------------
    sched = orca.engine(model, params, calib,
                        config=dataclasses.replace(scfg,
                                                   n_slots=args.slots))
    sched.run(queue_requests())
    done, fleet = best_of(lambda: sched.run(queue_requests()))

    # --- before/after: PR-1 jnp probe vs the fused Pallas serving step ---
    sched_ref = OrcaScheduler(model, params, pc, theta, scfg,
                              n_slots=args.slots, probe_impl="ref")
    sched_ref.run(queue_requests())
    done_ref, fleet_ref = best_of(lambda: sched_ref.run(queue_requests()))

    # --- eviction/probe-impl must not change ANY stop decision -----------
    stop_c = np.array([r.stop_step for r in done])
    stop_r = np.array([r.stop_step for r in done_ref])
    assert (base.stop_step == stop_c).all(), \
        f"stop decisions diverged: static {base.stop_step} vs {stop_c}"
    assert (stop_c == stop_r).all(), \
        f"probe impls diverged: kernel {stop_c} vs pr1-jnp {stop_r}"
    for i, r in enumerate(done):
        n = min(len(r.scores), base.scores[i].shape[0])
        np.testing.assert_allclose(np.array(r.scores)[:n],
                                   base.scores[i][:n], atol=1e-4)
    print("[throughput] per-request stop decisions identical "
          f"(stop steps {stop_c.tolist()})")

    # --- paged vs dense at EQUAL KV HBM on the shared-prefix workload ----
    pcfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.prefix_max_new, lam=float(lam),
                       burn_in=2)
    p_cache_len = args.prefix_prompt_len + args.prefix_max_new
    bs = args.block_size
    assert p_cache_len % bs == 0, (p_cache_len, bs)
    # equal budget: TOTAL physical pages (null page included) hold exactly
    # as many bytes as the dense lanes — the pool pays for its null page
    # out of the same budget, leaving num_blocks_total - 1 usable pages
    num_blocks_total = args.slots * p_cache_len // bs
    hbm_dense = kv_bytes_dense(cfg, args.slots, p_cache_len)
    hbm_paged = kv_bytes_paged(cfg, num_blocks_total, bs)
    assert hbm_dense == hbm_paged, (hbm_dense, hbm_paged)
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 2),
                                 (args.prefix_prompts, args.prefix_prompt_len),
                                 0, cfg.vocab_size)

    def prefix_requests():
        # self-consistency: samples of one prompt enqueue back-to-back
        return [make_request(prompts[p])
                for p in range(args.prefix_prompts)
                for _ in range(args.prefix_samples)]

    n_prefix = args.prefix_prompts * args.prefix_samples
    dense_sched = OrcaScheduler(model, params, pc, theta, pcfg,
                                n_slots=args.slots, cache_len=p_cache_len)
    dense_sched.run(prefix_requests())
    done_d, fleet_d = best_of(lambda: dense_sched.run(prefix_requests()))
    paged_sched = OrcaScheduler(model, params, pc, theta, pcfg,
                                n_slots=args.paged_slots,
                                cache_len=p_cache_len, paged=True,
                                block_size=bs, num_blocks=num_blocks_total)
    paged_sched.run(prefix_requests())
    done_p, fleet_p = best_of(lambda: paged_sched.run(prefix_requests()))
    stop_d = np.array([r.stop_step for r in done_d])
    stop_p = np.array([r.stop_step for r in done_p])
    assert (stop_d == stop_p).all(), \
        f"paged KV changed stop decisions: dense {stop_d} vs paged {stop_p}"
    print(f"[throughput] paged == dense stop decisions on shared-prefix "
          f"workload ({stop_p.tolist()}); {fleet_p.prefill_skips} of "
          f"{n_prefix} prefills served from the resident prefix, "
          f"KV budget {hbm_dense / 1e6:.2f} MB each")

    # --- chunked vs admission prefill on mixed long-prompt/short-decode --
    chunk = args.chunk_tokens or 16
    # off-chunk-aligned prompt lengths: every prompt leaves a short tail,
    # the budget the PACKED composer reclaims by fusing it with the next
    # prompt's head
    mixed_lens = [67, 93, 129, 90, 61, 114, 81, 126,
                  121, 70, 95, 106]
    # a wider resident fleet makes the admission stall story concrete:
    # every batch-1 full-prompt prefill blocks FOUR live decode rows
    m_slots = max(args.slots, 4)
    mcfg_serve = ServeConfig(tokens_per_step=args.tokens_per_step,
                             max_new_tokens=args.mixed_max_new,
                             lam=float(lam), burn_in=2)
    m_cache = max(mixed_lens) + args.mixed_max_new
    m_blocks = m_slots * ((m_cache + bs - 1) // bs) + 1
    hbm_mixed = kv_bytes_paged(cfg, m_blocks, bs)
    m_prompts = [jax.random.randint(jax.random.PRNGKey(args.seed + 3 + i),
                                    (L,), 0, cfg.vocab_size)
                 for i, L in enumerate(mixed_lens)]

    def mixed_requests():
        # two priority classes so the per-class TTFT/queue-wait
        # percentiles (FleetMetrics.per_class) show up in the rows; the
        # FIFO composer ignores the classes, stop decisions are
        # class-invariant either way
        return [make_request(p, priority=(1 if i % 3 == 0 else 0))
                for i, p in enumerate(m_prompts)]

    # IDENTICAL pool everywhere: equal KV HBM, only the prefill schedule
    # differs (admission-time full prompt vs token-budget chunks, one
    # request per chunk vs packed multi-request chunks)
    adm_sched = OrcaScheduler(model, params, pc, theta, mcfg_serve,
                              n_slots=m_slots, paged=True, block_size=bs,
                              num_blocks=m_blocks)
    adm_sched.run(mixed_requests())
    done_a, fleet_a = best_of(lambda: adm_sched.run(mixed_requests()))
    chk_sched = OrcaScheduler(model, params, pc, theta, mcfg_serve,
                              n_slots=m_slots, paged=True, block_size=bs,
                              num_blocks=m_blocks, chunk_tokens=chunk,
                              pack_chunks=False)
    chk_sched.run(mixed_requests())
    done_k, fleet_k = best_of(lambda: chk_sched.run(mixed_requests()))
    # --- packed vs single-request chunks at EQUAL token budget ------------
    pk_sched = OrcaScheduler(model, params, pc, theta, mcfg_serve,
                             n_slots=m_slots, paged=True, block_size=bs,
                             num_blocks=m_blocks, chunk_tokens=chunk,
                             pack_chunks=True)
    assert pk_sched.token_budget == chk_sched.token_budget
    pk_sched.run(mixed_requests())
    done_x, fleet_x = best_of(lambda: pk_sched.run(mixed_requests()))
    stop_a = np.array([r.stop_step for r in done_a])
    stop_k = np.array([r.stop_step for r in done_k])
    stop_x = np.array([r.stop_step for r in done_x])
    assert (stop_a == stop_k).all(), \
        f"chunked prefill changed stop decisions: {stop_a} vs {stop_k}"
    assert (stop_k == stop_x).all(), \
        f"packed chunks changed stop decisions: {stop_k} vs {stop_x}"
    for sched_i in (chk_sched, pk_sched):
        assert sched_i._engine.compile_counts()["step"] == 1
        assert sched_i._engine.compile_counts()["admission_prefill"] == 0
    stall_ratio = fleet_a.stall_ms_p99 / max(fleet_k.stall_ms_p99, 1e-9)
    ttft_ratio = fleet_a.ttft_ms_p99 / max(fleet_k.ttft_ms_p99, 1e-9)
    packed_ratio = (fleet_x.requests_per_s
                    / max(fleet_k.requests_per_s, 1e-9))
    print(f"[throughput] packed == chunked == admission stop decisions on "
          f"mixed workload ({stop_k.tolist()}); KV budget "
          f"{hbm_mixed / 1e6:.2f} MB each, ONE step executable, "
          f"{fleet_k.prefill_chunks} chunks of {chunk} single vs "
          f"{fleet_x.prefill_chunks} packed ({fleet_x.packed_chunks} "
          "multi-request)")
    print(f"[throughput] p99 decode stall {fleet_a.stall_ms_p99:.2f} ms -> "
          f"{fleet_k.stall_ms_p99:.2f} ms ({stall_ratio:.2f}x), p99 TTFT "
          f"{fleet_a.ttft_ms_p99:.1f} -> {fleet_k.ttft_ms_p99:.1f} ms "
          f"({ttft_ratio:.2f}x)")
    print(f"[throughput] packed vs single-chunk (equal budget "
          f"{pk_sched.token_budget}): {packed_ratio:.2f}x requests/s "
          f"({fleet_x.requests_per_s:.2f} vs {fleet_k.requests_per_s:.2f}), "
          f"p99 TTFT {fleet_k.ttft_ms_p99:.1f} -> "
          f"{fleet_x.ttft_ms_p99:.1f} ms")

    # --- grouped consensus stop vs N independent samples at EQUAL KV HBM -
    g_size = args.group_size
    g_cache = args.prefix_prompt_len + args.group_max_new
    assert g_cache % bs == 0, (g_cache, bs)
    # equal budget again: the pool's TOTAL pages (null included) hold the
    # dense lanes' bytes; the gang (leader full + siblings sharing the
    # prompt pages) and the N independent prefix-shared samples get the
    # exact same physical pages to race in
    g_blocks = args.slots * g_cache // bs
    hbm_group = kv_bytes_paged(cfg, g_blocks, bs)
    g_prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 4),
                                   (args.group_prompts,
                                    args.prefix_prompt_len),
                                   0, cfg.vocab_size)
    gcfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.group_max_new, lam=float(lam),
                       burn_in=2)

    def group_reqs():
        return [r for p in range(args.group_prompts)
                for r in make_group(g_prompts[p], g_size, group_id=p)]

    def independent_reqs():
        # the SAME samples with no group ids: N independent requests each
        # running to its own per-request ORCA stop / budget
        return [make_request(g_prompts[p])
                for p in range(args.group_prompts) for _ in range(g_size)]

    # fixed consensus threshold, not LTT-calibrated, for the same reason
    # lam falls back to 0.99: greedy siblings of a random-weight model emit
    # identical answer streams, so consensus fires right after burn-in —
    # this row measures the SERVING win of firing (pages freed, slots
    # refilled); tests/test_validity_regression.py owns the calibrated
    # group-risk guarantee
    grp_sched = OrcaScheduler(model, params, pc, theta, gcfg,
                              n_slots=args.paged_slots, paged=True,
                              block_size=bs, num_blocks=g_blocks,
                              consensus=0.9)
    grp_sched.run(group_reqs())
    done_g, fleet_g = best_of(lambda: grp_sched.run(group_reqs()))
    ind_sched = OrcaScheduler(model, params, pc, theta, gcfg,
                              n_slots=args.paged_slots, paged=True,
                              block_size=bs, num_blocks=g_blocks)
    ind_sched.run(independent_reqs())
    done_i, fleet_i = best_of(lambda: ind_sched.run(independent_reqs()))
    # grouping with the consensus OFF must not move any stop decision
    off_sched = OrcaScheduler(model, params, pc, theta, gcfg,
                              n_slots=args.paged_slots, paged=True,
                              block_size=bs, num_blocks=g_blocks)
    done_o, _ = off_sched.run(group_reqs())
    stop_i = np.array([r.stop_step for r in done_i])
    stop_o = np.array([r.stop_step for r in done_o])
    assert (stop_i == stop_o).all(), \
        f"gang scheduling changed stop decisions: {stop_i} vs {stop_o}"
    consensus_idx = [int(g.consensus_index) for g in grp_sched.groups]
    assert fleet_g.consensus_groups == args.group_prompts, \
        f"only {fleet_g.consensus_groups}/{args.group_prompts} groups fired"
    assert fleet_g.cancel_freed_blocks > 0, \
        "consensus cancellation freed no pages"
    grp_sched.pool.check()
    group_ratio = (fleet_g.requests_per_s
                   / max(fleet_i.requests_per_s, 1e-9))
    print(f"[throughput] group consensus (size {g_size}, threshold 0.9): "
          f"all {fleet_g.consensus_groups} groups fired at reasoning steps "
          f"{consensus_idx}, {fleet_g.samples_cancelled} siblings cancelled "
          f"mid-flight, {fleet_g.cancel_freed_blocks} pages freed at "
          f"cancel, group savings {fleet_g.group_savings:.0f} steps "
          f"(mean {fleet_g.group_savings_mean:.3f}), KV budget "
          f"{hbm_group / 1e6:.2f} MB each")
    print(f"[throughput] grouped-consensus vs {g_size}-independent: "
          f"{group_ratio:.2f}x requests/s ({fleet_g.requests_per_s:.2f} vs "
          f"{fleet_i.requests_per_s:.2f})")

    # --- overload: involuntary preemption vs waiting, undersized pool ----
    o_slots = args.slots
    o_cache = args.prompt_len + args.max_new_tokens
    # pool sized so exactly `o_slots` requests can hold pages at once: an
    # urgent class-0 arrival at a full house must either preempt a lower-
    # class resident (spill KV + probe state to host RAM) or wait
    o_blocks = 1 + o_slots * ((o_cache + bs - 1) // bs)
    hbm_over = kv_bytes_paged(cfg, o_blocks, bs)

    def overload_requests():
        # burst arrival: batch traffic (class 1) lands first and fills the
        # fleet, two urgent class-0 requests hit the full house, background
        # class 2 trails — FIFO admission order preserves the burst shape
        reqs = queue_requests()
        for i, r in enumerate(reqs):
            r.priority = 1 if i < o_slots else \
                (0 if i < o_slots + 2 else 2)
        return reqs

    pre_sched = OrcaScheduler(model, params, pc, theta, scfg,
                              n_slots=o_slots, paged=True, block_size=bs,
                              num_blocks=o_blocks)
    pre_sched.run(overload_requests())
    done_v, fleet_v = best_of(lambda: pre_sched.run(overload_requests()))
    nop_sched = OrcaScheduler(model, params, pc, theta, scfg,
                              n_slots=o_slots, paged=True, block_size=bs,
                              num_blocks=o_blocks, preemption=False)
    nop_sched.run(overload_requests())
    done_n, fleet_n = best_of(lambda: nop_sched.run(overload_requests()))
    stop_v = np.array([r.stop_step for r in done_v])
    stop_n = np.array([r.stop_step for r in done_n])
    # the whole point: spilling a live request's KV and probe state to host
    # RAM and restoring it later must not move a single stop decision
    assert (stop_v == stop_n).all(), \
        f"preemption changed stop decisions: {stop_v} vs {stop_n}"
    assert fleet_v.preemptions >= 1, "overload never forced a spill"
    assert fleet_v.restores == fleet_v.preemptions, \
        "a spilled request was never restored"
    assert fleet_n.preemptions == 0
    pre_sched.pool.check()
    c0_pre = fleet_v.per_class["c0_ttft_ms_p99"]
    c0_wait = fleet_n.per_class["c0_ttft_ms_p99"]
    preempt_ratio = c0_wait / max(c0_pre, 1e-9)
    assert preempt_ratio > 1.0, \
        f"preemption did not improve class-0 TTFT ({c0_wait:.1f} ms vs " \
        f"{c0_pre:.1f} ms)"
    print(f"[throughput] overload preemption == wait-only stop decisions "
          f"({stop_v.tolist()}); {fleet_v.preemptions} spills / "
          f"{fleet_v.restores} restores, {fleet_v.spilled_blocks} pages "
          f"through host RAM, KV budget {hbm_over / 1e6:.2f} MB")
    print(f"[throughput] class-0 p99 TTFT under overload: "
          f"{c0_wait:.1f} ms (wait) -> {c0_pre:.1f} ms (preempt), "
          f"{preempt_ratio:.2f}x")

    # --- fleet: 2 hosts vs 1 host at EQUAL TOTAL KV pages ----------------
    n_fleet_hosts = args.fleet_hosts
    f_cache = args.prefix_prompt_len + args.prefix_max_new
    # total budget = what n_fleet_hosts dense-equivalent per-host pools
    # need; the single host gets the SAME total pages in one pool — the
    # fleet's win is turning the same bytes into more concurrent slots
    f_host_blocks = args.paged_slots * (f_cache // bs) + 1
    f_total_blocks = n_fleet_hosts * f_host_blocks
    hbm_fleet = kv_bytes_paged(cfg, f_total_blocks, bs)
    f_prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 5),
                                   (args.fleet_prompts,
                                    args.prefix_prompt_len),
                                   0, cfg.vocab_size)

    def fleet_requests():
        # self-consistency style shared-prefix traffic: samples of one
        # prompt enqueue back-to-back (the affinity router's best case)
        return [make_request(f_prompts[p])
                for p in range(args.fleet_prompts)
                for _ in range(args.prefix_samples)]

    fcfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.prefix_max_new, lam=float(lam),
                       burn_in=2, n_slots=args.paged_slots,
                       cache_len=f_cache, paged=True, block_size=bs,
                       num_blocks=f_total_blocks)
    one_sched = orca.engine(model, params, calib, config=fcfg)
    one_sched.run(fleet_requests())
    done_1h, fleet_1h = best_of(lambda: one_sched.run(fleet_requests()))
    fl_router = orca.fleet(model, params, calib, config=fcfg,
                           n_hosts=n_fleet_hosts)
    fl_router.run(fleet_requests())
    done_fl, fleet_fl = best_of(lambda: fl_router.run(fleet_requests()))
    stop_1h = np.array([r.stop_step for r in done_1h])
    stop_fl = np.array([r.stop_step for r in done_fl])
    assert (stop_1h == stop_fl).all(), \
        f"fleet serving changed stop decisions: {stop_1h} vs {stop_fl}"
    assert fleet_fl.prefill_skips > 0, \
        "prefix-affine placement produced no donor-host prefill skips"
    assert fleet_fl.routed_affine > 0, \
        "no placement followed the prefix-affinity hint"
    for h in fl_router.hosts:
        h.pool.check()
    fleet_ratio = (fleet_fl.requests_per_s
                   / max(fleet_1h.requests_per_s, 1e-9))
    fleet_ttft_gain = (fleet_1h.ttft_ms_p99
                       / max(fleet_fl.ttft_ms_p99, 1e-9))
    print(f"[throughput] fleet == single-host stop decisions on "
          f"shared-prefix workload ({stop_fl.tolist()}); "
          f"{fleet_fl.prefill_skips} donor-host prefill skips, "
          f"{fleet_fl.routed_affine} prefix-affine placements, KV budget "
          f"{hbm_fleet / 1e6:.2f} MB total each")
    print(f"[throughput] {n_fleet_hosts}-host fleet vs 1 host (equal total "
          f"pages): {fleet_ratio:.2f}x requests/s "
          f"({fleet_fl.requests_per_s:.2f} vs {fleet_1h.requests_per_s:.2f}), "
          f"p99 TTFT {fleet_1h.ttft_ms_p99:.1f} -> "
          f"{fleet_fl.ttft_ms_p99:.1f} ms ({fleet_ttft_gain:.2f}x; "
          f"requests/s needs >= 2 cores to beat parity, this box has "
          f"{os.cpu_count()})")

    # --- speculative draft-verify vs one-token decode, replay fleet ------
    # decode-bound: tokens_per_step=1 replay trajectories, every generated
    # token is a scored reasoning step.  The replay model drafts from its
    # own trajectory (100% acceptance — the ceiling a learned drafter
    # approaches), so each unified step advances every slot by the full
    # verify block.  EQUAL KV HBM by construction: the two runs differ
    # ONLY in spec_tokens (same engine shape, same cache)
    s_traj, s_steps = args.spec_trajectories, args.spec_steps
    s_rs = np.random.RandomState(args.seed)
    s_drift = np.linspace(0, 1.2, s_steps)[None, :, None]
    s_bank = (s_rs.randn(s_traj, s_steps, 16) * 0.3
              + s_drift * s_rs.rand(s_traj, 1, 16)).astype(np.float32)
    s_theta = {"W0": (s_rs.randn(16) * 0.4).astype(np.float32),
               "b0": np.float32(-0.2)}
    s_pc = ProbeConfig(d_phi=16, smooth_window=4)
    s_scfg = ServeConfig(tokens_per_step=1, max_new_tokens=s_steps,
                         lam=0.62, burn_in=3)
    s_model, s_params = replay_model(s_bank), replay_params(s_bank)

    def spec_requests():
        return replay_requests([s_steps] * s_traj)

    # wall times here are tens of ms, so this row takes extra timed reps —
    # the per-step fixed cost spec amortizes is exactly what jitters
    s_reps = max(args.reps, 5)
    ot_sched = OrcaScheduler(s_model, s_params, s_pc, s_theta, s_scfg,
                             n_slots=4)
    ot_sched.run(spec_requests())
    done_ot, fleet_ot = best_of(lambda: ot_sched.run(spec_requests()),
                                n=s_reps)
    sp_sched = OrcaScheduler(s_model, s_params, s_pc, s_theta, s_scfg,
                             n_slots=4, spec_tokens=args.spec_tokens)
    sp_sched.run(spec_requests())
    done_sp, fleet_sp = best_of(lambda: sp_sched.run(spec_requests()),
                                n=s_reps)
    stop_ot = np.array([r.stop_step for r in done_ot])
    stop_sp = np.array([r.stop_step for r in done_sp])
    # the tentpole invariant: draft-verify must not move a stop decision
    assert (stop_ot == stop_sp).all(), \
        f"spec decode changed stop decisions: {stop_ot} vs {stop_sp}"
    for r_ot, r_sp in zip(done_ot, done_sp):
        assert r_ot.tokens == r_sp.tokens, "spec decode changed tokens"
    assert fleet_sp.acceptance_rate == 1.0, \
        f"replay self-draft acceptance {fleet_sp.acceptance_rate} != 1.0"
    assert fleet_sp.engine_steps < fleet_ot.engine_steps
    spec_ratio = fleet_sp.tokens_per_s / max(fleet_ot.tokens_per_s, 1e-9)
    assert spec_ratio >= 1.3, \
        f"spec decode only {spec_ratio:.2f}x tokens/s (need >= 1.3x)"
    print(f"[throughput] spec == one-token stop decisions on replay fleet "
          f"({stop_sp.tolist()}); {fleet_sp.spec_tokens_accepted}/"
          f"{fleet_sp.spec_tokens_proposed} drafts accepted, accepted "
          f"length p50/p99 {fleet_sp.accepted_len_p50:.1f}/"
          f"{fleet_sp.accepted_len_p99:.1f}")
    print(f"[throughput] spec decode (k={args.spec_tokens}, self-draft): "
          f"{spec_ratio:.2f}x decode tokens/s ({fleet_sp.tokens_per_s:.1f} "
          f"vs {fleet_ot.tokens_per_s:.1f}), engine steps "
          f"{fleet_ot.engine_steps} -> {fleet_sp.engine_steps}")

    # --- tree vs linear spec vs one-token, PARTIAL acceptance ------------
    # same replay bank, but every drafted token is corrupted with
    # --draft-wrong-rate: the linear chain dies at its first wrong token,
    # the tree's W chains fail independently and the verifier commits the
    # longest surviving root-to-leaf path.  EQUAL KV HBM by construction
    # (replay carries no KV; engine shape identical across the triple)
    wrong = args.draft_wrong_rate
    # the ratio gate needs walls well clear of scheduler jitter: tile the
    # bank so each timed pass serves 4x the trajectories (~200 ms walls
    # instead of ~50 ms; stop decisions stay per-trajectory deterministic
    # so the byte-identity asserts below are unchanged in meaning), and
    # take extra best-of reps on top — both knobs attack the same
    # single-core noise that once flipped this ratio run to run
    pa_tile = 4
    pa_bank = np.tile(s_bank, (pa_tile, 1, 1))
    pa_model = replay_model(pa_bank, draft_wrong_rate=wrong)
    pa_params = replay_params(pa_bank)

    def pa_requests():
        return replay_requests([s_steps] * (s_traj * pa_tile))

    pa_reps = max(args.reps, 8)
    pt_sched = OrcaScheduler(pa_model, pa_params, s_pc, s_theta, s_scfg,
                             n_slots=4)
    pt_sched.run(pa_requests())
    done_pt, fleet_pt = best_of(lambda: pt_sched.run(pa_requests()),
                                n=pa_reps)
    pl_sched = OrcaScheduler(pa_model, pa_params, s_pc, s_theta, s_scfg,
                             n_slots=4, spec_tokens=args.spec_tokens)
    pl_sched.run(pa_requests())
    done_pl, fleet_pl = best_of(lambda: pl_sched.run(pa_requests()),
                                n=pa_reps)
    tr_sched = OrcaScheduler(pa_model, pa_params, s_pc, s_theta, s_scfg,
                             n_slots=4, spec_tree=args.spec_tree)
    tr_sched.run(pa_requests())
    done_tr, fleet_tr = best_of(lambda: tr_sched.run(pa_requests()),
                                n=pa_reps)
    stop_pt = np.array([r.stop_step for r in done_pt])
    stop_pl = np.array([r.stop_step for r in done_pl])
    stop_tr = np.array([r.stop_step for r in done_tr])
    # wrong drafts are the verifier's problem, never the user's: the stop
    # decisions survive ANY draft quality, linear or tree
    assert (stop_pt == stop_pl).all(), \
        f"partial-accept linear spec changed stops: {stop_pt} vs {stop_pl}"
    assert (stop_pt == stop_tr).all(), \
        f"tree spec changed stops: {stop_pt} vs {stop_tr}"
    for r_pt, r_tr in zip(done_pt, done_tr):
        assert r_pt.tokens == r_tr.tokens, "tree spec changed tokens"
    assert 0 < fleet_pl.acceptance_rate < 1.0, \
        f"linear acceptance {fleet_pl.acceptance_rate} not partial"
    assert fleet_tr.tree_nodes_proposed > 0
    assert fleet_tr.engine_steps < fleet_pl.engine_steps < \
        fleet_pt.engine_steps
    assert tr_sched._engine.compile_counts()["step"] == 1
    # the gated ratio is SEQUENTIAL ENGINE STEPS at equal committed
    # tokens — the quantity tree verification actually shrinks, and fully
    # deterministic (seeded drafts, seeded wrongness).  Wall-clock
    # tokens/s is printed and recorded too, but on a single-core CPU the
    # tree's larger per-step packed chunk (1+W*D nodes/slot vs k)
    # costs real compute per step and mutes the wall gain to ~1.1-1.2x;
    # bandwidth-bound decode on real accelerators realizes the step
    # ratio, so the wall number only gets a tolerant informational floor
    # (same box caveat as the fleet requests/s row above)
    tree_ratio = fleet_pl.engine_steps / max(fleet_tr.engine_steps, 1)
    assert tree_ratio >= 1.15, \
        f"tree spec only {tree_ratio:.2f}x fewer sequential steps than " \
        f"linear (need >= 1.15x)"
    # no wall-clock direction assert here: on a single serial core the
    # per-step compute grows with the packed chunk, so tree-vs-one-token
    # wall ordering is genuinely load-dependent — the deterministic step
    # asserts above are the contract, the walls are reporting
    tree_wall_ratio = (fleet_tr.tokens_per_s
                       / max(fleet_pl.tokens_per_s, 1e-9))
    print(f"[throughput] tree == linear == one-token stop decisions at "
          f"wrong-rate {wrong} ({stop_tr.tolist()}); accepted path p50/p99 "
          f"{fleet_tr.tree_path_accepted_p50:.1f}/"
          f"{fleet_tr.tree_path_accepted_p99:.1f} over "
          f"{fleet_tr.tree_nodes_proposed} proposed nodes")
    print(f"[throughput] tree spec ({args.spec_tree}) vs linear "
          f"(k={args.spec_tokens}): {tree_ratio:.2f}x fewer sequential "
          f"steps ({fleet_pt.engine_steps} -> {fleet_pl.engine_steps} -> "
          f"{fleet_tr.engine_steps}), wall {tree_wall_ratio:.2f}x decode "
          f"tokens/s ({fleet_tr.tokens_per_s:.1f} vs "
          f"{fleet_pl.tokens_per_s:.1f}; single-core mutes this, see "
          f"docstring)")

    util_b = base.active_slot_steps / max(base.total_slot_steps, 1)
    steps_s = fleet.engine_steps / max(fleet.wall_time_s, 1e-9)
    steps_s_ref = fleet_ref.engine_steps / max(fleet_ref.wall_time_s, 1e-9)
    rows = [
        {"mode": "static-batch", "engine_steps": base.engine_steps,
         "requests_per_s": n_requests / base.wall_time_s,
         "slot_utilization": util_b, "wall_s": base.wall_time_s},
        {"mode": "continuous", **fleet.row(), "steps_per_s": steps_s,
         "wall_s": fleet.wall_time_s},
        {"mode": "continuous[pr1-jnp-probe]", **fleet_ref.row(),
         "steps_per_s": steps_s_ref, "wall_s": fleet_ref.wall_time_s},
        {"mode": "dense-prefix", **fleet_d.row(),
         "kv_mb": hbm_dense / 1e6, "wall_s": fleet_d.wall_time_s},
        {"mode": "paged-prefix", **fleet_p.row(),
         "kv_mb": hbm_paged / 1e6, "wall_s": fleet_p.wall_time_s},
        {"mode": "admission-prefill-mixed", **fleet_a.row(),
         "kv_mb": hbm_mixed / 1e6, "wall_s": fleet_a.wall_time_s},
        {"mode": "single-chunk-mixed", **fleet_k.row(),
         "kv_mb": hbm_mixed / 1e6, "chunk_tokens": chunk,
         "wall_s": fleet_k.wall_time_s},
        {"mode": "packed-chunk-mixed", **fleet_x.row(),
         "kv_mb": hbm_mixed / 1e6, "chunk_tokens": chunk,
         "wall_s": fleet_x.wall_time_s},
        {"mode": "group-consensus", **fleet_g.row(),
         "kv_mb": hbm_group / 1e6, "group_size": g_size,
         "wall_s": fleet_g.wall_time_s},
        {"mode": "group-independent", **fleet_i.row(),
         "kv_mb": hbm_group / 1e6, "group_size": 1,
         "wall_s": fleet_i.wall_time_s},
        {"mode": "overload-preempt", **fleet_v.row(),
         "kv_mb": hbm_over / 1e6, "wall_s": fleet_v.wall_time_s},
        {"mode": "overload-wait", **fleet_n.row(),
         "kv_mb": hbm_over / 1e6, "wall_s": fleet_n.wall_time_s},
        {"mode": "fleet-1-host", **fleet_1h.row(),
         "kv_mb": hbm_fleet / 1e6, "wall_s": fleet_1h.wall_time_s},
        {"mode": f"fleet-{n_fleet_hosts}-hosts", **fleet_fl.row(),
         "kv_mb": hbm_fleet / 1e6, "wall_s": fleet_fl.wall_time_s},
        {"mode": "one-token-decode", **fleet_ot.row(),
         "wall_s": fleet_ot.wall_time_s},
        {"mode": f"spec-decode-k{args.spec_tokens}", **fleet_sp.row(),
         "wall_s": fleet_sp.wall_time_s},
        {"mode": "one-token-partial", **fleet_pt.row(),
         "wall_s": fleet_pt.wall_time_s},
        {"mode": f"linear-spec-k{args.spec_tokens}-partial",
         **fleet_pl.row(), "wall_s": fleet_pl.wall_time_s},
        {"mode": f"tree-spec-{args.spec_tree}-partial", **fleet_tr.row(),
         "wall_s": fleet_tr.wall_time_s},
    ]
    print_table("serving throughput (same lambda*, same stop decisions)",
                rows, ("mode", "engine_steps", "requests_per_s",
                       "slot_utilization", "prefill_skips",
                       "stall_ms_p99", "ttft_ms_p99", "packed_chunks",
                       "wall_s"))

    speedup = rows[1]["requests_per_s"] / max(rows[0]["requests_per_s"], 1e-9)
    probe_ratio = steps_s / max(steps_s_ref, 1e-9)
    paged_ratio = (fleet_p.requests_per_s
                   / max(fleet_d.requests_per_s, 1e-9))
    print(f"\ncontinuous batching: {speedup:.2f}x requests/s, slot "
          f"utilization {util_b:.2f} -> {fleet.slot_utilization:.2f}")
    print(f"fused probe step: {steps_s:.1f} steps/s (kernel) vs "
          f"{steps_s_ref:.1f} steps/s (pr1-jnp) -> {probe_ratio:.2f}x "
          "at identical stops")
    print(f"paged KV (equal HBM, shared prefix): {paged_ratio:.2f}x "
          f"requests/s vs dense ({fleet_p.requests_per_s:.2f} vs "
          f"{fleet_d.requests_per_s:.2f})")

    report = {
        "schema": 9,
        "quick": QUICK,
        "rows": rows,
        # the gate requires these BYTE-IDENTICAL against the baseline: the
        # calibrated procedure's stop decisions are part of the contract
        "stop_steps": {
            "continuous": stop_c.tolist(),
            "dense_prefix": stop_d.tolist(),
            "paged_prefix": stop_p.tolist(),
            "mixed_admission": stop_a.tolist(),
            "mixed_chunked": stop_k.tolist(),
            "mixed_packed": stop_x.tolist(),
            # N-independent == gang-scheduled-without-consensus (asserted
            # above); the grouped run's consensus fire indices are part of
            # the calibrated procedure too
            "group_independent": stop_i.tolist(),
            "group_consensus_index": consensus_idx,
            # preempt == wait-only (asserted above): one list covers both
            "overload": stop_v.tolist(),
            # fleet == single-host (asserted above): one list covers both
            "fleet": stop_fl.tolist(),
            # spec == one-token (asserted above): one list covers both
            "spec_decode": stop_sp.tolist(),
            # tree == linear == one-token at partial acceptance (asserted
            # above): one list covers the whole triple
            "tree_spec": stop_tr.tolist(),
        },
        # every metric must stay >= min_frac * baseline value; tolerances
        # live IN the baseline so re-baselining is an explicit commit
        "check": {
            "metrics": {
                "continuous_vs_static_requests_per_s":
                    {"value": speedup, "min_frac": 0.5},
                "kernel_vs_ref_steps_per_s":
                    {"value": probe_ratio, "min_frac": 0.5},
                "paged_vs_dense_requests_per_s":
                    {"value": paged_ratio, "min_frac": 0.6},
                "continuous_steps_per_s":
                    {"value": steps_s, "min_frac": 0.1},
                # stall-free serving: admission-prefill p99 step stall over
                # chunked p99 (the tentpole win — >= 2x committed)
                "admission_vs_chunked_stall_p99":
                    {"value": stall_ratio, "min_frac": 0.4},
                "chunked_mixed_requests_per_s":
                    {"value": fleet_k.requests_per_s, "min_frac": 0.3},
                # packed composer: requests/s of packed over single-request
                # chunks at equal token budget and equal KV HBM
                "packed_vs_single_chunk":
                    {"value": packed_ratio, "min_frac": 0.75},
                # consensus stop: requests/s of gang-scheduled groups with
                # mid-flight sibling cancellation over the same samples
                # served independently, equal KV HBM
                "group_consensus_requests_per_s":
                    {"value": fleet_g.requests_per_s, "min_frac": 0.3},
                "group_consensus_vs_independent":
                    {"value": group_ratio, "min_frac": 0.6},
                # overload: class-0 p99 TTFT improvement from involuntary
                # preemption (no-preempt / preempt ratio, bigger is better)
                "preemption_ttft_p99_class0":
                    {"value": preempt_ratio, "min_frac": 0.3},
                # fleet serving at equal TOTAL KV pages on shared-prefix
                # traffic (stops byte-identical, asserted).  The p99-TTFT
                # gain is STRUCTURAL (half the admission waves) and holds
                # on one core; the requests/s ratio only beats parity
                # with >= 2 physical cores (concurrent host stepping), so
                # its committed single-core value is ~1x and the floor is
                # tolerant — multi-core CI clears it with margin
                "fleet_requests_per_s":
                    {"value": fleet_fl.requests_per_s, "min_frac": 0.3},
                "fleet_vs_single_host":
                    {"value": fleet_ratio, "min_frac": 0.5},
                "fleet_ttft_p99_gain":
                    {"value": fleet_ttft_gain, "min_frac": 0.5},
                # speculative decode on the decode-bound replay fleet at
                # equal KV HBM: absolute decode tokens/s, plus the ratio
                # over one-token decode.  The ratio's floor is pinned at
                # 1.3x (min_frac scaled so baseline * min_frac == 1.3
                # whenever the committed ratio clears 1.3/0.95)
                "spec_decode_tokens_per_s":
                    {"value": fleet_sp.tokens_per_s, "min_frac": 0.3},
                "spec_vs_one_token":
                    {"value": spec_ratio,
                     "min_frac": min(0.95, 1.3 / spec_ratio)},
                # tree speculative decode on the PARTIAL-acceptance replay
                # fleet at equal KV HBM.  The gated ratio is SEQUENTIAL
                # ENGINE STEPS (linear/tree at equal committed tokens) —
                # deterministic, so the 1.15x floor cannot flake (min_frac
                # scaled so baseline * min_frac == 1.15 whenever the
                # committed ratio clears 1.15/0.95).  Wall tokens/s is
                # informational with a tolerant floor: the single-core
                # per-step cost of the bigger tree chunk mutes it here,
                # while bandwidth-bound decode on real accelerators
                # realizes the step ratio (see module docstring)
                "tree_spec_tokens_per_s":
                    {"value": fleet_tr.tokens_per_s, "min_frac": 0.3},
                "tree_vs_linear_spec":
                    {"value": tree_ratio,
                     "min_frac": min(0.95, 1.15 / tree_ratio)},
            },
        },
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"[throughput] wrote {out_path}")

    if args.check:
        return check_against_baseline(report, BASELINE)
    return 0


def check_against_baseline(report: dict, baseline_path: str) -> int:
    """The CI gate: stop decisions byte-identical, metrics within the
    baseline's own tolerances.  Returns a nonzero exit code on regression."""
    if not os.path.exists(baseline_path):
        print(f"[check] FAIL: no committed baseline at {baseline_path}")
        return 2
    with open(baseline_path) as f:
        baseline = json.load(f)
    if baseline.get("schema") != report["schema"]:
        print("[check] FAIL: baseline schema "
              f"{baseline.get('schema')} != {report['schema']} — "
              "re-baseline by running without --check and committing "
              f"{baseline_path}")
        return 2
    if baseline.get("quick") != report["quick"]:
        print(f"[check] FAIL: baseline quick={baseline.get('quick')} but "
              f"this run has quick={report['quick']} (set "
              "REPRO_BENCH_QUICK to match the committed baseline)")
        return 2
    failures = []
    for name, b_stops in baseline["stop_steps"].items():
        f_stops = report["stop_steps"].get(name)
        if f_stops != b_stops:
            failures.append(f"stop decisions for {name!r} changed: "
                            f"baseline {b_stops} vs fresh {f_stops}")
    for name, b in baseline["check"]["metrics"].items():
        fresh = report["check"]["metrics"].get(name)
        if fresh is None:
            failures.append(f"metric {name!r} missing from fresh run")
            continue
        floor = b["value"] * b["min_frac"]
        if fresh["value"] < floor:
            failures.append(
                f"{name}: {fresh['value']:.3f} < floor {floor:.3f} "
                f"(baseline {b['value']:.3f} x min_frac {b['min_frac']})")
        else:
            print(f"[check] {name}: {fresh['value']:.3f} >= floor "
                  f"{floor:.3f}  OK")
    if failures:
        for msg in failures:
            print(f"[check] FAIL: {msg}")
        print("[check] throughput regression gate FAILED — if intentional, "
              "re-baseline: python benchmarks/serving_throughput.py && "
              f"git add {baseline_path}")
        return 1
    print("[check] throughput regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
