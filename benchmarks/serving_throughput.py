"""Continuous batching vs static batching at the SAME calibrated lambda*.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--arch smollm-360m]

Drives a queue of ``--requests`` (default 4x the slot count) through

  * ``OrcaScheduler`` — continuous batching: each ORCA stop evicts its slot,
    which is refilled from the queue before the next fused step;
  * the static-batch ``ServingEngine`` baseline — requests grouped into
    fixed batches of ``--slots``; stopped sequences burn their slot until
    the slowest group member finishes.

Both paths run the identical calibrated procedure (same probe theta, same
lambda*, same burn-in), so per-request stop decisions must be IDENTICAL —
the benchmark asserts stop steps match exactly and score trajectories agree
to tolerance, then reports requests/s, engine steps and slot utilization.
Eviction is where the paper's calibrated savings become throughput.

A third row replays the continuous queue with the PR-1 jnp probe
(``probe_impl="ref"``) for a before/after of the fused Pallas serving step:
same stop decisions (asserted), steps/s compared.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro import api as orca
from repro.configs import get_config
from repro.core.probe import ProbeConfig
from repro.launch.serve import model_inputs, trajectories_from_model
from repro.models import build
from repro.serving import (OrcaScheduler, ServeConfig, ServingEngine,
                           make_request, serve_queue_static)

from benchmarks.common import print_table, save_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=0,
                    help="queue size (default 4x slots)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--tokens-per-step", type=int, default=4)
    ap.add_argument("--train-trajectories", type=int, default=24)
    ap.add_argument("--delta", type=float, default=0.25)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions per path (best kept)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    n_requests = args.requests or 4 * args.slots

    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[throughput] {cfg.name}: {n_requests} requests through "
          f"{args.slots} slots")

    ts = trajectories_from_model(model, params, args.train_trajectories,
                                 args.prompt_len, args.max_new_tokens,
                                 args.tokens_per_step, args.seed)
    half = len(ts) // 2
    train, cal = ts.subset(np.arange(half)), ts.subset(np.arange(half, len(ts)))
    calib = orca.fit(train, mode="consistent", method="ttt",
                     pc=ProbeConfig(d_phi=cfg.d_model, smooth_window=4),
                     epochs=args.epochs, epoch_select=False, seed=args.seed)
    # demo fallback keeps eviction observable on tiny random-weight models
    lam = orca.calibrated_lambda(calib, cal, args.delta, fallback=0.99)
    print(f"[throughput] calibrated lambda* = {lam:.3f}")

    batch = model_inputs(cfg, jax.random.PRNGKey(args.seed + 1), n_requests,
                         args.prompt_len)
    pc, theta = calib.serving_params()
    scfg = ServeConfig(tokens_per_step=args.tokens_per_step,
                       max_new_tokens=args.max_new_tokens, lam=float(lam),
                       burn_in=2)

    extra_keys = [k for k in batch if k != "tokens"]

    def queue_requests():
        return [make_request(batch["tokens"][i],
                             extra={k: batch[k][i:i + 1] for k in extra_keys})
                for i in range(n_requests)]

    # each path gets one untimed warm-up pass (jit + Pallas tracing), then
    # best-of-N timed passes — steady-state throughput is what serving
    # cares about, and best-of-N tames shared-machine timing noise
    def best_of(run, n=args.reps):
        results = [run() for _ in range(n)]
        return min(results, key=lambda r: r[-1].wall_time_s
                   if isinstance(r, tuple) else r.wall_time_s)

    # --- static-batch baseline -------------------------------------------
    eng = ServingEngine(model, params, pc, theta, scfg)
    serve_queue_static(eng, batch, args.prompt_len, args.slots)
    base = best_of(lambda: serve_queue_static(eng, batch, args.prompt_len,
                                              args.slots))

    # --- continuous batching ---------------------------------------------
    sched = orca.engine(model, params, calib, n_slots=args.slots, lam=lam,
                        tokens_per_step=args.tokens_per_step,
                        max_new_tokens=args.max_new_tokens, burn_in=2)
    sched.run(queue_requests())
    done, fleet = best_of(lambda: sched.run(queue_requests()))

    # --- before/after: PR-1 jnp probe vs the fused Pallas serving step ---
    sched_ref = OrcaScheduler(model, params, pc, theta, scfg,
                              n_slots=args.slots, probe_impl="ref")
    sched_ref.run(queue_requests())
    done_ref, fleet_ref = best_of(lambda: sched_ref.run(queue_requests()))

    # --- eviction/probe-impl must not change ANY stop decision -----------
    stop_c = np.array([r.stop_step for r in done])
    stop_r = np.array([r.stop_step for r in done_ref])
    assert (base.stop_step == stop_c).all(), \
        f"stop decisions diverged: static {base.stop_step} vs {stop_c}"
    assert (stop_c == stop_r).all(), \
        f"probe impls diverged: kernel {stop_c} vs pr1-jnp {stop_r}"
    for i, r in enumerate(done):
        n = min(len(r.scores), base.scores[i].shape[0])
        np.testing.assert_allclose(np.array(r.scores)[:n],
                                   base.scores[i][:n], atol=1e-4)
    print("[throughput] per-request stop decisions identical "
          f"(stop steps {stop_c.tolist()})")

    util_b = base.active_slot_steps / max(base.total_slot_steps, 1)
    steps_s = fleet.engine_steps / max(fleet.wall_time_s, 1e-9)
    steps_s_ref = fleet_ref.engine_steps / max(fleet_ref.wall_time_s, 1e-9)
    rows = [
        {"mode": "static-batch", "engine_steps": base.engine_steps,
         "requests_per_s": n_requests / base.wall_time_s,
         "slot_utilization": util_b, "wall_s": base.wall_time_s},
        {"mode": "continuous", **fleet.row(), "steps_per_s": steps_s,
         "wall_s": fleet.wall_time_s},
        {"mode": "continuous[pr1-jnp-probe]", **fleet_ref.row(),
         "steps_per_s": steps_s_ref, "wall_s": fleet_ref.wall_time_s},
    ]
    print_table("serving throughput (same lambda*, same stop decisions)",
                rows, ("mode", "engine_steps", "requests_per_s",
                       "slot_utilization", "wall_s"))
    save_rows("serving_throughput", rows)

    speedup = rows[1]["requests_per_s"] / max(rows[0]["requests_per_s"], 1e-9)
    print(f"\ncontinuous batching: {speedup:.2f}x requests/s, slot "
          f"utilization {util_b:.2f} -> {fleet.slot_utilization:.2f}")
    print(f"fused probe step: {steps_s:.1f} steps/s (kernel) vs "
          f"{steps_s_ref:.1f} steps/s (pr1-jnp) -> "
          f"{steps_s / max(steps_s_ref, 1e-9):.2f}x at identical stops")
    if fleet.engine_steps > base.engine_steps:
        print("note: queue shorter than needed to amortize? continuous ran "
              "more fused steps than the static baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
