"""Shared infrastructure for the paper-table benchmarks.

Probe training is cached on disk (results/probes/<key>) so the table scripts
compose without retraining; REPRO_BENCH_QUICK=1 shrinks corpus/epochs for CI.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
from typing import Dict, Optional, Sequence

import numpy as np

import jax

from repro import api as orca
from repro.checkpoint import restore, save_pytree
from repro.core.pipeline import TrainedProbe, evaluate_probe
from repro.core.probe import ProbeConfig, init_outer
from repro.core.static_probe import StaticProbe
from repro.trajectories import TrajectorySet, corpus_splits, ood_benchmark

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
D_PHI = 128 if QUICK else 192
N_TRAIN, N_CAL, N_TEST = (360, 120, 120) if QUICK else (500, 170, 170)
N_OOD = 120 if QUICK else 170
EPOCHS = 25 if QUICK else 35
DELTAS = (0.05, 0.1, 0.15, 0.2)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
PROBE_DIR = os.path.join(RESULTS, "probes")


@functools.lru_cache(maxsize=None)
def corpus(d_phi: int = D_PHI, seed: int = 0):
    return corpus_splits(N_TRAIN, N_CAL, N_TEST, d_phi=d_phi, seed=seed)


@functools.lru_cache(maxsize=None)
def ood(name: str, d_phi: int = D_PHI):
    return ood_benchmark(name, N_OOD, d_phi=d_phi)


def _probe_key(pc: ProbeConfig, mode: str, seed: int, tag: str) -> str:
    blob = json.dumps([dataclasses.asdict(pc), mode, seed, tag, N_TRAIN,
                       EPOCHS], sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


_PROBE_MEMO: Dict[str, TrainedProbe] = {}


def get_probe(train: TrajectorySet, mode: str, pc: ProbeConfig,
              seed: int = 0, tag: str = "corpus", epochs: Optional[int] = None,
              epoch_select: bool = True) -> TrainedProbe:
    key = _probe_key(pc, mode, seed, tag) + ("" if epoch_select else "-nosel")
    if key in _PROBE_MEMO:
        return _PROBE_MEMO[key]
    path = os.path.join(PROBE_DIR, key)
    theta_tmpl = init_outer(pc, jax.random.PRNGKey(seed))
    if os.path.isdir(os.path.join(path, "final")):
        theta = restore(theta_tmpl, os.path.join(path, "final"))
        with open(os.path.join(path, "final", "meta.json")) as f:
            hist = json.load(f).get("history", [])
        probe = TrainedProbe(pc, theta, hist)
    else:
        probe = orca.fit(train, mode=mode, method="ttt", pc=pc,
                         epochs=epochs or EPOCHS, seed=seed,
                         epoch_select=epoch_select).probe
        os.makedirs(PROBE_DIR, exist_ok=True)
        save_pytree(probe.theta, path, meta={"history": probe.history})
    _PROBE_MEMO[key] = probe
    return probe


_STATIC_MEMO: Dict[str, StaticProbe] = {}


def get_static(train: TrajectorySet, mode: str, tag: str = "corpus"
               ) -> StaticProbe:
    key = f"{mode}-{tag}-{len(train)}"
    if key not in _STATIC_MEMO:
        _STATIC_MEMO[key] = orca.fit(train, mode=mode, method="static").probe
    return _STATIC_MEMO[key]


def eval_rows(method: str, mode: str, scores_cal, cal, scores_test, test,
              deltas: Sequence[float] = DELTAS) -> list:
    ev = evaluate_probe(scores_cal, cal, scores_test, test, mode, deltas)
    return [{"method": method, "mode": mode, **r.row()} for r in ev.results]


def print_table(title: str, rows: list, cols: Sequence[str]):
    print(f"\n### {title}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def save_rows(name: str, rows: list):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
