"""Benchmark harness: one entry per paper table/figure + roofline + micro.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig3]
    REPRO_BENCH_QUICK=1 shrinks corpora/epochs for CI.

Each table prints CSV rows and persists json under results/.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

TABLES = [
    ("table2", "benchmarks.table2_indist"),
    ("table3", "benchmarks.table3_ood"),
    ("table4", "benchmarks.table4_crossmodel"),
    ("table5", "benchmarks.table5_ablation"),
    ("table7", "benchmarks.table7_variants"),
    ("table9", "benchmarks.table9_token_savings"),
    ("table10", "benchmarks.table10_epochs"),
    ("fig3", "benchmarks.fig3_calibration"),
    ("fig4", "benchmarks.fig4_distribution"),
    ("fig5", "benchmarks.fig5_trajectory"),
    ("roofline", "benchmarks.roofline_report"),
    ("micro", "benchmarks.microbench"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(k for k, _ in TABLES))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for key, mod_name in TABLES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            importlib.import_module(mod_name).run()
            print(f"# {key} done in {time.time()-t0:.1f}s")
        except Exception:
            failures += 1
            print(f"# {key} FAILED:")
            traceback.print_exc()
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
