"""Figure 5 — score trajectories on a representative problem: the static
probe's score stays below its threshold (0 savings) while the TTT probe
adapts online and crosses after the breakthrough."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import stopping as S
from repro.core.pipeline import make_labels
from repro.core.probe import ProbeConfig


def run() -> list:
    train, cal, test = C.corpus()
    mode = "supervised"
    lab_cal = make_labels(cal, mode)
    static = C.get_static(train, mode)
    probe = C.get_probe(train, mode, ProbeConfig(d_phi=C.D_PHI))
    evs = {}
    for name, s_cal in [("static", static.scores(cal.phis, cal.mask)),
                        ("ttt", probe.scores(cal))]:
        evs[name] = S.calibrate_and_evaluate(
            s_cal, lab_cal, cal.mask, s_cal, lab_cal, cal.mask, delta=0.1)
    s_static = static.scores(test.phis, test.mask)
    s_ttt = probe.scores(test)
    # find a problem the TTT probe stops early but static runs to budget
    rows = []
    for i in range(len(test)):
        T, tau = int(test.lengths[i]), int(test.tau[i])
        if tau >= T or tau < 12:
            continue
        lam_s, lam_t = evs["static"].lam, evs["ttt"].lam
        if not (np.isfinite(lam_s) and np.isfinite(lam_t)):
            continue
        cross_t = np.where(s_ttt[i, 10:T] >= lam_t)[0]
        cross_s = np.where(s_static[i, 10:T] >= lam_s)[0]
        if len(cross_t) and not len(cross_s):
            stop_t = 10 + int(cross_t[0])
            rows.append({"problem": i, "T": T, "breakthrough_step": tau,
                         "ttt_stop": stop_t, "ttt_lambda": lam_t,
                         "static_lambda": lam_s,
                         "ttt_savings": 1 - (stop_t + 1) / T,
                         "static_savings": 0.0})
            if len(rows) == 1:
                steps = list(range(0, T, max(T // 12, 1)))
                print(f"\n# sample problem {i}: breakthrough at step {tau}, "
                      f"TTT crosses {lam_t:.2f} at step {stop_t}")
                print("step:   " + " ".join(f"{t:5d}" for t in steps))
                print("ttt:    " + " ".join(f"{s_ttt[i,t]:5.2f}" for t in steps))
                print("static: " + " ".join(f"{s_static[i,t]:5.2f}" for t in steps))
        if len(rows) >= 5:
            break
    C.print_table("Fig 5: problems where online adaptation stops early but "
                  "the static probe never crosses", rows,
                  ["problem", "T", "breakthrough_step", "ttt_stop",
                   "ttt_savings", "static_savings"])
    C.save_rows("fig5_trajectory", rows)
    return rows


if __name__ == "__main__":
    run()
