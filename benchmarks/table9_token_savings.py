"""Table 9 — step-level vs token-level savings (delta=0.1, supervised).

Token counts per step are simulated with the paper's observed structure:
roughly uniform step lengths with a mild late-trajectory lengthening (their
Llama rows show later steps are longer, making token savings slightly
exceed step savings)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import stopping as S
from repro.core.pipeline import make_labels
from repro.core.probe import ProbeConfig


def _token_savings(tau, ts, growth: float, seed: int = 0) -> float:
    rs = np.random.RandomState(seed)
    out = []
    for i in range(len(ts)):
        T = ts.lengths[i]
        lens = 20 + 10 * rs.rand(T) + growth * np.arange(T)
        used = min(int(tau[i, 0]) + 1, T)
        out.append(1.0 - lens[:used].sum() / lens.sum())
    return float(np.mean(out))


def run() -> list:
    train, cal, test = C.corpus()
    mode = "supervised"
    rows = []
    lab_cal = make_labels(cal, mode)
    for name, scorer in [
        ("static", lambda ts: C.get_static(train, mode).scores(ts.phis, ts.mask)),
        ("ttt-noqk", lambda ts: C.get_probe(
            train, mode, ProbeConfig(d_phi=C.D_PHI)).scores(ts)),
    ]:
        s_cal, s_te = scorer(cal), scorer(test)
        ev = S.calibrate_and_evaluate(s_cal, lab_cal, cal.mask, s_te,
                                      make_labels(test, mode), test.mask,
                                      delta=0.1)
        if not np.isfinite(ev.lam):
            continue
        tau = S.stop_times(s_te, [ev.lam], test.mask)
        step_sav = S.savings(tau, test.mask)[0]
        for model, growth in [("uniform(qwen-like)", 0.0),
                              ("late-heavy(llama-like)", 0.4)]:
            tok_sav = _token_savings(tau, test, growth)
            rows.append({"method": name, "length_model": model,
                         "step_savings": float(step_sav),
                         "token_savings": tok_sav,
                         "delta_pp": tok_sav - float(step_sav)})
    C.print_table("Table 9: step vs token savings (paper: |delta| < .005 "
                  "uniform, +.01-.02 late-heavy)", rows,
                  ["method", "length_model", "step_savings", "token_savings",
                   "delta_pp"])
    C.save_rows("table9_token", rows)
    return rows


if __name__ == "__main__":
    run()
