"""Table 3 — zero-shot OOD generalization at delta=0.1 on five held-out
benchmarks (math500 / gpqa / aime'24/'25/'26 presets)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.probe import ProbeConfig

BENCHES = ("math500", "gpqa", "aime24", "aime25", "aime26")


def run() -> list:
    train, cal, _ = C.corpus()
    rows = []
    for mode in ("supervised", "consistent"):
        static = C.get_static(train, mode)
        noqk = C.get_probe(train, mode, ProbeConfig(d_phi=C.D_PHI))
        qk = C.get_probe(train, mode, ProbeConfig(d_phi=C.D_PHI, variant="qk",
                                                  d_h=min(128, C.D_PHI)))
        for bench in BENCHES:
            ts = C.ood(bench)
            for name, s_cal, s_te in [
                ("static", static.scores(cal.phis, cal.mask),
                 static.scores(ts.phis, ts.mask)),
                ("ttt-noqk", noqk.scores(cal), noqk.scores(ts)),
                ("ttt-qk128", qk.scores(cal), qk.scores(ts)),
            ]:
                for r in C.eval_rows(name, mode, s_cal, cal, s_te, ts,
                                     deltas=(0.1,)):
                    rows.append({"bench": bench, **r})
    C.print_table("Table 3: zero-shot OOD @ delta=0.1 (paper: MATH-500 TTT "
                  ".637-.670 vs static .248, supervised)", rows,
                  ["bench", "method", "mode", "savings", "error"])
    C.save_rows("table3_ood", rows)
    return rows


if __name__ == "__main__":
    run()
