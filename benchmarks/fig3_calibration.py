"""Figure 3 — calibration quality: actual test error vs target delta for
every method; the LTT guarantee requires the curve to track/undershoot the
diagonal."""
from __future__ import annotations


from benchmarks import common as C
from repro.core.probe import ProbeConfig

DELTAS = (0.02, 0.05, 0.08, 0.1, 0.15, 0.2, 0.25, 0.3)


def run() -> list:
    train, cal, test = C.corpus()
    rows = []
    static = C.get_static(train, "supervised")
    probe = C.get_probe(train, "supervised", ProbeConfig(d_phi=C.D_PHI))
    for name, s_cal, s_te in [
        ("static", static.scores(cal.phis, cal.mask),
         static.scores(test.phis, test.mask)),
        ("ttt-noqk", probe.scores(cal), probe.scores(test)),
    ]:
        for r in C.eval_rows(name, "supervised", s_cal, cal, s_te, test,
                             deltas=DELTAS):
            rows.append({**r, "within_budget": r["error"] <= r["delta"] + 0.03})
    C.print_table("Fig 3: risk-control diagonal (error <= delta expected "
                  "up to finite-sample noise)", rows,
                  ["method", "delta", "error", "savings", "within_budget"])
    C.save_rows("fig3_calibration", rows)
    viol = [r for r in rows if not r["within_budget"]]
    print(f"# diagonal violations (> delta + 0.03 slack): {len(viol)}/{len(rows)}")
    return rows


if __name__ == "__main__":
    run()
