"""Table 4 — cross-model generalization: the same probe configs trained and
evaluated independently on three embedding families ("models" differing in
d_phi and generator seed, standing in for Qwen2.5-32B / QwQ-32B /
Llama-3.3-70B whose checkpoints are unavailable offline — DESIGN.md §7)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.probe import ProbeConfig
from repro.trajectories import corpus_splits

MODELS = [
    ("qwen2.5-32b-like", C.D_PHI, 11),
    ("qwq-32b-like", C.D_PHI, 12),
    ("llama-3.3-70b-like", int(C.D_PHI * 1.6), 13),
]


def run() -> list:
    rows = []
    for name, d_phi, seed in MODELS:
        train, cal, test = corpus_splits(C.N_TRAIN, C.N_CAL, C.N_TEST,
                                         d_phi=d_phi, seed=seed)
        static = C.get_static(train, "supervised", tag=name)
        rows += [{"model": name, **r} for r in C.eval_rows(
            "static", "supervised", static.scores(cal.phis, cal.mask), cal,
            static.scores(test.phis, test.mask), test, deltas=(0.1,))]
        for pname, pc in [
            ("ttt-noqk", ProbeConfig(d_phi=d_phi)),
            ("ttt-qk128", ProbeConfig(d_phi=d_phi, variant="qk",
                                      d_h=min(128, d_phi))),
        ]:
            probe = C.get_probe(train, "supervised", pc, seed=seed, tag=name)
            rows += [{"model": name, **r} for r in C.eval_rows(
                pname, "supervised", probe.scores(cal), cal,
                probe.scores(test), test, deltas=(0.1,))]
    C.print_table("Table 4: cross-model @ delta=0.1 (paper: TTT no-QK beats "
                  "static on all three families)", rows,
                  ["model", "method", "savings", "error"])
    C.save_rows("table4_crossmodel", rows)
    return rows


if __name__ == "__main__":
    run()
