"""Figure 4 — distribution of per-problem savings at delta=0.1: mean/median
and mass at the extremes (paper: TTT shifts the whole distribution up;
improvement is broad, not outlier-driven)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import stopping as S
from repro.core.pipeline import make_labels
from repro.core.probe import ProbeConfig


def run() -> list:
    train, cal, test = C.corpus()
    mode = "supervised"
    lab_cal = make_labels(cal, mode)
    rows = []
    for name, scorer in [
        ("static", lambda ts: C.get_static(train, mode).scores(ts.phis, ts.mask)),
        ("ttt-noqk", lambda ts: C.get_probe(
            train, mode, ProbeConfig(d_phi=C.D_PHI)).scores(ts)),
    ]:
        s_cal, s_te = scorer(cal), scorer(test)
        ev = S.calibrate_and_evaluate(s_cal, lab_cal, cal.mask, s_te,
                                      make_labels(test, mode), test.mask,
                                      delta=0.1)
        if not np.isfinite(ev.lam):
            rows.append({"method": name, "mean": 0.0, "median": 0.0,
                         "frac_zero": 1.0, "frac_gt_half": 0.0})
            continue
        tau = S.stop_times(s_te, [ev.lam], test.mask)[:, 0]
        lens = test.lengths
        per = 1.0 - np.minimum(tau + 1, lens) / lens
        rows.append({"method": name, "mean": float(per.mean()),
                     "median": float(np.median(per)),
                     "frac_zero": float((per < 1e-9).mean()),
                     "frac_gt_half": float((per > 0.5).mean())})
    C.print_table("Fig 4: per-problem savings distribution @ delta=0.1 "
                  "(paper: TTT mean .475/median .444 vs static .377/.313)",
                  rows, ["method", "mean", "median", "frac_zero",
                         "frac_gt_half"])
    C.save_rows("fig4_distribution", rows)
    return rows


if __name__ == "__main__":
    run()
