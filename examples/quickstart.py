"""Quickstart: the whole ORCA pipeline in ~2 minutes on CPU, through the
``repro.api`` facade.

    PYTHONPATH=src python examples/quickstart.py

1. generate a synthetic reasoning-trajectory corpus (3:1:1 split),
2. ``orca.fit`` the TTT calibrator (Algorithm 1) + the static baseline,
3. ``orca.evaluate``: LTT-calibrate lambda* (Algorithm 2A) and report
   deployed savings/error (Algorithm 2B) in- and out-of-distribution.
"""
import numpy as np

from repro import api as orca
from repro.core.probe import ProbeConfig
from repro.trajectories import corpus_splits, ood_benchmark


def main():
    print("== ORCA quickstart ==")
    train, cal, test = corpus_splits(300, 100, 100, d_phi=96, seed=0)
    print(f"corpus: {len(train)} train / {len(cal)} cal / {len(test)} test "
          f"trajectories, d_phi={train.phis.shape[-1]}")

    calibrators = {
        "ttt": orca.fit(train, mode="supervised", method="ttt",
                        pc=ProbeConfig(d_phi=96), epochs=25),
        "static": orca.fit(train, mode="supervised", method="static"),
    }
    print("\nmethod   delta  savings  error   lambda*")
    for method, calib in calibrators.items():
        ev = orca.evaluate(calib, cal, test, deltas=(0.05, 0.1, 0.2))
        for r in ev.results:
            print(f"{method:8s} {r.delta:.2f}   {r.savings:.3f}    "
                  f"{r.error:.3f}   {r.lam:.3f}" if np.isfinite(r.lam) else
                  f"{method:8s} {r.delta:.2f}   {r.savings:.3f}    "
                  f"{r.error:.3f}   never-stop")

    ood = ood_benchmark("math500", 100, d_phi=96)
    e_t = orca.evaluate(calibrators["ttt"], cal, ood, deltas=(0.1,)).results[0]
    e_s = orca.evaluate(calibrators["static"], cal, ood,
                        deltas=(0.1,)).results[0]
    print(f"\nzero-shot OOD (math500-like) @ delta=0.1:")
    print(f"  ttt    savings {e_t.savings:.3f}  error {e_t.error:.3f}")
    print(f"  static savings {e_s.savings:.3f}  error {e_s.error:.3f}")
    print("\nExpected: ttt >= static savings with error <~ delta in-dist, "
          "and a larger gap OOD (the paper's headline result).")


if __name__ == "__main__":
    main()
