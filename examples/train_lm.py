"""Train a ~100M-parameter LM for a few hundred steps (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the framework's training driver (data pipeline -> jitted train step ->
Adam -> checkpointing) on a 12-layer llama-style config.  On a cluster the
same driver runs the full assigned configs under the production mesh
(see repro/launch/train.py --mesh).
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
import repro.launch.train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    # ~100M params: 12L x d512 x ffn2048, 16k vocab — built from the
    # smollm family config
    base = get_config("smollm-360m")
    cfg = dataclasses.replace(
        base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab_size=16384, dtype="float32")
    # register it under a temp name by monkey-patching get_config for the CLI
    import repro.launch.train as train_mod
    orig = train_mod.get_config
    train_mod.get_config = lambda name: cfg if name == "lm100m" else orig(name)
    try:
        rc = train_mod.main([
            "--arch", "lm100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "256", "--lr", "6e-4",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20",
        ])
    finally:
        train_mod.get_config = orig
    sys.exit(rc)


if __name__ == "__main__":
    main()
