"""OOD generalization demo: the paper's headline Table 3 effect.

    PYTHONPATH=src python examples/ood_generalization.py

Trains TTT + static calibrators once on the in-distribution corpus through
the ``repro.api`` facade, then applies both ZERO-SHOT to all five OOD
benchmarks at delta=0.1, showing the static probe's calibration break
(conservative on math500-like, premature on gpqa-like) while the TTT probe
adapts instance-wise.
"""
from repro import api as orca
from repro.core.probe import ProbeConfig
from repro.trajectories import corpus_splits, ood_benchmark

BENCHES = ("math500", "gpqa", "aime24", "aime25", "aime26")


def main():
    train, cal, test = corpus_splits(400, 150, 150, d_phi=128, seed=0)
    ttt = orca.fit(train, mode="supervised", method="ttt",
                   pc=ProbeConfig(d_phi=128), epochs=30)
    static = orca.fit(train, mode="supervised", method="static")
    r_t = orca.evaluate(ttt, cal, test, deltas=(0.1,)).results[0]
    r_s = orca.evaluate(static, cal, test, deltas=(0.1,)).results[0]
    print(f"in-dist @0.1: ttt {r_t.savings:.3f}/{r_t.error:.3f}  "
          f"static {r_s.savings:.3f}/{r_s.error:.3f}")
    print("\nbench     ttt.sav ttt.err   static.sav static.err")
    for b in BENCHES:
        ts = ood_benchmark(b, 150, d_phi=128)
        e_t = orca.evaluate(ttt, cal, ts, deltas=(0.1,)).results[0]
        e_s = orca.evaluate(static, cal, ts, deltas=(0.1,)).results[0]
        print(f"{b:9s} {e_t.savings:.3f}   {e_t.error:.3f}     "
              f"{e_s.savings:.3f}      {e_s.error:.3f}")


if __name__ == "__main__":
    main()
