"""End-to-end serving driver (deliverable b): a request queue served by the
continuous-batching ORCA scheduler on a real model from the zoo.

    PYTHONPATH=src python examples/serve_early_stop.py [--arch smollm-360m]

Pipeline: harvest calibration trajectories from the model itself
(consistency labels — no ground truth needed), ``orca.fit`` the TTT
calibrator, LTT-calibrate lambda*, then serve the queue through
``repro.api.engine`` — each ORCA stop evicts its slot, which is refilled
from the queue on the next step (plus the static-batch baseline for
comparison).
"""
import argparse

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()
    serve_driver.main([
        "--arch", args.arch, "--reduced",
        "--requests", "8", "--slots", str(args.slots), "--prompt-len", "16",
        "--max-new-tokens", "96", "--tokens-per-step", "8",
        "--train-trajectories", "24", "--delta", "0.25", "--epochs", "8",
        "--static-baseline",
    ])


if __name__ == "__main__":
    main()
