"""End-to-end serving driver (deliverable b): serve batched requests through
a real model from the zoo with ORCA risk-controlled early stopping.

    PYTHONPATH=src python examples/serve_early_stop.py [--arch smollm-360m]

Pipeline: harvest calibration trajectories from the model itself
(consistency labels — no ground truth needed), meta-train the probe,
LTT-calibrate lambda*, then serve new requests with the fused
decode+probe+stopping step (repro.serving.make_serve_step).
"""
import argparse

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()
    serve_driver.main([
        "--arch", args.arch, "--reduced",
        "--requests", "4", "--prompt-len", "16",
        "--max-new-tokens", "96", "--tokens-per-step", "8",
        "--train-trajectories", "24", "--delta", "0.25", "--epochs", "8",
    ])


if __name__ == "__main__":
    main()
