"""Packed multi-request chunks + the pluggable scheduling-policy layer.

The tentpole invariants: packed, unpacked (one request per chunk) and
legacy one-shot (admission-time) prefill produce IDENTICAL stop decisions
through ``OrcaScheduler`` under every policy; the packed kernel matches
its dense oracle (block-diagonal isolation included) for fp32/bf16/int8;
the token budget is never exceeded; pages are never double-owned; no
decode slot skips a step; the priority policy never starves the batch
class; and ONE step executable covers every prompt-length/packing shape.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.kernels import paged_flash_packed_chunk
from repro.kernels import ref as R
from repro.models import build
from repro.models.attention import (attn_prefill_packed, packed_chunk_mask,
                                    quantize_kv)
from repro.serving import (FIFOPolicy, OrcaScheduler, PriorityPolicy,
                           RequestState, ServeConfig,
                           TTFTAwarePolicy, make_policy, make_request,
                           replay_model, replay_params)
from repro.serving.policy import ComposeView

from tests._hypothesis_stub import given, settings, st


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias, smooth_window=2):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=smooth_window)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _mixed_prompts(mcfg, lens, seed=3):
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               mcfg.vocab_size)
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# kernel parity: packed chunk vs the dense oracle (block-diagonal isolation)

def _packed_case(key, c, h, kv, d, bs, p, nb, r, dtype=jnp.float32,
                 int8=False):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (c, h, d)).astype(dtype)
    kp = jax.random.normal(ks[1], (p, kv, bs, d))
    vp = jax.random.normal(ks[2], (p, kv, bs, d))
    tables = jax.random.randint(ks[3], (r, nb), 0, p)
    # segments laid out contiguously; last segment absorbs the tail
    bounds = np.linspace(0, c, r + 1).astype(int)
    seg = np.zeros((c,), np.int32)
    for s in range(r):
        seg[bounds[s]:bounds[s + 1]] = s
    # every segment mid-prefill at its own progress (>= 1: raw-partials
    # comparison stays off the all-masked garbage-flush corner)
    pos = jnp.asarray([((s + 1) * nb * bs) // (r + 1) + 1 for s in range(r)])
    seg_valid = jnp.arange(nb * bs)[None, :] < pos[:, None]
    if int8:
        kp, ksc = quantize_kv(kp)
        vp, vsc = quantize_kv(vp)
        return q, kp, vp, jnp.asarray(seg), tables, seg_valid, ksc, vsc
    return (q, kp.astype(dtype), vp.astype(dtype), jnp.asarray(seg), tables,
            seg_valid, None, None)


@pytest.mark.parametrize("c,h,kv,d,bs,p,nb,r", [
    (6, 8, 8, 64, 8, 16, 4, 2),    # MHA, tail+head pack
    (8, 8, 2, 64, 8, 16, 4, 3),    # GQA, three-way pack
    (5, 16, 4, 128, 16, 12, 3, 1), # single segment == unpacked chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_chunk_kernel_matches_ref(c, h, kv, d, bs, p, nb, r, dtype):
    """Per-token unnormalized partials of the packed kernel equal the
    gathered-pages oracle — each token sees exactly its own segment's
    pages and validity prefix, nothing of its chunk neighbours'."""
    args = _packed_case(jax.random.PRNGKey(17), c, h, kv, d, bs, p, nb, r,
                        dtype=dtype)
    o, l, m = paged_flash_packed_chunk(*args)
    o_r, l_r, m_r = R.paged_packed_chunk_ref(*args)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for a, ref in ((o, o_r), (l, l_r), (m, m_r)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


def test_packed_chunk_kernel_int8_kv():
    args = _packed_case(jax.random.PRNGKey(19), 6, 8, 4, 64, 8, 16, 4, 2,
                        int8=True)
    o, l, m = paged_flash_packed_chunk(*args)
    o_r, l_r, m_r = R.paged_packed_chunk_ref(*args)
    for a, ref in ((o, o_r), (l, l_r), (m, m_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_packed_attention_pallas_equals_jnp():
    """Full packed-chunk attention (kernel partials + block-diagonal merge)
    equals the jnp single-softmax path, empty-cache head segment
    included."""
    c, h, kv, d, bs, p, nb, r = 7, 8, 4, 64, 8, 16, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(23), 5)
    q = jax.random.normal(ks[0], (c, h, d))
    k_new = jax.random.normal(ks[1], (c, kv, d))
    v_new = jax.random.normal(ks[2], (c, kv, d))
    cache = {"k": jax.random.normal(ks[3], (p, kv, bs, d)),
             "v": jax.random.normal(ks[4], (p, kv, bs, d))}
    tables = jax.random.randint(ks[0], (r, nb), 0, p)
    seg = jnp.asarray([0, 0, 0, 1, 1, 1, 1], jnp.int32)
    starts = jnp.asarray([11, 0], jnp.int32)   # segment 1: fresh head
    valid_tok = jnp.ones((c,), bool)
    mask = packed_chunk_mask(seg, valid_tok)
    out_j = attn_prefill_packed(q, k_new, v_new, cache, seg, starts, mask,
                                jnp.float32, seg_tables=tables, impl="jnp")
    out_p = attn_prefill_packed(q, k_new, v_new, cache, seg, starts, mask,
                                jnp.float32, seg_tables=tables,
                                impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p),
                               rtol=2e-5, atol=2e-5)


def test_block_diagonal_no_cross_request_attention(small_model):
    """The cross-request isolation proof: a packed chunk carrying two
    requests writes bit-the-same K/V as prefilling each request alone
    (per-request one-shot prefill up to tolerance), so no token can have
    attended anything of its chunk neighbour."""
    model, params = small_model
    mcfg = model.cfg
    cache_len = 24
    la, lb = 5, 7
    toks = _mixed_prompts(mcfg, [la, lb], seed=29)
    # packed: both prompts in ONE chunk into a 2-slot dense state
    state = model.init_decode_state(2, cache_len)
    c = la + lb
    chunk_toks = jnp.concatenate(toks)
    seg = jnp.asarray([0] * la + [1] * lb, jnp.int32)
    state = model.prefill_packed(mcfg, params, chunk_toks, state,
                                 seg, jnp.asarray([0, 1], jnp.int32),
                                 jnp.asarray([0, 0], jnp.int32),
                                 jnp.asarray([la, lb], jnp.int32))
    for i, (t, n) in enumerate(zip(toks, (la, lb))):
        solo, _, _ = model.prefill(mcfg, params, {"tokens": t[None]},
                                   cache_len)
        for key in solo:
            np.testing.assert_allclose(
                np.asarray(state[key][:, i, :, :n]).astype(np.float32),
                np.asarray(solo[key][:, 0, :, :n]).astype(np.float32),
                rtol=2e-5, atol=2e-5, err_msg=f"slot {i} {key}")
    # nothing written beyond each prompt
    assert np.abs(np.asarray(state["k"][:, 0, :, la:]).astype(
        np.float32)).max(initial=0.0) == 0.0


# ---------------------------------------------------------------------------
# policy unit tests

def test_priority_policy_never_starves_batch_head():
    """Under a sustained latency-class stream, the batch-class queue head
    is admitted after at most max_head_skips ACTUAL queue-jumps."""
    pol = PriorityPolicy(max_head_skips=3)
    batch_head = make_request(np.arange(4), priority=1)
    picks = []
    for step in range(5):
        waiting = [batch_head] + [make_request(np.arange(4), priority=0)
                                  for _ in range(3)]
        idx = pol.select_admit(waiting, step)
        pol.on_admitted(waiting, idx)        # admission succeeded
        picks.append(idx)
    assert picks[:3] != [0, 0, 0]            # latency class went first
    assert 0 in picks[:4], picks             # ...but the head was not starved


def test_priority_aging_ignores_failed_admissions():
    """A selection whose reservation fails (pool full: the scheduler
    breaks without admitting) must NOT age the head — only actual
    queue-jumps count, so priority is never inverted just because the
    pool was contended for a while."""
    pol = PriorityPolicy(max_head_skips=2)
    batch_head = make_request(np.arange(4), priority=1)
    latency = [make_request(np.arange(4), priority=0) for _ in range(3)]
    waiting = [batch_head] + latency
    # many pool-full iterations: select never followed by on_admitted
    for step in range(10):
        assert pol.select_admit(waiting, step) != 0
    # the head was never actually overtaken, so its clock is untouched:
    # it still takes max_head_skips real jumps before force-admission
    for step in range(2):
        idx = pol.select_admit(waiting, step)
        assert idx != 0
        pol.on_admitted(waiting, idx)
    assert pol.select_admit(waiting, 99) == 0


def test_probe_aware_chunk_sizing_shrinks_share():
    """The probe-aware knob: a boundary-heavy step halves the prefill
    share; far-from-boundary steps keep the greedy share."""
    view = lambda near: ComposeView(n_running=4, n_slots=4, n_prefilling=1,
                                    n_waiting=2, token_budget=12,
                                    chunk_tokens=8, near_boundary=near)
    assert FIFOPolicy().prefill_share(view(4)) == 8
    pol = FIFOPolicy(probe_margin=1)
    assert pol.prefill_share(view(0)) == 8
    assert pol.prefill_share(view(1)) == 8   # under half the residents
    assert pol.prefill_share(view(2)) == 4   # half the fleet near boundary
    assert pol.prefill_share(view(4)) == 4


def test_ttft_policy_widens_share_when_idle():
    """Only REACHABLE views: running and mid-prefill residents partition
    the fleet, and the share is only consulted while n_prefilling >= 1."""
    pol = TTFTAwarePolicy(busy_share=2)
    idle = ComposeView(n_running=1, n_slots=4, n_prefilling=1, n_waiting=0,
                       token_budget=12, chunk_tokens=8, near_boundary=0)
    busy = dataclasses.replace(idle, n_running=3)   # 3 decode + 1 prefill
    assert pol.prefill_share(idle) == 8      # free slots: full chunk
    assert pol.prefill_share(busy) == 2      # no free slot: throttled
    # default throttle: half a chunk
    assert TTFTAwarePolicy().prefill_share(busy) == 4
    assert make_policy("ttft").name == "ttft"
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("nope")


# ---------------------------------------------------------------------------
# scheduler satellites: validated errors + the disabled-chunking warning

def test_token_budget_below_slots_raises(small_model):
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6, burn_in=1)
    with pytest.raises(ValueError, match="raising token_budget"):
        OrcaScheduler(model, params, pc, theta, cfg, n_slots=4,
                      chunk_tokens=4, token_budget=3)


def test_chunk_tokens_warns_on_unchunkable_family():
    """A family without chunked prefill must WARN, not silently disable."""
    cfg = get_config("rwkv6_1b6").reduced()
    model = build(cfg)
    assert not model.supports_chunked
    pc = ProbeConfig(d_phi=cfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                       burn_in=1)
    with pytest.warns(RuntimeWarning, match="admission-time"):
        sched = OrcaScheduler(model, None, pc, theta, scfg, n_slots=2,
                              chunk_tokens=4)
    assert sched.chunk_tokens is None


# ---------------------------------------------------------------------------
# end-to-end: packed == unpacked == one-shot stops under every policy

def _run(model, params, pc, theta, cfg, prompts, priorities=None, **kw):
    sched = OrcaScheduler(model, params, pc, theta, cfg, **kw)
    reqs = [make_request(p, priority=(priorities[i] if priorities else 0))
            for i, p in enumerate(prompts)]
    return sched.run(reqs) + (sched,)


@pytest.mark.parametrize("policy", ["fifo", "priority", "ttft"])
def test_packed_unpacked_oneshot_stops_identical(small_model, policy):
    model, params = small_model
    pc, theta = _probe(model.cfg, 1.5)    # borderline: mixed stop outcomes
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=14, lam=0.6,
                      burn_in=1)
    prompts = _mixed_prompts(model.cfg, [5, 9, 3, 12, 7, 4, 10], seed=31)
    prios = [i % 2 for i in range(len(prompts))]
    done_o, _, _ = _run(model, params, pc, theta, cfg, prompts, prios,
                        n_slots=2)
    done_p, fleet_p, _ = _run(model, params, pc, theta, cfg, prompts, prios,
                              n_slots=2, chunk_tokens=6, policy=policy,
                              pack_chunks=True)
    done_u, fleet_u, _ = _run(model, params, pc, theta, cfg, prompts, prios,
                              n_slots=2, chunk_tokens=6, policy=policy,
                              pack_chunks=False)
    for done in (done_p, done_u):
        assert [r.stop_step for r in done] == [r.stop_step for r in done_o]
        assert [r.steps_run for r in done] == [r.steps_run for r in done_o]
        for ra, rb in zip(done_o, done):
            np.testing.assert_allclose(np.array(ra.scores),
                                       np.array(rb.scores), atol=1e-4)
    assert fleet_p.packed_chunks > 0 and fleet_u.packed_chunks == 0
    assert fleet_p.peak_step_tokens <= 2 + 6
    # per-class latency surfaced for both classes
    assert "c0_ttft_ms_p50" in fleet_p.per_class
    assert "c1_queue_wait_ms_p99" in fleet_p.per_class


def test_packed_paged_int8_stops_identical():
    """Packed chunks through the paged int8 pool (kernel write path +
    prefix-reservation machinery) keep one-shot stop decisions."""
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pc, theta = _probe(cfg, 3.0)
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=10, lam=0.6,
                       burn_in=1)
    prompts = _mixed_prompts(cfg, [6, 9, 3, 11, 5], seed=37)
    done_o, _, base = _run(model, params, pc, theta, scfg, prompts,
                           n_slots=2, paged=True, block_size=4)
    done_p, fleet_p, sched = _run(model, params, pc, theta, scfg, prompts,
                                  n_slots=2, paged=True, block_size=4,
                                  chunk_tokens=5, pack_chunks=True)
    assert [r.stop_step for r in done_p] == [r.stop_step for r in done_o]
    assert [r.state for r in done_p] == [r.state for r in done_o]
    assert fleet_p.packed_chunks > 0
    assert sched.pool.blocks_in_use == 0 and base.pool.blocks_in_use == 0


def test_one_step_executable_across_lengths_and_packing(small_model):
    """ONE compiled step executable across >= 5 prompt-length variants AND
    both packing modes (packing is composer data, not executable shape)."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                      burn_in=1)
    lens = [3, 7, 11, 15, 19, 5]
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2,
                          chunk_tokens=4, pack_chunks=True)
    sched.run([make_request(p) for p in _mixed_prompts(model.cfg, lens)])
    counts = sched._engine.compile_counts()
    assert counts["step"] == 1 and counts["admission_prefill"] == 0, counts
    # flip the composer to unpacked on the SAME scheduler: same executable
    sched.pack_chunks = False
    sched.run([make_request(p)
               for p in _mixed_prompts(model.cfg, lens, seed=41)])
    assert sched._engine.compile_counts() == counts


# ---------------------------------------------------------------------------
# hypothesis sweep: budget x chunk x prompt lengths x policy

def _replay_setup(seed=0, n=10, t=16, d=16):
    rs = np.random.RandomState(seed)
    bank = (rs.randn(n, t, d) * 0.6).astype(np.float32)
    model, params = replay_model(bank, prompt_len=4), replay_params(bank)
    pc = ProbeConfig(d_phi=d, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(2))
    theta["b0"] = jnp.asarray(0.4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=2)
    return model, params, pc, theta, cfg, bank


def _sweep_case(budget, chunk, lens, policy):
    """Composer invariants under arbitrary (budget, chunk, queue, policy):
    the token budget is NEVER exceeded, no decode step is skipped, pages
    are never double-owned, the batch class is never starved (every
    request completes), and stop decisions equal the unchunked oracle
    bit-for-bit (replay trajectories are exact)."""
    model, params, pc, theta, cfg, bank = _replay_setup()
    n_slots = 3
    budget = max(budget, n_slots)

    def reqs(ids):
        return [make_request(np.full((4,), i, np.int64),
                             max_new_tokens=int(bank.shape[1]),
                             priority=j % 2)
                for j, i in enumerate(ids)]

    ids = [L % bank.shape[0] for L in lens]
    oracle = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                           paged=True, block_size=4)
    done_o, _ = oracle.run(reqs(ids))
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                          paged=True, block_size=4, chunk_tokens=chunk,
                          token_budget=budget, policy=policy,
                          pack_chunks=True)
    done_c, fleet = sched.run(reqs(ids))
    for r in done_c:
        assert r.state in (RequestState.STOPPED, RequestState.FINISHED)
        # one token per engine step, first token to completion: no decode
        # slot skipped a step while prefill work was pending
        assert len(r.tokens) == r.completed_step - r.first_token_step + 1
        assert r.first_token_step > r.admitted_step >= 0
    # stop decisions identical to the unchunked oracle — run() returns
    # submission order, so the comparison is positional even when priority
    # admission reorders service
    assert [r.stop_step for r in done_c] == [r.stop_step for r in done_o]
    assert fleet.peak_step_tokens <= budget
    # overlapping residents never co-own a private page — a preempted
    # request frees its pages while SWAPPED and block_ids records the
    # post-restore allocation, so its ownership span starts at
    # restored_step (step-level double ownership is owned by
    # tests/test_preemption.py + pool.check)
    spans = [((r.restored_step if r.n_preempted else r.admitted_step),
              r.completed_step, set(r.block_ids),
              r.n_shared_blocks) for r in done_c]
    for i in range(len(spans)):
        for j in range(i + 1, len(spans)):
            a0, a1, ba, sa = spans[i]
            b0, b1, bb, sb = spans[j]
            if a0 < b1 and b0 < a1 and not (sa or sb):
                assert not (ba & bb), (i, j, ba & bb)
    sched.pool.check()
    assert sched.pool.blocks_in_use == 0


@pytest.mark.parametrize("budget,chunk,lens,policy", [
    (3, 1, [1, 2, 3], "fifo"),            # budget == n_slots: 1-token shares
    (12, 8, [9, 1, 5, 7], "priority"),    # roomy budget, reordered admission
    (5, 3, [4, 4, 4, 4, 4], "ttft"),      # tight budget, throttled share
    (7, 2, [8, 3, 9, 1, 6, 2], "priority"),
    (6, 5, [7, 7, 1, 3], "ttft"),
])
def test_packed_sweep_explicit_cases(budget, chunk, lens, policy):
    """Pinned corners of the sweep space — runs even without the optional
    ``hypothesis`` dependency (the property test below skips there)."""
    _sweep_case(budget, chunk, lens, policy)


@settings(max_examples=12, deadline=None)
@given(budget=st.integers(3, 12), chunk=st.integers(1, 8),
       lens=st.lists(st.integers(1, 9), min_size=3, max_size=7),
       policy=st.sampled_from(["fifo", "priority", "ttft"]))
def test_packed_sweep_invariants(budget, chunk, lens, policy):
    _sweep_case(budget, chunk, lens, policy)
