"""Tests for the TTT probe: inner-loop math, outer meta-training, variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import probe as P
from repro.core import ttt
from repro.core.probe import ProbeConfig
from repro.optim import Adam
from repro.trajectories import corpus_splits


def _phis(rng, t=12, d=8):
    return jax.random.normal(rng, (t, d), jnp.float32)


def test_score_then_update_protocol():
    """s_t must use W_{t-1} (scores computed BEFORE the step's update)."""
    pc = ProbeConfig(d_phi=4, eta=0.5)
    theta = P.init_outer(pc, jax.random.PRNGKey(0))
    phis = _phis(jax.random.PRNGKey(1), t=3, d=4)
    out = ttt.inner_unroll(pc, theta, phis)
    # manual step 1: score with W0
    z = np.asarray(phis, np.float64)
    W = np.asarray(theta["W0"], np.float64)
    b = float(theta["b0"])
    s0 = 1 / (1 + np.exp(-(z[0] @ W + b)))
    assert float(out.scores[0]) == pytest.approx(s0, rel=1e-5)
    # manual inner update with C=0 then score step 2
    coeff = 2 * s0 * s0 * (1 - s0)
    W1 = W - 0.5 * coeff * z[0]
    b1 = b - 0.5 * coeff
    s1 = 1 / (1 + np.exp(-(z[1] @ W1 + b1)))
    assert float(out.scores[1]) == pytest.approx(s1, rel=1e-5)


def test_inner_update_matches_autodiff():
    """The analytic Brier gradient equals jax.grad of the loss."""
    pc = ProbeConfig(d_phi=6)
    theta = P.init_outer(pc, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (6,))
    c = 1.0
    fast = (theta["W0"], theta["b0"])
    gW, gb = P.brier_grad(fast, z, c)

    def loss(fast):
        return jnp.square(P.score(fast, z) - c)

    aW, ab = jax.grad(loss)(fast)
    np.testing.assert_allclose(np.asarray(gW), np.asarray(aW), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(ab), rtol=1e-5)


def test_mask_freezes_updates_and_eta_zero_is_static():
    pc0 = ProbeConfig(d_phi=4, eta=0.0)
    theta = P.init_outer(pc0, jax.random.PRNGKey(0))
    phis = _phis(jax.random.PRNGKey(2), t=8, d=4)
    out = ttt.inner_unroll(pc0, theta, phis)
    # eta=0: all weights stay at W0 -> scores are the static probe's
    zq, _ = P.features(pc0, theta, phis)
    s_static = P.score((theta["W0"], theta["b0"]), zq)
    np.testing.assert_allclose(np.asarray(out.scores), np.asarray(s_static),
                               rtol=1e-6)
    # masked-out steps do not change the weights
    pc = ProbeConfig(d_phi=4, eta=0.3)
    m0 = jnp.zeros((8,))
    out_frozen = ttt.inner_unroll(pc, theta, phis, mask=m0)
    np.testing.assert_allclose(np.asarray(out_frozen.fast_final[0]),
                               np.asarray(theta["W0"]), rtol=1e-6)


@pytest.mark.parametrize("variant_kwargs", [
    dict(variant="noqk"),
    dict(variant="qk", d_h=16),
    dict(variant="qk", d_h=16, layernorm=True),
    dict(variant="qk", d_h=16, layernorm=True, residual=True),
    dict(variant="qk", d_h=16, shared_qk=True),
    dict(variant="qk", d_h=16, mlp=True),
    dict(variant="qk", d_h=16, learnable_eta=True),
])
def test_all_variants_train_and_score(variant_kwargs):
    pc = ProbeConfig(d_phi=12, **variant_kwargs)
    theta = P.init_outer(pc, jax.random.PRNGKey(0))
    phis = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 12))
    labels = (jax.random.uniform(jax.random.PRNGKey(2), (4, 10)) > 0.5).astype(jnp.float32)
    mask = jnp.ones((4, 10))
    loss, grads = jax.value_and_grad(
        lambda th: ttt.outer_loss(pc, th, phis, labels, mask))(theta)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    s = ttt.deployed_scores(pc, theta, phis, mask)
    assert s.shape == (4, 10)
    assert ((np.asarray(s) >= 0) & (np.asarray(s) <= 1)).all()


def test_outer_training_reduces_loss():
    train, _, _ = corpus_splits(80, 10, 10, d_phi=32, seed=0)
    pc = ProbeConfig(d_phi=32, eta=0.01)
    theta = P.init_outer(pc, jax.random.PRNGKey(0))
    opt = Adam(lr=1e-2, clip_norm=1.0)
    from repro.core.labels import supervised_labels
    labels = supervised_labels(train.correct, train.mask)
    theta2, hist = ttt.meta_train(
        pc, theta, opt, jnp.asarray(train.phis), jnp.asarray(labels),
        jnp.asarray(train.mask), epochs=5, batch_size=40,
        rng=jax.random.PRNGKey(1))
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_smoothing_window():
    s = jnp.asarray([[1.0, 0.0, 1.0, 0.0, 1.0]])
    sm = P.smooth_scores(s, 2)
    np.testing.assert_allclose(np.asarray(sm)[0], [1.0, 0.5, 0.5, 0.5, 0.5])
    sm1 = P.smooth_scores(s, 1)
    np.testing.assert_allclose(np.asarray(sm1), np.asarray(s))


@given(st.integers(1, 12))
@settings(max_examples=10, deadline=None)
def test_smoothing_is_causal(w):
    rs = np.random.RandomState(0)
    a = rs.rand(1, 20).astype(np.float32)
    b = a.copy()
    b[0, 15:] = 9.0  # future change
    sa = np.asarray(P.smooth_scores(jnp.asarray(a), w))
    sb = np.asarray(P.smooth_scores(jnp.asarray(b), w))
    np.testing.assert_allclose(sa[0, :15], sb[0, :15], rtol=1e-6)


def test_bptt_truncation_still_trains():
    pc = ProbeConfig(d_phi=8, bptt_truncation=4)
    theta = P.init_outer(pc, jax.random.PRNGKey(0))
    phis = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    labels = jnp.concatenate([jnp.zeros((2, 8)), jnp.ones((2, 8))], axis=1)
    loss, grads = jax.value_and_grad(
        lambda th: ttt.outer_loss(pc, th, phis, labels))(theta)
    assert np.isfinite(float(loss))
    assert float(jnp.sum(jnp.abs(grads["W0"]))) > 0
