"""Chunked prefill (the unified token-budget step): stop-decision parity
with admission-time prefill across dense/paged/prefix-shared serving, the
shared prefill helper vs ``model.prefill``, legacy-shim regressions, the
bounded-compile-cache guarantee, and a hypothesis sweep over the batch
composer's (token budget, chunk size, prompt lengths) space."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.serving import (ContinuousServingEngine, OrcaScheduler,
                           RequestState, ServeConfig, ServingEngine,
                           ChunkWork, chunk_supported, chunked_prefill,
                           extract_trajectories, init_probe_state,
                           make_request, replay_model, replay_params)

from tests._hypothesis_stub import given, settings, st

# the deprecated shims (ServingEngine.serve / run_orca) are exercised here
# ON PURPOSE as equality baselines — silence their DeprecationWarning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")



@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias, smooth_window=2):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=smooth_window)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _mixed_prompts(mcfg, lens, seed=3):
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               mcfg.vocab_size)
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# the shared prefill helper == model.prefill

@pytest.mark.parametrize("chunk", [4, 5, 11, 64])
def test_chunked_prefill_cache_matches_full_prefill(small_model, chunk):
    model, params = small_model
    mcfg = model.cfg
    B, S, cache_len = 2, 11, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              mcfg.vocab_size)
    full, _, _ = model.prefill(mcfg, params, {"tokens": toks}, cache_len)
    state = chunked_prefill(model, params, {"tokens": toks}, cache_len,
                            chunk_tokens=chunk)
    for key in full:
        np.testing.assert_allclose(
            np.asarray(full[key][:, :, :, :S]).astype(np.float32),
            np.asarray(state[key][:, :, :, :S]).astype(np.float32),
            rtol=2e-5, atol=2e-5, err_msg=key)
    # padding beyond the prompt is DROPPED, not written
    assert np.abs(np.asarray(state["k"][:, :, :, S:]).astype(
        np.float32)).max(initial=0.0) == 0.0


def test_chunked_prefill_int8_serves_same_stops():
    """int8 chunked prefill reads QUANTIZED prefix K/V where the one-shot
    prefill read exact activations, so caches drift beyond quantization
    noise — but served stop decisions (the procedure's contract) must
    agree with admission-time prefill end-to-end."""
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pc, theta = _probe(cfg, 3.0)           # decisive scores, robust stops
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=12, lam=0.6,
                       burn_in=1)
    prompts = _mixed_prompts(cfg, [8, 13, 6, 10], seed=17)
    (done_b, _, _), (done_c, fleet_c, _) = _run_pair(
        model, params, pc, theta, scfg, prompts, chunk=4)
    assert [r.stop_step for r in done_b] == [r.stop_step for r in done_c]
    assert [r.state for r in done_b] == [r.state for r in done_c]
    assert fleet_c.prefill_chunks > 0


def test_chunk_supported_gates_hidden_prefixes(small_model):
    model, _ = small_model
    assert chunk_supported(model, {"tokens": jnp.zeros((1, 4), jnp.int32)})
    # multimodal prompts keep the one-shot prefill path
    assert not chunk_supported(model, {"tokens": jnp.zeros((1, 4), jnp.int32),
                                       "patch_embeds": jnp.zeros((1, 2, 8))})
    vlm = build(get_config("llava_next_34b").reduced())
    assert not chunk_supported(
        vlm, {"tokens": jnp.zeros((1, 4), jnp.int32),
              "patch_embeds": jnp.zeros((1, 2, 8))})


# ---------------------------------------------------------------------------
# legacy shims route through the helper and stay equal

def test_static_serve_shim_chunked_equals_legacy(small_model):
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6,
                      burn_in=1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (3, 10), 0,
                                          model.cfg.vocab_size)}
    legacy = ServingEngine(model, params, pc, theta, cfg).serve(
        batch, prompt_len=10)
    chunked = ServingEngine(model, params, pc, theta, cfg,
                            chunk_tokens=4).serve(batch, prompt_len=10)
    assert legacy.stop_step.tolist() == chunked.stop_step.tolist()
    assert legacy.steps_run.tolist() == chunked.steps_run.tolist()
    np.testing.assert_allclose(legacy.scores, chunked.scores, atol=1e-4)
    np.testing.assert_array_equal(legacy.tokens, chunked.tokens)


def test_extract_trajectories_chunked_equals_legacy(small_model):
    model, params = small_model
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0,
                                          model.cfg.vocab_size)}
    phis_a, toks_a = extract_trajectories(model, params, batch, 9,
                                          max_new_tokens=12,
                                          tokens_per_step=3)
    phis_b, toks_b = extract_trajectories(model, params, batch, 9,
                                          max_new_tokens=12,
                                          tokens_per_step=3, chunk_tokens=4)
    np.testing.assert_array_equal(toks_a, toks_b)
    np.testing.assert_allclose(phis_a, phis_b, atol=1e-4)


# ---------------------------------------------------------------------------
# scheduler: chunked == unchunked oracle (the tentpole invariant)

def _run_pair(model, params, pc, theta, cfg, prompts, *, n_slots=2,
              chunk=5, **kw):
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                         **kw)
    done_b, fleet_b = base.run([make_request(p) for p in prompts])
    ch = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                       chunk_tokens=chunk, **kw)
    done_c, fleet_c = ch.run([make_request(p) for p in prompts])
    return (done_b, fleet_b, base), (done_c, fleet_c, ch)


def _assert_equal_service(done_b, done_c):
    assert [r.stop_step for r in done_b] == [r.stop_step for r in done_c]
    assert [r.steps_run for r in done_b] == [r.steps_run for r in done_c]
    assert [r.state for r in done_b] == [r.state for r in done_c]
    for rb, rc in zip(done_b, done_c):
        np.testing.assert_allclose(np.array(rb.scores), np.array(rc.scores),
                                   atol=1e-4)


def test_scheduler_chunked_matches_unchunked_dense(small_model):
    """Mixed prompt lengths with chunk < prompt: admissions overlap live
    decode (mid-prefill residents), stop decisions must not move."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 1.5)    # borderline: mixed stop outcomes
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6,
                      burn_in=1)
    prompts = _mixed_prompts(model.cfg, [8, 13, 6, 17, 10, 8])
    (done_b, _, _), (done_c, fleet_c, ch) = _run_pair(
        model, params, pc, theta, cfg, prompts)
    _assert_equal_service(done_b, done_c)
    # every prompt token was scheduled as chunk work, none at admission
    assert fleet_c.prefill_chunks >= (8 + 13 + 6 + 17 + 10 + 8) // 5
    assert fleet_c.ttft_ms_p99 >= fleet_c.ttft_ms_p50 > 0.0
    assert fleet_c.stall_ms_p99 >= fleet_c.stall_ms_p50 > 0.0


def test_scheduler_chunked_matches_unchunked_paged(small_model):
    """Paged serving with a pool small enough to force WAITING backpressure
    keeps byte-identical stop decisions under chunked prefill."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 1.5)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=12, lam=0.6,
                      burn_in=1)
    prompts = _mixed_prompts(model.cfg, [8, 13, 6, 11, 9], seed=11)
    (done_b, _, base), (done_c, fleet_c, ch) = _run_pair(
        model, params, pc, theta, cfg, prompts, chunk=4, paged=True,
        block_size=4)
    _assert_equal_service(done_b, done_c)
    assert fleet_c.prefill_chunks > 0
    # every page returned to the pool
    assert ch.pool.blocks_in_use == 0 and base.pool.blocks_in_use == 0


def test_prefix_sharing_composes_with_chunked_prefill(small_model):
    """Self-consistency samples of a prompt whose donor prefilled in chunks
    still share its pages: the donor registers only once its LAST chunk
    lands, sharers skip prefill entirely, refcounts drain to zero."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 1.5)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                      burn_in=1)
    prompt = jax.random.randint(jax.random.PRNGKey(6), (12,), 0,
                                model.cfg.vocab_size)
    reqs = lambda: [make_request(prompt) for _ in range(4)]
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2,
                         paged=True, block_size=4)
    done_b, fleet_b = base.run(reqs())
    ch = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2,
                       paged=True, block_size=4, chunk_tokens=5)
    done_c, fleet_c = ch.run(reqs())
    _assert_equal_service(done_b, done_c)
    assert fleet_c.prefill_skips == fleet_b.prefill_skips > 0
    assert ch.pool.blocks_in_use == 0


def test_mid_prefill_slot_never_touches_probe_state(small_model):
    """While a slot prefills in chunks, its probe row must stay EXACTLY the
    parked fresh row — the boundary gate keeps the probe kernel off it —
    and a neighboring decode slot must advance normally."""
    model, params = small_model
    mcfg = model.cfg
    pc, theta = _probe(mcfg, 3.0)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=16, lam=0.9,
                      burn_in=8)
    eng = ContinuousServingEngine(model, params, pc, theta, cfg, n_slots=2,
                                  cache_len=40, chunk_tokens=4)
    prompts = _mixed_prompts(mcfg, [6, 16], seed=7)
    eng.admit(0, {"tokens": prompts[0][None]}, 6)       # decoding neighbor
    eng.begin_prefill(1)
    # score-relevant probe state must stay the parked fresh row (pooling
    # accumulators hid_sum/tok_count free-run on parked rows and are zeroed
    # when the probe is armed — same contract as released slots)
    fields = ("W", "b", "ring", "n_scores", "smoothed", "stopped",
              "stop_step")
    fresh = init_probe_state(pc, theta, 1, mcfg.d_model)
    parked = {f: np.asarray(getattr(fresh, f)[0]) for f in fields}
    parked["stopped"] = np.asarray(True)
    toks = np.asarray(prompts[1])
    n_before = int(np.asarray(eng.st.n_scores[0]))
    for start in range(0, 16, 4):
        eng.step(ChunkWork.single(slot=1, tokens=toks, start=start, length=4))
        row = {f: np.asarray(getattr(eng.st, f)[1]) for f in fields}
        for f, v in parked.items():
            np.testing.assert_array_equal(row[f], v, err_msg=f)
    # the neighbor decoded through all 4 chunk steps (no skipped steps)
    assert int(np.asarray(eng.st.n_scores[0])) == n_before + 4
    eng.finish_prefill(1, {"tokens": prompts[1][None]}, 16)
    assert eng.pos[1] == 16 and not bool(np.asarray(eng.st.stopped[1]))


# ---------------------------------------------------------------------------
# bounded compile cache: ONE step executable across prompt lengths

def test_compile_cache_bounded_across_prompt_lengths(small_model):
    """The satellite fix: with chunked prefill the engine compiles exactly
    one step executable however many distinct prompt lengths arrive; the
    legacy admission path compiles a fresh prefill per length."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                      burn_in=1)
    lens = [5, 9, 13, 17, 21]               # >= 4 distinct lengths
    prompts = _mixed_prompts(model.cfg, lens, seed=9)

    ch = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2,
                       chunk_tokens=4)
    ch.run([make_request(p) for p in prompts])
    counts = ch._engine.compile_counts()
    assert counts["step"] == 1, counts
    assert counts["admission_prefill"] == 0, counts
    # a second mixed-length wave must not add executables
    ch.run([make_request(p) for p in _mixed_prompts(model.cfg, lens,
                                                    seed=21)])
    assert ch._engine.compile_counts() == counts

    legacy = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    legacy.run([make_request(p) for p in prompts])
    lcounts = legacy._engine.compile_counts()
    assert lcounts["admission_prefill"] >= len(lens) - 1, lcounts


# ---------------------------------------------------------------------------
# batch composer sweep: (token budget, chunk size, prompt lengths)

def _replay_setup(seed=0, n=10, t=16, d=16):
    rs = np.random.RandomState(seed)
    bank = (rs.randn(n, t, d) * 0.6).astype(np.float32)
    model, params = replay_model(bank, prompt_len=4), replay_params(bank)
    pc = ProbeConfig(d_phi=d, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(2))
    theta["b0"] = jnp.asarray(0.4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=2)
    return model, params, pc, theta, cfg, bank


@settings(max_examples=15, deadline=None)
@given(budget=st.integers(2, 12), chunk=st.integers(1, 8),
       lens=st.lists(st.integers(1, 9), min_size=3, max_size=7))
def test_composer_sweep_decode_never_starves(budget, chunk, lens):
    """Composer invariants under arbitrary (token budget, chunk size,
    prompt lengths): decode slots never skip a step while prefill work is
    pending (every RUNNING request gains exactly one token per engine
    step), pool pages are never double-owned, and stop decisions equal the
    unchunked oracle bit-for-bit (replay trajectories are exact)."""
    model, params, pc, theta, cfg, bank = _replay_setup()
    n_slots = 3
    budget = max(budget, n_slots)     # composer contract: decode first

    def reqs(prompt_lens):
        out = []
        for i, L in enumerate(prompt_lens):
            toks = np.full((4,), i, np.int64)     # prompt_len=4, traj id i
            r = make_request(toks, max_new_tokens=int(bank.shape[1]))
            out.append(r)
        return out

    # the replay prompt length is fixed (4) but the COMPOSER sees varying
    # effective prefill work via the chunk/budget interplay; vary lens by
    # mapping them onto trajectory ids so queue composition still varies
    ids = [L % bank.shape[0] for L in lens]
    oracle = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                           paged=True, block_size=4)
    done_o, _ = oracle.run(reqs(ids))
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                          paged=True, block_size=4, chunk_tokens=chunk,
                          token_budget=budget)
    done_c, fleet = sched.run(reqs(ids))
    assert [r.stop_step for r in done_o] == [r.stop_step for r in done_c]
    for r in done_c:
        assert r.state in (RequestState.STOPPED, RequestState.FINISHED)
        # one token per engine step from first token to completion: the
        # decode slot never skipped a step while prefill was pending
        assert len(r.tokens) == r.completed_step - r.first_token_step + 1
        assert r.first_token_step > r.admitted_step >= 0
    # overlapping residents never co-own a private page
    live_spans = [(r.admitted_step, r.completed_step, set(r.block_ids),
                   r.n_shared_blocks) for r in done_c]
    for i in range(len(live_spans)):
        for j in range(i + 1, len(live_spans)):
            a0, a1, ba, sa = live_spans[i]
            b0, b1, bb, sb = live_spans[j]
            if a0 < b1 and b0 < a1 and not (sa or sb):
                assert not (ba & bb), (i, j, ba & bb)
    sched.pool.check()
    assert sched.pool.blocks_in_use == 0


def test_composer_respects_token_budget(small_model):
    """With a budget leaving room for less than a full chunk, the composer
    shrinks the chunk instead of starving decode (decode slots first)."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                      burn_in=1)
    prompts = _mixed_prompts(model.cfg, [12, 12, 12], seed=13)
    # budget 3 with 2 slots: at most ONE prefill token rides a step when
    # both slots decode, so a 12-token prompt needs >= 12 chunk launches
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2,
                          chunk_tokens=8, token_budget=3)
    done, fleet = sched.run([make_request(p) for p in prompts])
    assert all(r.done for r in done)
    assert fleet.prefill_chunks >= 12
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    done_b, _ = base.run([make_request(p) for p in prompts])
    _assert_equal_service(done_b, done)
