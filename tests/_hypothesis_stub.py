"""Shared fallback for the optional ``hypothesis`` dev dependency.

Importing ``given/settings/st`` from here keeps the unit tests in a module
runnable when hypothesis is absent: each property test turns into a clean
pytest skip (the wrapped body calls ``pytest.importorskip``, so pytest
reports a standard skip reason) instead of breaking collection.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():       # zero-arg: strategy params must not look
                pytest.importorskip("hypothesis")   # like pytest fixtures
            skipper.__name__, skipper.__doc__ = fn.__name__, fn.__doc__
            return skipper
        return deco

    settings = given

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
