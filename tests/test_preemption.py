"""Involuntary preemption: spill/restore round trips (dense + paged +
int8 + mid-prefill), stop-decision invariance under forced preemption
across policy x packing x paging, page-ownership invariants, SWAPPED
re-admission ordering, victim selection, EDF admission, and the
oversized-gang skip (a blocked gang no longer stalls singletons)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.serving import (ChunkSeg, ChunkWork, ContinuousServingEngine,
                           EDFPolicy, FIFOPolicy, OrcaScheduler,
                           RequestState, ServeConfig, make_request,
                           replay_model, replay_params, replay_requests,
                           served_stop_times)

from tests._hypothesis_stub import given, settings, st


# ---------------------------------------------------------------------------
# engine-level: preempt -> restore is bit-for-bit

def _probe_row(state, slot):
    return {f: np.asarray(getattr(state, f)[slot]) for f in state._fields}


def _rows_equal(a, b, msg):
    for f, v in a.items():
        np.testing.assert_array_equal(v, b[f], err_msg=f"{msg}: {f}")


def _replay_engine(bank, n_slots=3):
    pc = ProbeConfig(d_phi=bank.shape[2], smooth_window=3)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=bank.shape[1],
                      lam=0.9, burn_in=1)
    return ContinuousServingEngine(replay_model(bank), replay_params(bank),
                                   pc, theta, cfg, n_slots=n_slots,
                                   cache_len=bank.shape[1] + 2)


def test_dense_spill_restore_bit_for_bit():
    """Dense engine: a preempted slot's Spill captures the probe row
    exactly, and restoring it into a DIFFERENT slot replays the identical
    future — smoothed scores, counters and stop flags, bit for bit."""
    rs = np.random.RandomState(0)
    bank = (rs.randn(4, 20, 16) * 0.5).astype(np.float32)
    eng_a, eng_b = _replay_engine(bank), _replay_engine(bank)
    for eng in (eng_a, eng_b):
        eng.admit(0, {"tokens": jnp.full((1, 1), 0, jnp.int32)}, 1)
        eng.admit(1, {"tokens": jnp.full((1, 1), 1, jnp.int32)}, 1)
        for _ in range(5):
            eng.step()
    before = _probe_row(eng_a.st, 0)
    pos_before, tok_before = int(eng_a.pos[0]), int(eng_a.token[0])
    spill = eng_a.preempt(0)
    assert spill.armed and spill.pages is None and spill.lane is not None
    assert spill.pos == pos_before and spill.token == tok_before
    assert spill.nbytes > 0
    _rows_equal(dict(zip(eng_a.st._fields, map(np.asarray, spill.probe))),
                before, "spill.probe")
    assert bool(eng_a.st.stopped[0])          # the slot is parked
    eng_a.restore(2, spill)                   # a different physical slot
    _rows_equal(_probe_row(eng_a.st, 2), before, "restored row")
    assert int(eng_a.pos[2]) == pos_before
    for i in range(12):
        va, vb = eng_a.step(), eng_b.step()
        for f in ("smoothed", "n_scores", "stopped", "stop_step", "tokens"):
            np.testing.assert_array_equal(
                getattr(va, f)[2], getattr(vb, f)[0],
                err_msg=f"step {i}: {f} diverged after restore")
            np.testing.assert_array_equal(
                getattr(va, f)[1], getattr(vb, f)[1],
                err_msg=f"step {i}: {f} of the UNDISTURBED slot moved")


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _paged_engine(model, params, *, chunk_tokens=None, num_blocks=16):
    pc = ProbeConfig(d_phi=model.cfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=10, lam=2.0,
                      burn_in=1)
    return ContinuousServingEngine(model, params, pc, theta, cfg,
                                   n_slots=2, cache_len=18, paged=True,
                                   block_size=4, num_blocks=num_blocks,
                                   chunk_tokens=chunk_tokens)


def _paged_roundtrip(model, params):
    """Preempt slot 0 mid-decode and restore it onto DIFFERENT physical
    pages; its future must match an undisturbed twin bit for bit."""
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                                 model.cfg.vocab_size)
    eng_a = _paged_engine(model, params)
    eng_b = _paged_engine(model, params)
    row0, row1, row_new = [1, 2, 3, 4], [5, 6, 7, 8], [12, 9, 11, 10]
    for eng in (eng_a, eng_b):
        eng.admit(0, {"tokens": prompts[0:1]}, 6, block_row=row0)
        eng.admit(1, {"tokens": prompts[1:2]}, 6, block_row=row1)
        for _ in range(3):
            eng.step()
    before = _probe_row(eng_a.st, 0)
    spill = eng_a.preempt(0, block_row=row0)
    assert spill.pages is not None and spill.n_blocks == 4
    assert spill.nbytes > 0
    # only the table indirection changes: new (even reordered) pages
    eng_a.restore(0, spill, block_row=row_new)
    _rows_equal(_probe_row(eng_a.st, 0), before, "restored row")
    for i in range(5):
        va, vb = eng_a.step(), eng_b.step()
        for f in ("smoothed", "n_scores", "stopped", "stop_step", "tokens"):
            np.testing.assert_array_equal(
                getattr(va, f), getattr(vb, f),
                err_msg=f"step {i}: {f} diverged after page move")
    return spill


def test_paged_spill_restore_bit_for_bit(small_model):
    model, params = small_model
    _paged_roundtrip(model, params)


def test_paged_int8_spill_restore_bit_for_bit():
    """Quantized KV spills carry the per-page scales with the pages."""
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spill = _paged_roundtrip(model, params)
    assert {"k", "v", "k_scale", "v_scale"} <= set(spill.pages)


def test_mid_prefill_spill_restore_bit_for_bit(small_model):
    """A victim preempted BETWEEN prefill chunks (probe parked, table row
    still NULL) resumes on new pages and decodes the identical future."""
    model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                model.cfg.vocab_size)
    tokens = np.asarray(prompt[0])
    eng_a = _paged_engine(model, params, chunk_tokens=4)
    eng_b = _paged_engine(model, params, chunk_tokens=4)
    row_a, row_new = [1, 2, 3, 4], [8, 7, 6, 5]

    def chunk(row, start):
        return ChunkWork(segs=(ChunkSeg(slot=0, tokens=tokens, start=start,
                                        length=4,
                                        row=np.asarray(row, np.int32)),))

    for eng in (eng_a, eng_b):
        eng.begin_prefill(0)
        eng.step(chunk(row_a, 0))             # first half of the prompt
    spill = eng_a.preempt(0, block_row=row_a, armed=False, prompt_len=4)
    assert not spill.armed and spill.prompt_len == 4
    eng_a.restore(0, spill, block_row=row_new)
    assert bool(eng_a.st.stopped[0])          # still parked mid-prefill
    eng_a.step(chunk(row_new, 4))             # second half, new pages
    eng_b.step(chunk(row_a, 4))
    eng_a.finish_prefill(0, {"tokens": prompt}, 8, block_row=row_new)
    eng_b.finish_prefill(0, {"tokens": prompt}, 8, block_row=row_a)
    for i in range(5):
        va, vb = eng_a.step(), eng_b.step()
        for f in ("smoothed", "n_scores", "stopped", "stop_step", "tokens"):
            np.testing.assert_array_equal(
                getattr(va, f)[0], getattr(vb, f)[0],
                err_msg=f"step {i}: {f} diverged after mid-prefill spill")


# ---------------------------------------------------------------------------
# scheduler-level: forced preemption never moves a stop decision

N_TRAJ, T_STEPS, D_PHI = 9, 24, 16


@pytest.fixture(scope="module")
def replay_bank():
    rs = np.random.RandomState(0)
    drift = np.linspace(0, 1.2, T_STEPS)[None, :, None]
    bank = (rs.randn(N_TRAJ, T_STEPS, D_PHI) * 0.3
            + drift * rs.rand(N_TRAJ, 1, D_PHI)).astype(np.float32)
    theta = {"W0": (rs.randn(D_PHI) * 0.4).astype(np.float32),
             "b0": np.float32(-0.2)}
    return bank, theta


def _fleet(bank, theta, *, n_slots=3, paged=True, num_blocks=None,
           chunk_tokens=None, policy=None, pack_chunks=False,
           preemption=True, priorities=None, group=None, deadlines=None):
    pc = ProbeConfig(d_phi=D_PHI, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=T_STEPS, lam=0.62,
                      burn_in=3)
    sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc, theta,
                          cfg, n_slots=n_slots, paged=paged, block_size=4,
                          num_blocks=num_blocks, chunk_tokens=chunk_tokens,
                          pack_chunks=pack_chunks, policy=policy,
                          preemption=preemption)
    reqs = replay_requests([T_STEPS] * bank.shape[0])
    for i, r in enumerate(reqs):
        r.priority = (priorities[i] if priorities is not None else i % 2)
        if deadlines is not None:
            r.deadline_ms = deadlines[i]
        if group is not None and i in group:
            r.group_id, r.sample_idx = 0, group.index(i)
    return sched, reqs


# Two class layouts that force preemption deterministically:
#
# * BURST (for FIFO, which ignores class at admission): batch traffic
#   arrives first and fills every slot, then two urgent requests hit a
#   full fleet — each spills the newest batch resident.
# * GANG (for priority/EDF, which admit urgent work first so a burst
#   never contends): an urgent singleton whose trajectory stops EARLY
#   shares the fleet with low-class traffic while a mid-class gang of 3
#   waits; the freed slot is not enough for the gang, so it preempts the
#   low-class residents to complete its slot quota.
BURST_PRIO = [1, 1, 1, 0, 0, 2, 2, 2, 2]
GANG_PRIO = [1, 0, 2, 2, 1, 1, 2, 2, 2]
GANG = [0, 4, 5]
BLOCKS_PER_REQ = 7                     # ceil((1 + 24) / 4)


def _layout(policy):
    return ((BURST_PRIO, None) if policy == "fifo"
            else (GANG_PRIO, GANG))


@pytest.fixture(scope="module")
def abundant_tau(replay_bank):
    bank, theta = replay_bank
    sched, reqs = _fleet(bank, theta, n_slots=N_TRAJ,
                         num_blocks=1 + N_TRAJ * BLOCKS_PER_REQ)
    done, fleet = sched.run(reqs)
    assert fleet.preemptions == 0      # nothing contended: pure baseline
    tau = served_stop_times(done, [T_STEPS] * N_TRAJ)
    assert 0 < int((tau < T_STEPS).sum()) < N_TRAJ   # real mixed stops
    return tau


@pytest.mark.parametrize("paged,chunk,policy,pack", [
    (True, None, "fifo", False),
    (True, 3, "fifo", False),
    (False, None, "fifo", False),
    (True, None, "priority", False),
    (True, 3, "priority", True),
    (False, None, "priority", False),
    (True, None, "edf", False),
    (False, 3, "edf", True),
])
def test_forced_preemption_is_stop_invariant(replay_bank, abundant_tau,
                                             paged, chunk, policy, pack):
    """A fleet under REAL contention (>= 1 victim spilled AND restored)
    serves byte-identical stop decisions to the abundant run — across
    victim-selection policy, chunk packing and paged/dense engines."""
    bank, theta = replay_bank
    priorities, group = _layout(policy)
    sched, reqs = _fleet(bank, theta, n_slots=3, paged=paged,
                         num_blocks=1 + 3 * BLOCKS_PER_REQ,
                         chunk_tokens=chunk, policy=policy,
                         pack_chunks=pack, priorities=priorities,
                         group=group)
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    assert fleet.preemptions > 0, "contention never materialized (vacuous)"
    assert fleet.restores == fleet.preemptions
    if paged:
        assert fleet.spilled_blocks > 0
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()
    np.testing.assert_array_equal(
        served_stop_times(done, [T_STEPS] * N_TRAJ), abundant_tau)
    # a preempted request went through SWAPPED and came back
    victims = [r for r in done if r.n_preempted > 0]
    assert victims
    for r in victims:
        assert r.restored_step > r.admitted_step
        assert r.state in (RequestState.STOPPED, RequestState.FINISHED)


def test_preemption_off_is_wait_only(replay_bank, abundant_tau):
    bank, theta = replay_bank
    sched, reqs = _fleet(bank, theta, n_slots=3,
                         num_blocks=1 + 3 * BLOCKS_PER_REQ,
                         priorities=BURST_PRIO, preemption=False)
    done, fleet = sched.run(reqs)
    assert fleet.preemptions == 0 and fleet.restores == 0
    assert all(r.n_preempted == 0 for r in done)
    np.testing.assert_array_equal(
        served_stop_times(done, [T_STEPS] * N_TRAJ), abundant_tau)


@given(seed=st.integers(min_value=0, max_value=10_000),
       policy=st.sampled_from(["fifo", "priority", "edf"]),
       paged=st.booleans(), pack=st.booleans())
@settings(max_examples=10, deadline=None)
def test_fuzz_preemption_no_double_ownership(seed, policy, paged, pack):
    """Random priorities/deadlines under a tight pool: every page has one
    owner at a time (pool.check() after every terminal state), every
    request terminates, and a spilled request's pages are back in the pool
    while it sits SWAPPED."""
    rs = np.random.RandomState(seed)
    bank = (rs.randn(7, 16, 8) * 0.4
            + np.linspace(0, 1, 16)[None, :, None]).astype(np.float32)
    theta = {"W0": (rs.randn(8) * 0.4).astype(np.float32),
             "b0": np.float32(-0.1)}
    pc = ProbeConfig(d_phi=8, smooth_window=3)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=16, lam=0.6,
                      burn_in=2)
    per_req = (1 + 16 + 3) // 4
    sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc, theta,
                          cfg, n_slots=3, paged=paged, block_size=4,
                          num_blocks=1 + 2 * per_req,
                          chunk_tokens=(3 if pack else None),
                          pack_chunks=pack, policy=policy)
    reqs = replay_requests([16] * 7)
    for r in reqs:
        r.priority = int(rs.randint(0, 3))
        if rs.rand() < 0.5:
            r.deadline_ms = float(rs.randint(50, 500))
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    assert all(not r.block_ids for r in done)      # every page returned
    assert sched.pool.num_free == sched.pool.num_usable
    sched.pool.check()
    assert fleet.restores == fleet.preemptions


# ---------------------------------------------------------------------------
# SWAPPED queue ordering + victim selection

def test_swapped_restores_before_waiting(replay_bank):
    """Victims spilled for an urgent gang restore BEFORE any same-class
    WAITING request is admitted — the SWAPPED queue outranks WAITING."""
    bank, theta = replay_bank
    sched, reqs = _fleet(bank, theta, n_slots=2,
                         num_blocks=1 + 4 * BLOCKS_PER_REQ,
                         priorities=[1, 0, 0, 1, 1, 1, 1, 1, 1],
                         group=[1, 2])
    done, fleet = sched.run(reqs)
    assert fleet.preemptions >= 1      # the gang evicted the resident
    victims = [r for r in done if r.n_preempted > 0]
    assert victims
    fresh = [r for r in done
             if r.n_preempted == 0 and r.priority == 1
             and r.admitted_step > 0 and r.group_id is None]
    assert fresh, "no class-1 admission followed the restores"
    for v in victims:
        assert v.restored_step >= 0
        assert all(v.restored_step <= w.admitted_step for w in fresh), \
            "a WAITING request overtook a SWAPPED victim of its own class"


def test_select_victim_lowest_class_newest_first():
    pol = FIFOPolicy()
    res = [make_request(np.zeros(1, np.int64), priority=p)
           for p in (2, 1, 2, 0)]
    for i, r in enumerate(res):
        r.admitted_step = i
    # for a class-0 admission: class 2 outranks class 1, newest class-2 wins
    assert pol.select_victim(res, 0) == 2
    # for a class-1 admission only the class-2 residents are eligible
    assert pol.select_victim(res, 1) == 2
    res[2].priority = 0
    assert pol.select_victim(res, 1) == 0
    # equal-or-higher urgency is never preempted (DAG: no livelock)
    assert pol.select_victim(res, 2) is None


def test_edf_ranks_by_deadline_and_from_metrics():
    reqs = [make_request(np.zeros(1, np.int64), priority=p)
            for p in (0, 1, 2)]
    # explicit per-request deadline beats any class SLO
    reqs[2].deadline_ms = 10.0
    pol = EDFPolicy(class_slo_ms={0: 500.0, 1: 200.0})
    assert pol.select_admit(reqs, 0) == 2
    reqs[2].deadline_ms = None
    # class SLOs: class 1 (200ms) now outranks class 0 (500ms)
    assert pol.select_admit(reqs, 0) == 1
    # unknown class 2 falls back to default_slo_ms * (priority + 1)
    assert pol._deadline(reqs[2]) == pytest.approx(3000.0)
    # the observability loop: SLOs seeded from a run's per-class p99s
    per_class = {"c0_ttft_ms_p99": 80.0, "c1_ttft_ms_p99": 40.0,
                 "c0_queue_wait_ms_p99": 999.0}        # non-TTFT key ignored
    pol2 = EDFPolicy.from_metrics(per_class, slack=1.5)
    assert pol2.class_slo_ms == {0: pytest.approx(120.0),
                                 1: pytest.approx(60.0)}
    assert pol2.select_admit(reqs[:2], 0) == 1


# ---------------------------------------------------------------------------
# oversized-gang admission (the run-loop `break` bugfix)

def _gang_fleet(bank, theta, *, max_head_skips=8, preemption=False):
    pc = ProbeConfig(d_phi=D_PHI, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=T_STEPS, lam=0.62,
                      burn_in=3)
    sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc, theta,
                          cfg, n_slots=3, paged=True, block_size=4,
                          num_blocks=1 + 6 * BLOCKS_PER_REQ,
                          policy=FIFOPolicy(max_head_skips=max_head_skips),
                          preemption=preemption)
    reqs = replay_requests([T_STEPS] * bank.shape[0])
    # queue order: 2 singletons, then a gang of 3, then more singletons —
    # the gang can only start once a whole fleet's worth of slots is free
    for i in (2, 3, 4):
        reqs[i].group_id, reqs[i].sample_idx = 0, i - 2
    return sched, reqs


def test_singleton_admits_past_blocked_gang(replay_bank):
    """FIFO, no preemption: requests 0-1 occupy 2 of 3 slots, the gang of
    3 cannot start — the old composer loop would `break` and leave the
    free slot idle forever.  The policy skip admits the singletons behind
    the gang into the free slot while the gang waits its turn."""
    bank, theta = replay_bank
    sched, reqs = _gang_fleet(bank, theta)
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done) and fleet.preemptions == 0
    gang = [r for r in done if r.group_id is not None]
    solo_late = [r for r in done if r.group_id is None and r.req_id
                 > max(g.req_id for g in gang)]
    # a later singleton used the slot the blocked gang could not
    assert min(s.admitted_step for s in solo_late) \
        < min(g.admitted_step for g in gang)
    # gang admission stayed atomic: all samples entered on one step
    assert len({g.admitted_step for g in gang}) == 1
    # and the skip moved WHEN work happened, never what the probe saw
    solo_sched, solo_reqs = _gang_fleet(bank, theta)
    for r in solo_reqs:
        r.group_id = None
    solo_done, _ = solo_sched.run(solo_reqs)
    np.testing.assert_array_equal(
        served_stop_times(done, [T_STEPS] * N_TRAJ),
        served_stop_times(solo_done, [T_STEPS] * N_TRAJ))


def test_blocked_gang_ages_to_a_pin(replay_bank):
    """With max_head_skips=1 the gang is pinned after one skip: every
    still-waiting singleton must then queue BEHIND it."""
    bank, theta = replay_bank
    sched, reqs = _gang_fleet(bank, theta, max_head_skips=1)
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    gang_step = min(r.admitted_step for r in done if r.group_id is not None)
    overtakers = [r for r in done
                  if r.group_id is None and 0 < r.admitted_step < gang_step]
    assert len(overtakers) <= 1        # the single allowed skip, no more
