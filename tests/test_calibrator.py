"""Calibrator protocol conformance (TTT + static) and facade/shim regression."""
import math

import pytest

from repro import api as orca
from repro.core import stopping as S
from repro.core.calibrator import (Calibrator, StaticCalibrator,
                                   TTTCalibrator, make_calibrator)
from repro.core.pipeline import make_labels, run_orca
from repro.core.probe import ProbeConfig
from repro.trajectories import corpus_splits


@pytest.fixture(scope="module")
def splits():
    return corpus_splits(60, 24, 24, d_phi=16, seed=3)


@pytest.fixture(scope="module")
def fitted(splits):
    train, _, _ = splits
    return {
        "ttt": orca.fit(train, mode="supervised", method="ttt",
                        pc=ProbeConfig(d_phi=16), epochs=4,
                        epoch_select=False, seed=3),
        "static": orca.fit(train, mode="supervised", method="static"),
    }


@pytest.mark.parametrize("method", ["ttt", "static"])
def test_protocol_conformance(fitted, splits, method):
    _, cal, test = splits
    c = fitted[method]
    assert isinstance(c, Calibrator)
    assert c.method == method and c.mode == "supervised"
    s = c.scores(test)
    assert s.shape == test.phis.shape[:2]
    assert (s[~test.mask] == 0).all()          # masked steps score 0
    assert ((s >= 0) & (s <= 1)).all()
    lam = c.calibrate(cal, delta=0.2)
    assert lam == c.threshold()
    assert math.isinf(lam) or 0.0 < lam <= 1.0


@pytest.mark.parametrize("method", ["ttt", "static"])
def test_calibrate_matches_stopping_pipeline(fitted, splits, method):
    """The protocol's calibrate() must equal the offline LTT path."""
    _, cal, _ = splits
    c = fitted[method]
    lam = c.calibrate(cal, delta=0.2)
    ref = S.calibrate_and_evaluate(
        c.scores(cal), make_labels(cal, c.mode), cal.mask,
        c.scores(cal), make_labels(cal, c.mode), cal.mask, delta=0.2)
    assert lam == ref.lam


def test_unfitted_and_uncalibrated_raise(splits):
    _, cal, _ = splits
    c = TTTCalibrator(epochs=1)
    with pytest.raises(RuntimeError):
        c.scores(cal)
    with pytest.raises(RuntimeError):
        c.calibrate(cal, delta=0.1)
    with pytest.raises(RuntimeError):
        c.threshold()


def test_static_serving_params_flatten_to_frozen_probe(fitted):
    """PCA+logreg flattens into eta=0 kernel state (PR 2): the fused engine
    can deploy the static baseline; unfitted still raises."""
    pc, theta = fitted["static"].serving_params()
    assert pc.eta == 0.0 and pc.variant == "noqk"
    assert theta["W0"].shape == (pc.d_phi,)
    from repro.core.calibrator import StaticCalibrator
    with pytest.raises(RuntimeError):
        StaticCalibrator().serving_params()


def test_ttt_serving_params_roundtrip(fitted):
    pc, theta = fitted["ttt"].serving_params()
    assert pc.d_phi == 16 and "W0" in theta


def test_make_calibrator_registry():
    assert isinstance(make_calibrator("ttt", epochs=1), TTTCalibrator)
    assert isinstance(make_calibrator("static"), StaticCalibrator)
    with pytest.raises(ValueError):
        make_calibrator("nope")


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_run_orca_shim_matches_facade(splits):
    """The deprecation shim must produce the facade's numbers exactly."""
    train, cal, test = splits
    out = run_orca(train, cal, test, mode="supervised",
                   pc=ProbeConfig(d_phi=16), deltas=(0.1, 0.2), epochs=4,
                   seed=3)
    ttt = orca.fit(train, mode="supervised", method="ttt",
                   pc=ProbeConfig(d_phi=16), epochs=4, seed=3)
    ev = orca.evaluate(ttt, cal, test, deltas=(0.1, 0.2))
    for a, b in zip(out["ttt"].results, ev.results):
        assert a.lam == b.lam
        assert a.savings == pytest.approx(b.savings, abs=1e-12)
        assert a.error == pytest.approx(b.error, abs=1e-12)
    static = orca.fit(train, mode="supervised", method="static")
    ev_s = orca.evaluate(static, cal, test, deltas=(0.1, 0.2))
    for a, b in zip(out["static"].results, ev_s.results):
        assert a.lam == b.lam
        assert a.savings == pytest.approx(b.savings, abs=1e-12)
