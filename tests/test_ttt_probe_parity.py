"""Differential parity: Pallas TTT-probe kernels vs jnp oracles vs the
pre-refactor (PR-1) serving probe, across batch sizes, feature dims, dtypes,
t_chunk values and mid-stream stopped slots.

The serving engine deploys the kernel path; the LTT guarantee only covers
the deployed procedure if that path is the SAME procedure the offline
calibration scored — so stop decisions are asserted exactly (bitwise), and
scores/weights to explicit tolerances, never eyeballed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.probe import ProbeConfig, init_outer
from repro.kernels import ref as R
from repro.kernels.ttt_probe import (serving_probe_step, ttt_probe_batched,
                                     ttt_probe_scan)
from repro.serving import (OrcaScheduler, ServeConfig, init_probe_state,
                           probe_update, replay_model, replay_params,
                           replay_requests)
from repro.trajectories.synthetic import TrajectoryDistribution, generate

TOL = dict(rtol=2e-4, atol=2e-5)


def _traj_batch(seed, n, t, f, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    zq = jax.random.normal(ks[0], (n, t, f)).astype(dtype)
    zk = jax.random.normal(ks[1], (n, t, f)).astype(dtype)
    c = (jax.random.uniform(ks[2], (n, t)) > 0.5).astype(jnp.float32)
    m = (jax.random.uniform(ks[3], (n, t)) > 0.2).astype(jnp.float32)
    w0 = (jax.random.normal(ks[4], (n, f)) / np.sqrt(f)).astype(dtype)
    b0 = jax.random.normal(ks[5], (n,)) * 0.3
    return zq, zk, c, m, w0, b0


# ---------------------------------------------------------------------------
# chunked multi-step kernel (vector per-slot state)

@pytest.mark.parametrize("n", [1, 3, 8])
@pytest.mark.parametrize("f", [64, 128])
@pytest.mark.parametrize("t_chunk", [8, 32, 128])
def test_batched_kernel_matches_ref(n, f, t_chunk):
    zq, zk, c, m, w0, b0 = _traj_batch(n * 1000 + f, n, 50, f)
    eta = jnp.asarray(0.02)
    s, wf, bf = ttt_probe_batched(zq, zk, c, m, w0, b0, eta, t_chunk=t_chunk)
    s_r, wf_r, bf_r = R.ttt_probe_batched_ref(zq, zk, c, m, w0, b0, eta)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), **TOL)
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wf_r), **TOL)
    np.testing.assert_allclose(np.asarray(bf), np.asarray(bf_r), **TOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_kernel_state_dtypes(dtype):
    """bf16 inputs/state are cast to an f32 compute path inside the kernel —
    results must match the oracle fed the same rounded values exactly, and
    the f32 run to bf16 resolution."""
    zq, zk, c, m, w0, b0 = _traj_batch(7, 3, 24, 64, dtype=dtype)
    eta = jnp.asarray(0.05)
    s, wf, bf = ttt_probe_batched(zq, zk, c, m, w0, b0, eta, t_chunk=8)
    s_r, wf_r, _ = R.ttt_probe_batched_ref(
        zq.astype(jnp.float32), zk.astype(jnp.float32), c, m,
        w0.astype(jnp.float32), b0, eta)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), **TOL)
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wf_r), **TOL)
    if dtype == jnp.bfloat16:
        zq32, zk32, c32, m32, w032, b032 = _traj_batch(7, 3, 24, 64)
        s32, _, _ = ttt_probe_batched(zq32, zk32, c32, m32, w032, b032, eta,
                                      t_chunk=8)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s32),
                                   rtol=0.1, atol=0.05)


def test_scan_equals_batched_broadcast():
    """Shared-init scan == batched scan from a broadcast state (one kernel)."""
    zq, zk, c, m, w0, b0 = _traj_batch(11, 4, 33, 128)
    eta = jnp.asarray(0.01)
    s_a, wf_a, bf_a = ttt_probe_scan(zq, zk, c, m, w0[0], b0[0], eta,
                                     t_chunk=16)
    s_b, wf_b, bf_b = ttt_probe_batched(
        zq, zk, c, m, jnp.broadcast_to(w0[0], w0.shape),
        jnp.broadcast_to(b0[0], b0.shape), eta, t_chunk=16)
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
    np.testing.assert_array_equal(np.asarray(wf_a), np.asarray(wf_b))
    np.testing.assert_array_equal(np.asarray(bf_a), np.asarray(bf_b))


# ---------------------------------------------------------------------------
# fused serving step vs the PR-1 jnp probe

def _fresh_state(batch, f, window, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    W = jax.random.normal(ks[0], (batch, f)) / np.sqrt(f)
    b = jax.random.normal(ks[1], (batch,)) * 0.2
    return (W, b, jnp.zeros((batch, window)), jnp.zeros((batch,), jnp.int32),
            jnp.zeros((batch,), bool), jnp.full((batch,), -1, jnp.int32))


def _chain(step_fn, state, feats, bnds, eta, lam, burn_in):
    outs = []
    for z, bnd in zip(feats, bnds):
        out = step_fn(z, z, bnd, *state, eta, lam, burn_in=burn_in)
        state = (out.W, out.b, out.ring, out.n_scores, out.stopped,
                 out.stop_step)
        outs.append(out)
    return outs


@pytest.mark.parametrize("batch", [1, 3, 8])
@pytest.mark.parametrize("f", [48, 64, 160])
def test_serving_step_matches_pr1_chain(batch, f):
    """A 20-token chain through the fused kernel and through the PR-1 jnp
    math: stop decisions bitwise equal, numerics to tolerance."""
    window, burn = 4, 2
    eta, lam = jnp.asarray(0.05), jnp.asarray(0.55)
    state = _fresh_state(batch, f, window, seed=batch + f)
    key = jax.random.PRNGKey(batch * 7 + f)
    feats = [jax.random.normal(jax.random.fold_in(key, i), (batch, f)) * 0.3
             for i in range(20)]
    bnds = [jnp.ones((batch,), bool) for _ in feats]
    outs_k = _chain(serving_probe_step, state, feats, bnds, eta, lam, burn)
    outs_r = _chain(R.serving_probe_step_ref, state, feats, bnds, eta, lam,
                    burn)
    for i, (k, r) in enumerate(zip(outs_k, outs_r)):
        np.testing.assert_array_equal(np.asarray(k.stopped),
                                      np.asarray(r.stopped), err_msg=f"t={i}")
        np.testing.assert_array_equal(np.asarray(k.stop_step),
                                      np.asarray(r.stop_step))
        np.testing.assert_array_equal(np.asarray(k.n_scores),
                                      np.asarray(r.n_scores))
        np.testing.assert_allclose(np.asarray(k.s), np.asarray(r.s),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(k.smoothed),
                                   np.asarray(r.smoothed), atol=1e-5)
        np.testing.assert_allclose(np.asarray(k.W), np.asarray(r.W),
                                   atol=1e-5)


def test_serving_step_freezes_midstream_stopped_slots():
    """Slots entering the step already stopped must be pure no-op compute:
    identical state out, no ring/score/weight movement — in BOTH impls."""
    batch, f, window = 5, 64, 3
    eta, lam = jnp.asarray(0.1), jnp.asarray(0.4)
    W, b, ring, n, stopped, ss = _fresh_state(batch, f, window, seed=9)
    stopped = jnp.asarray([False, True, False, True, False])
    ss = jnp.asarray([-1, 2, -1, 4, -1], jnp.int32)
    n = jnp.asarray([3, 2, 3, 4, 3], jnp.int32)
    z = jax.random.normal(jax.random.PRNGKey(1), (batch, f))
    bnd = jnp.ones((batch,), bool)      # raw boundary: impls must mask it
    for step_fn in (serving_probe_step, R.serving_probe_step_ref):
        out = step_fn(z, z, bnd, W, b, ring, n, stopped, ss, eta, lam,
                      burn_in=0)
        frozen = np.asarray(stopped)
        np.testing.assert_array_equal(np.asarray(out.W)[frozen],
                                      np.asarray(W)[frozen])
        np.testing.assert_array_equal(np.asarray(out.ring)[frozen],
                                      np.asarray(ring)[frozen])
        np.testing.assert_array_equal(np.asarray(out.n_scores)[frozen],
                                      np.asarray(n)[frozen])
        np.testing.assert_array_equal(np.asarray(out.stop_step)[frozen],
                                      np.asarray(ss)[frozen])
        assert np.asarray(out.stopped)[frozen].all()
        # live slots still move
        live = ~frozen
        assert (np.asarray(out.n_scores)[live] == np.asarray(n)[live] + 1).all()


def test_serving_chain_equals_chunked_scan():
    """With stopping disabled, N single serving steps == one chunked
    multi-step kernel call — the two variants are the same procedure."""
    batch, f, t = 3, 64, 12
    eta = jnp.asarray(0.03)
    lam = jnp.asarray(2.0)                       # sigmoid <= 1: never stops
    W, b, ring, n, stopped, ss = _fresh_state(batch, f, 4, seed=2)
    key = jax.random.PRNGKey(5)
    zs = jax.random.normal(key, (batch, t, f)) * 0.4
    feats = [zs[:, i] for i in range(t)]
    bnds = [jnp.ones((batch,), bool)] * t
    outs = _chain(serving_probe_step, (W, b, ring, n, stopped, ss),
                  feats, bnds, eta, lam, burn_in=0)
    s_chain = np.stack([np.asarray(o.s) for o in outs], axis=1)
    s_scan, wf, bf = ttt_probe_batched(zs, zs, jnp.zeros((batch, t)),
                                       jnp.ones((batch, t)), W, b, eta,
                                       t_chunk=4)
    np.testing.assert_allclose(s_chain, np.asarray(s_scan), atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[-1].W), np.asarray(wf),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[-1].b), np.asarray(bf),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# engine-level regression fixtures: kernel path vs PR-1 path end to end

def _served_fixture(probe_impl):
    dist = TrajectoryDistribution("parity", d_phi=32, t_min=16, t_max=32)
    ts = generate(dist, 10, seed=4)
    pc = ProbeConfig(d_phi=32, smooth_window=4)
    theta = init_outer(pc, jax.random.PRNGKey(0))
    theta["b0"] = jnp.asarray(0.45)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=int(ts.lengths.max()),
                      lam=0.62, burn_in=2)
    sched = OrcaScheduler(replay_model(ts.phis), replay_params(ts.phis), pc,
                          theta, cfg, n_slots=3, probe_impl=probe_impl)
    done, _ = sched.run(replay_requests(ts.lengths))
    return done


def test_engine_stop_decisions_bit_compatible_with_pr1():
    """The full continuous-batching engine, kernel probe vs the PR-1 jnp
    probe: every request's stop decision identical, scores to tolerance."""
    done_k = _served_fixture("kernel")
    done_r = _served_fixture("ref")
    assert [r.stop_step for r in done_k] == [r.stop_step for r in done_r]
    assert [r.state for r in done_k] == [r.state for r in done_r]
    for a, b in zip(done_k, done_r):
        assert len(a.scores) == len(b.scores)
        np.testing.assert_allclose(np.array(a.scores), np.array(b.scores),
                                   atol=1e-5)


def test_probe_update_rejects_unknown_impl():
    pc = ProbeConfig(d_phi=8, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(0))
    st = init_probe_state(pc, theta, 2, 8)
    with pytest.raises(ValueError, match="probe_impl"):
        probe_update(pc, theta, st, jnp.zeros((2, 8)), 0.5, 1, 0,
                     probe_impl="nope")


# ---------------------------------------------------------------------------
# property-based sweep (skips cleanly when hypothesis is absent)

@given(st.integers(1, 8), st.sampled_from([32, 64, 96, 128]),
       st.integers(0, 10_000), st.floats(0.3, 0.9), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_property_serving_step_parity(batch, f, seed, lam, window):
    """Random state + features (incl. randomly pre-stopped slots): kernel
    and PR-1 oracle agree on decisions exactly, numerics to tolerance."""
    rs = np.random.RandomState(seed)
    eta = jnp.asarray(rs.uniform(0.001, 0.2))
    lam = jnp.asarray(lam)
    W = jnp.asarray(rs.randn(batch, f) / np.sqrt(f), jnp.float32)
    b = jnp.asarray(rs.randn(batch) * 0.5, jnp.float32)
    ring = jnp.asarray(rs.rand(batch, window), jnp.float32)
    n = jnp.asarray(rs.randint(0, 9, batch), jnp.int32)
    stopped = jnp.asarray(rs.rand(batch) < 0.3)
    ss = jnp.where(stopped, n, -1).astype(jnp.int32)
    z = jnp.asarray(rs.randn(batch, f), jnp.float32)
    bnd = jnp.asarray(rs.rand(batch) < 0.8)
    burn = int(rs.randint(0, 4))
    out_k = serving_probe_step(z, z, bnd, W, b, ring, n, stopped, ss,
                               eta, lam, burn_in=burn)
    out_r = R.serving_probe_step_ref(z, z, bnd, W, b, ring, n, stopped,
                                     ss, eta, lam, burn_in=burn)
    np.testing.assert_array_equal(np.asarray(out_k.stopped),
                                  np.asarray(out_r.stopped))
    np.testing.assert_array_equal(np.asarray(out_k.stop_step),
                                  np.asarray(out_r.stop_step))
    np.testing.assert_array_equal(np.asarray(out_k.n_scores),
                                  np.asarray(out_r.n_scores))
    np.testing.assert_allclose(np.asarray(out_k.s), np.asarray(out_r.s),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_k.W), np.asarray(out_r.W),
                               atol=1e-5)
