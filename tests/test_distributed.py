"""Multi-device correctness tests: run in a subprocess with 8 emulated host
devices (XLA_FLAGS must be set before jax init, so these cannot run in the
main pytest process which already initialized 1 device).

Covers: expert-parallel MoE vs the dense oracle, sequence-sharded
flash-decode vs the single-device core, and FSDP/TP train-step lowering on a
small mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_in_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_moe_ep_matches_dense_oracle():
    run_in_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import moe as M
    from repro.models.common import init_params
    from repro.parallel import ParallelContext, use_parallel
    import dataclasses

    cfg = get_config("granite-moe-1b-a400m").reduced()   # 4 experts top-2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = init_params(M.moe_decls(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    y_dense, aux_dense = M.moe_dense(params, x, cfg)
    # generous capacity so the EP path drops nothing -> must match exactly
    cfg_full = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    ctx = ParallelContext(mesh=mesh, rules={"batch": ("data",)},
                          data_axes=("data",), model_axis="model",
                          ep_moe=True)
    with use_parallel(ctx):
        y_ep, aux_ep = jax.jit(lambda p, x: M.moe_ep(p, x, cfg_full, ctx))(params, x)
    np.testing.assert_allclose(np.asarray(y_dense, np.float32),
                               np.asarray(y_ep, np.float32), rtol=2e-3, atol=2e-3)
    print("EP == dense oracle OK")
    # gradient flows through the EP path
    def loss(p):
        y, aux = M.moe_ep(p, x, cfg_full, ctx)
        return jnp.sum(jnp.square(y)) + aux
    with use_parallel(ctx):
        g = jax.jit(jax.grad(loss))(params)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("EP grad OK", gn)
    """)


def test_flash_decode_sharded_matches_core():
    run_in_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.attention import _decode_core, _flash_decode_sharded
    from repro.parallel import ParallelContext

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelContext(mesh=mesh, rules={"batch": "data"},
                          data_axes=("data",), model_axis="model",
                          flash_decode=True)
    b, kv, g, s, d = 4, 2, 3, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    qg = jax.random.normal(ks[0], (b, kv, g, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    valid = jax.random.uniform(ks[3], (b, s)) > 0.3
    valid = valid.at[:, 0].set(True)
    ref = _decode_core(qg, k, v, valid)

    def sharded(*a):
        o, l, m = _flash_decode_sharded(ctx, *a)
        return o / jnp.maximum(l, 1e-30)[..., None]

    out = jax.jit(sharded)(qg, k, v, valid)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
    print("flash-decode sharded OK")
    """)


def test_small_mesh_train_and_decode_lowering():
    """A miniature of the dry-run on a 2x4 mesh with REAL execution:
    one train step + one serve step of a reduced arch, sharded."""
    run_in_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, InputShape
    from repro.launch import shardings as SH
    from repro.models import build
    from repro.optim import Adam
    from repro.parallel import use_parallel
    import dataclasses

    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = build(cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = InputShape("t", 64, 4, "train")
    ctx = SH.make_context(cfg, mesh, shape, multi_pod=False)
    ctx = dataclasses.replace(ctx, attn_impl="einsum", remat=False)
    with use_parallel(ctx):
        params = model.init(jax.random.PRNGKey(0))
        opt = Adam(lr=1e-3)
        opt_state = opt.init(params)
        batch = model.make_batch(jax.random.PRNGKey(1), shape)

        def train_step(params, opt_state, batch):
            (loss, m), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(params)
            upd, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, upd)
            return params, opt_state, loss

        params2, _, loss = jax.jit(train_step)(params, opt_state, batch)
        assert np.isfinite(float(loss)), loss
        print("sharded train step OK, loss", float(loss))

    dshape = InputShape("d", 64, 4, "decode")
    dctx = SH.make_context(cfg, mesh, dshape, multi_pod=False)
    with use_parallel(dctx):
        from repro.core.probe import ProbeConfig, init_outer
        from repro.serving import ServeConfig, init_probe_state, make_serve_step
        pc = ProbeConfig(d_phi=cfg.d_model)
        theta = init_outer(pc, jax.random.PRNGKey(2))
        scfg = ServeConfig(tokens_per_step=4, lam=0.9)
        cache_len, window = model.decode_geometry(dshape)
        state = model.init_decode_state(4, cache_len)
        st = init_probe_state(pc, theta, 4, cfg.d_model)
        step = jax.jit(make_serve_step(model, pc, scfg, window=window))
        tok = jnp.zeros((4,), jnp.int32)
        tok, state, st = step(params2, theta, tok, state,
                              jnp.asarray(0, jnp.int32), st)
        assert np.isfinite(np.asarray(st.smoothed, np.float32)).all()
        print("sharded serve step OK")
    """)
