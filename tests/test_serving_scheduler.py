"""Scheduler admission/eviction invariants + eviction score-invariance +
static-batch shim regression + randomized admit/finish/stop fuzzing."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.serving import (ContinuousServingEngine, OrcaScheduler,
                           RequestState, ServeConfig, ServingEngine,
                           init_probe_state, make_request, replay_model,
                           replay_params, reset_probe_slot,
                           served_stop_times)

# the deprecated shims (ServingEngine.serve / run_orca) are exercised here
# ON PURPOSE as equality baselines — silence their DeprecationWarning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias, smooth_window=2):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=smooth_window)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _prompts(mcfg, n, prompt_len=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, prompt_len), 0,
                              mcfg.vocab_size)


# ---------------------------------------------------------------------------
# probe-state slot ops

def test_reset_probe_slot_admit_and_park():
    pc = ProbeConfig(d_phi=4, smooth_window=3)
    theta = init_outer(pc, jax.random.PRNGKey(0))
    st = init_probe_state(pc, theta, 3, 4)
    dirty = st._replace(W=st.W + 7.0, n_scores=st.n_scores + 5,
                        stopped=jnp.ones((3,), bool))
    fresh = reset_probe_slot(pc, theta, dirty, 1, active=True)
    np.testing.assert_allclose(np.asarray(fresh.W[1]),
                               np.asarray(theta["W0"]))
    assert int(fresh.n_scores[1]) == 0 and not bool(fresh.stopped[1])
    # other slots untouched
    np.testing.assert_allclose(np.asarray(fresh.W[0]), np.asarray(dirty.W[0]))
    assert bool(fresh.stopped[0])
    parked = reset_probe_slot(pc, theta, fresh, 1, active=False)
    assert bool(parked.stopped[1])


# ---------------------------------------------------------------------------
# scheduler invariants

def test_freed_slot_refilled_on_next_step(small_model):
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)       # high scores -> early stops
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=24, lam=0.5, burn_in=1)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    reqs = [make_request(p) for p in _prompts(model.cfg, 6)]
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    evictions = sorted(r.completed_step for r in done)
    late_admissions = sorted(r.admitted_step for r in done
                             if r.admitted_step > 0)
    # every eviction immediately hands its slot to the next waiting request
    assert late_admissions == evictions[:len(late_admissions)]
    assert len(late_admissions) == 4         # 6 requests, 2 initial slots
    assert fleet.engine_steps < 6 * 24       # far better than sequential
    assert 0.0 < fleet.slot_utilization <= 1.0
    assert fleet.n_requests == 6


def test_all_requests_reach_terminal_state(small_model):
    model, params = small_model
    pc, theta = _probe(model.cfg, -50.0)     # never stops -> budget path
    cfg = ServeConfig(tokens_per_step=4, max_new_tokens=8, lam=0.99, burn_in=0)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    reqs = [make_request(p) for p in _prompts(model.cfg, 3)]
    done, fleet = sched.run(reqs)
    assert [r.state for r in done] == [RequestState.FINISHED] * 3
    assert all(r.stop_step == -1 and len(r.tokens) == 8 for r in done)
    # 3 requests x 8 tokens over 2 slots: one refill round
    assert fleet.engine_steps == 16


def test_eviction_is_score_invariant(small_model):
    """A request served under continuous batching (staggered admission,
    neighbors coming and going) must produce the same scores and stop step
    as a fresh single-request run."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6, burn_in=1)
    prompts = _prompts(model.cfg, 5)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    done, _ = sched.run([make_request(p) for p in prompts])
    for i, r in enumerate(done):
        solo_sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=1)
        solo, _ = solo_sched.run([make_request(prompts[i])])
        assert solo[0].stop_step == r.stop_step
        np.testing.assert_allclose(np.array(r.scores),
                                   np.array(solo[0].scores), atol=1e-4)


def test_scheduler_matches_static_batch_engine(small_model):
    """Shim regression: the deprecated static-batch ServingEngine and the
    continuous scheduler agree on every stop decision, score and the shared
    savings metric when given the same queue."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6, burn_in=1)
    prompts = _prompts(model.cfg, 4)
    res = ServingEngine(model, params, pc, theta, cfg).serve(
        {"tokens": prompts}, prompt_len=8)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    done, fleet = sched.run([make_request(p) for p in prompts])
    assert res.stop_step.tolist() == [r.stop_step for r in done]
    assert res.steps_run.tolist() == [r.steps_run for r in done]
    for i, r in enumerate(done):
        n = len(r.scores)
        np.testing.assert_allclose(np.array(r.scores), res.scores[i, :n],
                                   atol=1e-4)
    # both paths report the SAME unified savings metric
    assert fleet.mean_step_savings == pytest.approx(res.savings, abs=1e-9)


def test_continuous_engine_admit_release_cycle(small_model):
    """Slot-level engine API: admit -> step -> release -> re-admit reuses the
    slot with a clean probe state."""
    model, params = small_model
    mcfg = model.cfg
    pc, theta = _probe(mcfg, 3.0, smooth_window=1)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=8, lam=0.5, burn_in=0)
    eng = ContinuousServingEngine(model, params, pc, theta, cfg,
                                  n_slots=2, cache_len=24)
    prompts = _prompts(mcfg, 2, prompt_len=6)
    eng.admit(0, {"tokens": prompts[0:1]}, 6)
    view = eng.step()
    assert bool(view.stopped[1])      # empty slot stays parked (no-op row)
    first_run = [float(eng.step().smoothed[0]) for _ in range(2)]
    eng.release(0)
    assert bool(eng.step().stopped[0])
    eng.admit(0, {"tokens": prompts[0:1]}, 6)
    eng.step()
    second_run = [float(eng.step().smoothed[0]) for _ in range(2)]
    np.testing.assert_allclose(first_run, second_run, atol=1e-4)


# ---------------------------------------------------------------------------
# randomized fuzzing: slot invariants under arbitrary admit/finish/stop mixes


def _probe_row(st, slot):
    return {f: np.asarray(getattr(st, f)[slot]) for f in st._fields}


def _assert_rows_equal(row, expect, msg):
    for f, v in expect.items():
        np.testing.assert_array_equal(row[f], v, err_msg=f"{msg}: {f}")


def test_engine_fuzz_admit_release_step_invariants():
    """Random admit/release/step sequences against ContinuousServingEngine:
    * an admitted slot's probe row equals a fresh ``init_probe_state`` row;
    * a released slot equals the parked fresh row (stopped=True) and stays
      frozen across subsequent steps;
    * the vector ``pos`` advances by exactly one per step for every slot a
      request occupies (monotonic per request)."""
    rs = np.random.RandomState(0)
    n_traj, t_max, d, n_slots = 16, 24, 32, 3
    bank = rs.randn(n_traj, t_max, d).astype(np.float32) * 0.5
    model, params = replay_model(bank), replay_params(bank)
    pc = ProbeConfig(d_phi=d, smooth_window=3)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t_max, lam=0.9,
                      burn_in=1)
    eng = ContinuousServingEngine(model, params, pc, theta, cfg,
                                  n_slots=n_slots, cache_len=t_max + 2)
    fresh = _probe_row(init_probe_state(pc, theta, 1, d), 0)
    parked = dict(fresh, stopped=np.asarray(True))
    occupant = {}                        # slot -> admit-time pos
    steps_since = {}
    for opno in range(60):
        op = rs.choice(["admit", "release", "step"], p=[0.3, 0.15, 0.55])
        free = [s for s in range(n_slots) if s not in occupant]
        if op == "admit" and free:
            slot = int(rs.choice(free))
            traj = int(rs.randint(n_traj))
            eng.admit(slot, {"tokens": jnp.full((1, 1), traj, jnp.int32)}, 1)
            _assert_rows_equal(_probe_row(eng.st, slot), fresh,
                               f"op{opno} admit slot{slot}")
            occupant[slot], steps_since[slot] = int(eng.pos[slot]), 0
        elif op == "release" and occupant:
            slot = int(rs.choice(list(occupant)))
            eng.release(slot)
            _assert_rows_equal(_probe_row(eng.st, slot), parked,
                               f"op{opno} release slot{slot}")
            del occupant[slot], steps_since[slot]
        elif op == "step":
            before = {s: _probe_row(eng.st, s)
                      for s in range(n_slots) if s not in occupant}
            eng.step()
            for slot in occupant:
                steps_since[slot] += 1
                # vector pos: strictly monotonic, +1 per fused step
                assert int(eng.pos[slot]) == occupant[slot] + steps_since[slot]
            for slot, row in before.items():
                # empty/evicted slots are frozen no-op compute
                keep = {f: row[f] for f in
                        ("W", "b", "ring", "n_scores", "stopped", "stop_step")}
                _assert_rows_equal(_probe_row(eng.st, slot), keep,
                                   f"op{opno} parked slot{slot}")


def test_scheduler_fuzz_no_double_occupancy(small_model):
    """Randomized queue (mixed budgets -> interleaved ORCA stops and budget
    finishes): any two requests overlapping in engine-step time must have
    occupied distinct slots, every slot stays within range, and every
    request reaches a terminal state with a consistent slot history."""
    model, params = small_model
    rs = np.random.RandomState(7)
    pc, theta = _probe(model.cfg, 1.5)   # borderline scores: mixed outcomes
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6,
                      burn_in=1)
    reqs = [make_request(p, max_new_tokens=int(rs.choice([6, 10, 16])))
            for p in _prompts(model.cfg, 10, prompt_len=6, seed=8)]
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3)
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    states = {r.state for r in done}
    assert states <= {RequestState.STOPPED, RequestState.FINISHED}
    for r in done:
        assert 0 <= r.slot < 3
        assert r.admitted_step >= 0 and r.completed_step > r.admitted_step
    # no double-occupancy: same slot => disjoint [admitted, completed) spans
    for a, b in itertools.combinations(done, 2):
        if a.slot == b.slot:
            assert (a.completed_step <= b.admitted_step
                    or b.completed_step <= a.admitted_step), (a, b)
    # slot-step accounting is consistent with the occupancy intervals
    busy = sum(r.completed_step - r.admitted_step for r in done)
    assert busy == fleet.active_slot_steps


# ---------------------------------------------------------------------------
# served_stop_times convention regression

def test_served_stop_times_step_zero_stop():
    """The engine convention is ``stop_step >= 0`` means "stopped" — the
    old ``> 0`` comparison misread a step-0 stop as budget exhaustion and
    charged the request its full length.  The 0-based offline index floors
    at 0 (the offline grid cannot stop before its first score)."""
    reqs = [make_request(np.zeros(1, np.int64)) for _ in range(3)]
    reqs[0].stop_step = 0        # convention-level boundary case
    reqs[1].stop_step = 1        # the kernel's earliest real stop
    reqs[2].stop_step = -1       # budget exhausted: never charged
    assert served_stop_times(reqs, [12, 12, 12]).tolist() == [0, 0, 12]
