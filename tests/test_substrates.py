"""Tests for the substrate layers: data pipeline, optimizer, checkpointing,
serving engine (incl. the fused ORCA serve_step)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.models import build
from repro.optim import Adam, cosine_schedule
from repro.checkpoint import latest_step, restore, save_pytree
from repro.serving import (ServeConfig, ServingEngine, extract_trajectories,
                           init_probe_state, make_serve_step)

# the deprecated shims (ServingEngine.serve / run_orca) are exercised here
# ON PURPOSE as equality baselines — silence their DeprecationWarning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# data

def test_token_pipeline_deterministic_and_shaped():
    cfg = TokenPipelineConfig(vocab_size=100, seq_len=32, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch(7), p2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # targets are next-token shifted
    np.testing.assert_array_equal(p1._batch_np(0)[:, 1:],
                                  p1.batch(0)["targets"])
    assert (b1["tokens"] < 100).all() and (b1["tokens"] >= 0).all()
    assert not np.array_equal(p1.batch(0)["tokens"], p1.batch(1)["tokens"])


def test_token_pipeline_has_learnable_structure():
    """Markov blend: P(next | prev state) is peaked vs the unigram prior."""
    cfg = TokenPipelineConfig(vocab_size=50, seq_len=256, global_batch=16)
    toks = TokenPipeline(cfg).batch(0)["tokens"].reshape(-1)
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(a % 64, []).append(b)
    # top continuation should be much more likely than uniform
    top_frac = []
    for vs in pairs.values():
        if len(vs) >= 30:
            _, counts = np.unique(vs, return_counts=True)
            top_frac.append(counts.max() / len(vs))
    assert np.mean(top_frac) > 3.0 / 50


# ---------------------------------------------------------------------------
# optimizer

def test_adam_converges_quadratic():
    opt = Adam(lr=0.1, clip_norm=None)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        upd, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adam_clipping_and_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
    opt = Adam(lr=1.0, clip_norm=1.0)
    g = {"x": jnp.asarray([30.0, 40.0])}   # norm 50 -> scaled to 1
    st = opt.init(g)
    upd, _ = opt.update(g, st, g)
    assert np.isfinite(np.asarray(upd["x"])).all()


# ---------------------------------------------------------------------------
# checkpointing

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "tup": (jnp.zeros((2,)), jnp.ones((1,)))}
    d = str(tmp_path)
    save_pytree(tree, d, step=3)
    save_pytree(tree, d, step=10)
    assert latest_step(d) == 10
    template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = restore(template, os.path.join(d, "step_10"))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_pytree({"a": jnp.zeros((2,))}, d, step=0)
    with pytest.raises(ValueError):
        restore({"a": jnp.zeros((3,))}, os.path.join(d, "step_0"))


# ---------------------------------------------------------------------------
# serving engine with ORCA stopping

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_serve_step_stops_on_high_scores(small_model):
    model, params = small_model
    mcfg = model.cfg
    B, prompt = 2, 8
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    # force immediate stopping: huge positive bias, lam tiny, burn_in 0
    theta["b0"] = jnp.asarray(50.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=24, lam=0.5, burn_in=0)
    eng = ServingEngine(model, params, pc, theta, cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, prompt),
                                          0, mcfg.vocab_size)}
    res = eng.serve(batch, prompt_len=prompt, cache_len=prompt + 32)
    assert (res.stop_step >= 0).all()          # everyone stopped early
    assert res.savings > 0.0


def test_serve_step_budget_exhaustion(small_model):
    model, params = small_model
    mcfg = model.cfg
    pc = ProbeConfig(d_phi=mcfg.d_model)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(-50.0)           # never stop
    cfg = ServeConfig(tokens_per_step=4, max_new_tokens=16, lam=0.99, burn_in=0)
    eng = ServingEngine(model, params, pc, theta, cfg)
    batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    res = eng.serve(batch, prompt_len=4, cache_len=32)
    assert (res.stop_step == -1).all()
    assert res.savings == 0.0


def test_extract_trajectories_shapes(small_model):
    model, params = small_model
    mcfg = model.cfg
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    phis, toks = extract_trajectories(model, params, batch, prompt_len=4,
                                      max_new_tokens=12, tokens_per_step=3,
                                      cache_len=20)
    assert phis.shape == (2, 4, mcfg.d_model)
    assert toks.shape == (2, 12)
    assert np.isfinite(phis).all()


def test_probe_state_freezes_after_stop(small_model):
    """Stopped sequences must not update fast weights further."""
    model, params = small_model
    mcfg = model.cfg
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=1)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(50.0)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=6, lam=0.5, burn_in=0)
    step_fn = jax.jit(make_serve_step(model, pc, cfg))
    state = model.init_decode_state(1, 16)
    st = init_probe_state(pc, theta, 1, mcfg.d_model)
    token = jnp.zeros((1,), jnp.int32)
    W_hist = []
    for i in range(4):
        token, state, st = step_fn(params, theta, token, state,
                                   jnp.asarray(i, jnp.int32), st)
        W_hist.append(np.asarray(st.W))
    assert bool(np.asarray(st.stopped).all())
    # after stopping, W frozen
    np.testing.assert_array_equal(W_hist[-1], W_hist[-2])
