"""Per-kernel allclose tests: Pallas (interpret=True on CPU) vs pure-jnp
oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention, flash_decode, make_unroll_kernel,
                           paged_flash_decode, paged_flash_prefill_chunk,
                           ttt_probe_scan, wkv_scan)
from repro.kernels import ref as R
from repro.core.probe import ProbeConfig
from repro.core import ttt


# ---------------------------------------------------------------------------
# TTT probe fused scan

@pytest.mark.parametrize("n,t,f", [(2, 16, 128), (3, 40, 256), (1, 130, 128)])
def test_ttt_probe_scan_matches_ref(n, t, f):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    zq = jax.random.normal(ks[0], (n, t, f))
    zk = jax.random.normal(ks[1], (n, t, f))
    c = (jax.random.uniform(ks[2], (n, t)) > 0.5).astype(jnp.float32)
    m = jnp.ones((n, t))
    w0 = jax.random.normal(ks[3], (f,)) / np.sqrt(f)
    b0 = jnp.asarray(0.3)
    eta = jnp.asarray(0.01)
    s, wf, bf = ttt_probe_scan(zq, zk, c, m, w0, b0, eta, t_chunk=32)
    s_r, wf_r, bf_r = R.ttt_probe_ref(zq, zk, c, m, w0, b0, eta)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wf_r), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bf), np.asarray(bf_r), rtol=2e-4, atol=2e-5)


def test_ttt_probe_scan_respects_mask():
    n, t, f = 2, 24, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    zq = jax.random.normal(ks[0], (n, t, f))
    m = jnp.concatenate([jnp.ones((n, 12)), jnp.zeros((n, 12))], axis=1)
    w0 = jax.random.normal(ks[1], (f,)) / np.sqrt(f)
    _, wf, _ = ttt_probe_scan(zq, zq, jnp.zeros((n, t)), m, w0,
                              jnp.asarray(0.0), jnp.asarray(0.05), t_chunk=8)
    _, wf_r, _ = R.ttt_probe_ref(zq, zq, jnp.zeros((n, t)), m, w0,
                                 jnp.asarray(0.0), jnp.asarray(0.05))
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wf_r), rtol=1e-4)


def test_ttt_kernel_plugs_into_core_unroll():
    """The kernel is a drop-in for the core inner loop at deployment."""
    pc = ProbeConfig(d_phi=128)
    from repro.core.probe import init_outer
    theta = init_outer(pc, jax.random.PRNGKey(0))
    phis = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 128))
    mask = jnp.ones((3, 20))
    s_core = ttt.deployed_scores(pc, theta, phis, mask)
    s_kern = ttt.deployed_scores(pc, theta, phis, mask,
                                 kernel=make_unroll_kernel(t_chunk=16))
    np.testing.assert_allclose(np.asarray(s_core), np.asarray(s_kern),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention (prefill)

@pytest.mark.parametrize("b,sq,sk,h,kv,d", [
    (1, 128, 128, 4, 4, 64),     # MHA
    (2, 128, 128, 8, 2, 64),     # GQA
    (1, 256, 256, 4, 1, 128),    # MQA, larger head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, sk, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 4, 64))
    v = jax.random.normal(ks[2], (1, 128, 4, 64))
    out = flash_attention(q, k, v, causal=True, window=32, bq=32, bk=32)
    ref = R.flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash decode

@pytest.mark.parametrize("b,h,kv,s,d", [
    (2, 8, 8, 512, 64), (2, 8, 2, 1024, 64), (1, 16, 4, 2048, 128)])
def test_flash_decode_matches_ref(b, h, kv, s, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    # partially filled cache: positions >= fill are invalid
    fill = s // 2 + 3
    valid = jnp.broadcast_to(jnp.arange(s) < fill, (b, s))
    out = flash_decode(q, k, v, valid, bs=256)
    ref = R.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_ragged_valid():
    """Per-row validity (ring buffers) is honored."""
    b, h, kv, s, d = 3, 4, 4, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    valid = jax.random.uniform(jax.random.PRNGKey(5), (b, s)) > 0.4
    valid = valid.at[:, 0].set(True)
    out = flash_decode(q, k, v, valid, bs=64)
    ref = R.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Paged flash decode (block-table gather through scalar prefetch)

@pytest.mark.parametrize("b,h,kv,d,bs,p,nb", [
    (2, 8, 8, 64, 16, 24, 6),     # MHA
    (3, 8, 2, 64, 8, 16, 4),      # GQA, small pages
    (1, 16, 4, 128, 32, 12, 8),   # MQA-ish, larger head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_matches_ref(b, h, kv, d, bs, p, nb, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, h, d)).astype(dtype)
    k_pages = jax.random.normal(ks[1], (p, kv, bs, d)).astype(dtype)
    v_pages = jax.random.normal(ks[2], (p, kv, bs, d)).astype(dtype)
    tables = jax.random.randint(ks[3], (b, nb), 0, p)
    # each row at its own length (continuous batching)
    pos = jnp.asarray([((i + 1) * nb * bs) // (b + 1) + 1 for i in range(b)])
    valid = jnp.arange(nb * bs)[None, :] < pos[:, None]
    out = paged_flash_decode(q, k_pages, v_pages, tables, valid)
    ref = R.paged_decode_ref(q, k_pages, v_pages, tables, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_paged_flash_decode_int8_kv():
    """int8 pages dequantize per VMEM block inside the kernel."""
    from repro.models.attention import quantize_kv
    b, h, kv, d, bs, p, nb = 2, 8, 4, 64, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    kq, ksc = quantize_kv(jax.random.normal(ks[1], (p, kv, bs, d)))
    vq, vsc = quantize_kv(jax.random.normal(ks[2], (p, kv, bs, d)))
    tables = jax.random.randint(ks[3], (b, nb), 0, p)
    valid = jnp.arange(nb * bs)[None, :] < jnp.asarray([[13], [29]])
    out = paged_flash_decode(q, kq, vq, tables, valid, ksc, vsc)
    ref = R.paged_decode_ref(q, kq, vq, tables, valid, ksc, vsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Paged prefill chunk (q-block > 1 extension of the paged kernel)

@pytest.mark.parametrize("b,c,h,kv,d,bs,p,nb", [
    (2, 4, 8, 8, 64, 16, 24, 6),   # MHA
    (3, 6, 8, 2, 64, 8, 16, 4),    # GQA, small pages
    (1, 16, 16, 4, 128, 32, 12, 8),  # chunk wider than a page
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_chunk_matches_ref(b, c, h, kv, d, bs, p, nb, dtype):
    """The q-block > 1 kernel's unnormalized partials equal the gathered-
    pages oracle for every chunk query row."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (b, c, h, d)).astype(dtype)
    k_pages = jax.random.normal(ks[1], (p, kv, bs, d)).astype(dtype)
    v_pages = jax.random.normal(ks[2], (p, kv, bs, d)).astype(dtype)
    tables = jax.random.randint(ks[3], (b, nb), 0, p)
    # mid-prefill: each request resumed at its own progress (>= 1 so the
    # kernel/oracle l-garbage-flush corner stays out of the raw partials)
    pos = jnp.asarray([((i + 1) * nb * bs) // (b + 1) + 1 for i in range(b)])
    valid = jnp.arange(nb * bs)[None, :] < pos[:, None]
    o, l, m = paged_flash_prefill_chunk(q, k_pages, v_pages, tables, valid)
    o_r, l_r, m_r = R.paged_prefill_chunk_ref(q, k_pages, v_pages, tables,
                                              valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for a, r in ((o, o_r), (l, l_r), (m, m_r)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol)


def test_paged_prefill_chunk_int8_kv():
    from repro.models.attention import quantize_kv
    b, c, h, kv, d, bs, p, nb = 2, 5, 8, 4, 64, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    q = jax.random.normal(ks[0], (b, c, h, d))
    kq, ksc = quantize_kv(jax.random.normal(ks[1], (p, kv, bs, d)))
    vq, vsc = quantize_kv(jax.random.normal(ks[2], (p, kv, bs, d)))
    tables = jax.random.randint(ks[3], (b, nb), 0, p)
    valid = jnp.arange(nb * bs)[None, :] < jnp.asarray([[13], [29]])
    o, l, m = paged_flash_prefill_chunk(q, kq, vq, tables, valid, ksc, vsc)
    o_r, l_r, m_r = R.paged_prefill_chunk_ref(q, kq, vq, tables, valid,
                                              ksc, vsc)
    for a, r in ((o, o_r), (l, l_r), (m, m_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


def test_prefill_chunk_q_block_one_equals_decode_kernel():
    """A C=1 chunk IS a decode step without the extra_kv column: the
    q-block > 1 kernel must reproduce paged_flash_decode's partials."""
    b, h, kv, d, bs, p, nb = 2, 8, 4, 64, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(15), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k_pages = jax.random.normal(ks[1], (p, kv, bs, d))
    v_pages = jax.random.normal(ks[2], (p, kv, bs, d))
    tables = jax.random.randint(ks[3], (b, nb), 0, p)
    valid = jnp.arange(nb * bs)[None, :] < jnp.asarray([[9], [22]])
    o_d, l_d, m_d = paged_flash_decode(q, k_pages, v_pages, tables, valid,
                                       return_partials=True)
    o_c, l_c, m_c = paged_flash_prefill_chunk(q[:, None], k_pages, v_pages,
                                              tables, valid)
    np.testing.assert_allclose(np.asarray(o_c[:, :, :, 0]), np.asarray(o_d),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(l_c[..., 0]), np.asarray(l_d),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_c[..., 0]), np.asarray(m_d),
                               rtol=2e-5, atol=2e-5)


def test_chunked_prefill_attention_pallas_matches_jnp():
    """End-to-end chunk attention (kernel partials + within-chunk causal
    merge) equals the jnp concat-softmax path, including the fully-masked
    first chunk (pos_start=0)."""
    from repro.models import attention as A
    b, c, h, kv, d, bs, p, nb = 2, 6, 8, 4, 64, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(17), 5)
    q = jax.random.normal(ks[0], (b, c, h, d))
    k_new = jax.random.normal(ks[1], (b, c, kv, d))
    v_new = jax.random.normal(ks[2], (b, c, kv, d))
    cache_l = {"k": jax.random.normal(ks[3], (p, kv, bs, d)),
               "v": jax.random.normal(ks[4], (p, kv, bs, d))}
    tables = jax.random.randint(jax.random.PRNGKey(18), (b, nb), 0, p)
    for pos_start in (0, 7):
        valid = jnp.broadcast_to(jnp.arange(nb * bs)[None, :] < pos_start,
                                 (b, nb * bs))
        o_j = A.attn_prefill_chunk(q, k_new, v_new, cache_l, valid,
                                   jnp.float32, block_tables=tables,
                                   impl="jnp")
        o_p = A.attn_prefill_chunk(q, k_new, v_new, cache_l, valid,
                                   jnp.float32, block_tables=tables,
                                   impl="pallas", interpret=True)
        assert np.isfinite(np.asarray(o_p)).all()
        np.testing.assert_allclose(np.asarray(o_j), np.asarray(o_p),
                                   rtol=2e-5, atol=2e-5)


def test_paged_matches_dense_flash_decode_when_contiguous():
    """An identity block table makes paged attention literally the dense
    cache read: both kernels must agree."""
    b, h, kv, d, bs, nb = 2, 4, 4, 64, 64, 4
    s = nb * bs
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    valid = jnp.broadcast_to(jnp.arange(s) < s - 17, (b, s))
    dense = flash_decode(q, k, v, valid, bs=bs)
    # pages for row b occupy ids [b*nb, (b+1)*nb): (B*nb, KV, bs, d)
    pages_k = k.reshape(b, kv, nb, bs, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b * nb, kv, bs, d)
    pages_v = v.reshape(b, kv, nb, bs, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b * nb, kv, bs, d)
    tables = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    paged = paged_flash_decode(q, pages_k, pages_v, tables, valid)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV scan

@pytest.mark.parametrize("b,t,h,d", [(1, 32, 2, 32), (2, 100, 4, 64)])
def test_wkv_scan_matches_ref(b, t, h, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, t, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d)))  # decay in (0,1)
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    s0 = jnp.zeros((b, h, d, d))
    out, sf = wkv_scan(r, k, v, w, u, s0, ct=16)
    out_r, sf_r = R.wkv_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_r),
                               rtol=1e-4, atol=1e-4)


def test_wkv_scan_state_carry():
    """Chunked state carry: running two halves sequentially == one pass."""
    b, t, h, d = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r = jax.random.normal(ks[0], (b, t, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    s0 = jnp.zeros((b, h, d, d))
    out_full, sf_full = wkv_scan(r, k, v, w, u, s0, ct=16)
    o1, s1 = wkv_scan(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0, ct=16)
    o2, s2 = wkv_scan(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s1, ct=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(out_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf_full),
                               rtol=1e-4, atol=1e-4)
