"""Unit + property tests for LTT calibration, conformal quantile, stopping."""
import math

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import calibration as C
from repro.core import stopping as S
from repro.core import labels as L


# ---------------------------------------------------------------------------
# binomial p-value

def test_binom_cdf_matches_bruteforce():
    from math import comb
    n, p = 37, 0.13
    for k in [0, 1, 5, 17, 36, 37]:
        brute = sum(comb(n, i) * p**i * (1-p)**(n-i) for i in range(0, k+1))
        assert C.binom_cdf(k, n, p) == pytest.approx(brute, rel=1e-9)


@given(st.integers(10, 500), st.floats(0.01, 0.5), st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_pvalue_in_unit_interval_and_monotone(n, delta, risk):
    p = C.binomial_pvalue(risk, n, delta)
    assert 0.0 <= p <= 1.0
    p_hi = C.binomial_pvalue(min(risk + 0.1, 1.0), n, delta)
    assert p_hi >= p - 1e-12  # higher empirical risk => larger p-value


@given(st.integers(20, 300), st.floats(0.02, 0.3))
@settings(max_examples=30, deadline=None)
def test_pvalue_superuniform_under_null(n, delta):
    """Under H: r >= delta, P(p <= eps) <= eps (validity of the test)."""
    rs = np.random.RandomState(0)
    eps = 0.1
    rejections = 0
    trials = 200
    for _ in range(trials):
        risks = rs.rand(n) < delta  # risk exactly delta (boundary of null)
        p = C.binomial_pvalue(risks.mean(), n, delta)
        rejections += p <= eps
    # allow generous slack: 3 sigma of binomial(trials, eps)
    bound = eps * trials + 3 * math.sqrt(trials * eps * (1 - eps))
    assert rejections <= bound


def test_ltt_fixed_sequence_stops_at_first_failure():
    # columns: risk 0, 0, high, 0 -> FST must stop at the high column
    n = 200
    risk = np.zeros((n, 4))
    risk[:150, 2] = 1.0
    grid = [0.9, 0.8, 0.7, 0.6]
    res = C.ltt_calibrate(risk, grid, delta=0.1, eps=0.05)
    assert res.rejected.tolist() == [True, True, False, False]
    assert res.lam == 0.8


def test_ltt_no_rejection_returns_inf():
    n = 50
    risk = np.ones((n, 3))
    res = C.ltt_calibrate(risk, [0.9, 0.8, 0.7], delta=0.1, eps=0.05)
    assert math.isinf(res.lam)


@given(st.integers(50, 400), st.floats(0.05, 0.3), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_ltt_guarantee_on_synthetic_monotone_risk(n, delta, seed):
    """End-to-end LTT validity: with monotone true risk over the grid, the
    selected lambda* has true risk <= delta with high probability."""
    rs = np.random.RandomState(seed)
    grid = np.linspace(0.95, 0.05, 19)
    true_risk = np.clip(1.0 - grid, 0, 1) * 0.6       # increasing w/ aggression
    risk = (rs.rand(n, len(grid)) < true_risk[None]).astype(float)
    res = C.ltt_calibrate(risk, grid, delta=delta, eps=0.05)
    if not math.isinf(res.lam):
        j = int(np.argmax(res.grid == res.lam))
        # this single draw should essentially always satisfy the guarantee;
        # allow the eps slack by checking against delta directly
        assert true_risk[j] <= delta + 0.12  # loose: single-run check


def test_conformal_quantile_coverage():
    rs = np.random.RandomState(3)
    eps = 0.1
    hits = []
    for _ in range(300):
        cal = rs.randn(99)
        q = C.conformal_quantile(cal, eps)
        hits.append(rs.randn() <= q)
    assert np.mean(hits) >= 1 - eps - 0.05


# ---------------------------------------------------------------------------
# stopping rule metrics

def test_stop_times_first_crossing():
    scores = np.array([[0.1, 0.2, 0.9, 0.95, 0.1]])
    mask = np.ones((1, 5), bool)
    tau = S.stop_times(scores, [0.9, 0.5], mask, burn_in=0)
    assert tau.tolist() == [[2, 2]]
    tau = S.stop_times(scores, [0.99], mask, burn_in=0)
    assert tau.tolist() == [[5]]  # budget exhausted


def test_stop_times_burn_in():
    scores = np.array([[0.99, 0.99, 0.2, 0.95, 0.1]])
    mask = np.ones((1, 5), bool)
    tau = S.stop_times(scores, [0.9], mask, burn_in=2)
    assert tau.tolist() == [[3]]  # early crossings suppressed during burn-in


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_stop_time_monotone_in_lambda(l1, l2):
    """tau_lambda is nondecreasing in lambda (more conservative = later)."""
    rs = np.random.RandomState(5)
    scores = rs.rand(20, 30)
    mask = np.ones((20, 30), bool)
    hi, lo = max(l1, l2), min(l1, l2)
    tau = S.stop_times(scores, [hi, lo], mask, burn_in=0)
    assert (tau[:, 0] >= tau[:, 1]).all()


def test_risk_only_counts_premature_stops():
    labels = np.array([[0, 0, 1, 1, 1]], float)
    mask = np.ones((1, 5), bool)
    tau = np.array([[1, 2, 5]])  # early-wrong, at-transition, budget
    risk = S.procedure_risk(tau, labels, mask)
    assert risk.tolist() == [[1.0, 0.0, 0.0]]


def test_savings_metric():
    mask = np.ones((2, 10), bool)
    tau = np.array([[4], [9]])   # stops after 5th/10th step
    sav = S.savings(tau, mask)
    assert sav[0] == pytest.approx((0.5 + 0.0) / 2)


# ---------------------------------------------------------------------------
# labels

def test_supervised_labels_cumulative():
    correct = np.array([[0, 0, 1, 0, 1]])
    lab = L.supervised_labels(correct)
    assert lab.tolist() == [[0, 0, 1, 1, 1]]


def test_consistent_labels_suffix_stable():
    answers = np.array([[3, 5, 7, 7, 7]])
    lab = L.consistent_labels(answers)
    assert lab.tolist() == [[0, 0, 1, 1, 1]]
    answers = np.array([[3, 7, 5, 7, 7]])   # flickers away from final at t=2
    lab = L.consistent_labels(answers)
    assert lab.tolist() == [[0, 0, 0, 1, 1]]


def test_consistent_labels_with_mask():
    answers = np.array([[3, 7, 7, 0, 0]])
    mask = np.array([[1, 1, 1, 0, 0]], bool)
    lab = L.consistent_labels(answers, mask)
    assert lab[0, :3].tolist() == [0, 1, 1]
    assert lab[0, 3:].tolist() == [0, 0]


def test_transition_time():
    lab = np.array([[0, 0, 1, 1], [0, 0, 0, 0]], float)
    tt = L.transition_time(lab)
    assert tt.tolist() == [2, 4]
