"""Unit tests for the roofline analysis: HLO collective parsing, analytic
FLOP/byte model, report assembly."""

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline import analytic, build_report, parse_collectives
from repro.roofline.analysis import _shape_bytes

HLO = """\
HloModule jit_train_step

%while_body.1 (arg: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %p = f32[8,128]{1,0} parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[16,256]{1,0} all-gather(%y), dimensions={1}
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %top = f32[1024]{0} all-reduce(%a), replica_groups={}
  %cp = f32[512]{0} collective-permute(%b)
  ROOT %r = f32[4]{0} add(%a, %a)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("bf16[16,256]") == 16 * 256 * 2
    assert _shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_loop_multiplier():
    stats = parse_collectives(HLO, loop_multiplier=10)
    # in-body ops x10, entry ops x1
    want_ar = 8 * 128 * 4 * 10 + 1024 * 4
    want_ag = 16 * 256 * 2 * 10
    want_cp = 512 * 4
    assert stats.by_op["all-reduce"] == want_ar
    assert stats.by_op["all-gather"] == want_ag
    assert stats.by_op["collective-permute"] == want_cp
    assert stats.count == 4
    assert stats.bytes_total == want_ar + want_ag + want_cp


def test_analytic_moe_active_vs_full():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    n_full = analytic.non_embedding_params(cfg)
    n_act = analytic.non_embedding_params(cfg, active=True)
    assert n_act < n_full
    # 16 experts top-2: ffn params ratio ~ 2/16 -> active well under half
    assert n_act / n_full < 0.45
    # ballpark the config name: ~42B total, ~6.6B active (non-embedding)
    assert 25e9 < n_full < 60e9
    assert 3e9 < n_act < 10e9


def test_analytic_decode_memory_dominated_by_cache_or_weights():
    cfg = get_config("qwen1.5-32b")
    est = analytic.estimate(cfg, INPUT_SHAPES["decode_32k"])
    # decode reads >= active weights once
    assert est.bytes >= analytic.non_embedding_params(cfg, active=True) * 2
    # one token per sequence: tiny model_flops vs train
    est_tr = analytic.estimate(cfg, INPUT_SHAPES["train_4k"])
    assert est.model_flops < est_tr.model_flops / 1000


def test_analytic_sliding_window_caps_decode_context():
    cfg = get_config("llama3.2-3b")
    e_long = analytic.estimate(cfg, INPUT_SHAPES["long_500k"])
    e_dec = analytic.estimate(cfg, INPUT_SHAPES["decode_32k"])
    # 500k sliding-window decode attends <= window (8192) < 32768 full cache,
    # but decode_32k has batch 128 vs 1 — compare per-sequence context bytes
    ctx_long = analytic.attention_context(cfg, INPUT_SHAPES["long_500k"])
    ctx_dec = analytic.attention_context(cfg, INPUT_SHAPES["decode_32k"])
    assert ctx_long == cfg.long_context_window
    assert ctx_dec == 32768.0


def test_build_report_terms_and_dominance():
    cfg = get_config("smollm-360m")
    rep = build_report(cfg, INPUT_SHAPES["train_4k"], "16x16", 256, HLO,
                       cost={"flops": 1e12, "bytes accessed": 1e9})
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.t_compute > 0 and rep.t_memory > 0
    assert 0 < rep.flops_ratio <= 1.0
    assert rep.cost_analysis_flops == 1e12
    # train is compute-bound for this config at these constants
    assert rep.dominant == "compute"
