"""Paged KV-cache serving: pool invariants, write/read round trips,
engine-level dense-vs-paged stop parity (jnp + forced-interpret Pallas,
fp32 + int8 KV), prefix sharing, pool exhaustion backpressure, and a
hypothesis sweep over admit/release/stop orderings."""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.models import attention as attn
from repro.serving import (BlockPool, NULL_BLOCK, OrcaScheduler,
                           RequestState, ServeConfig, blocks_needed,
                           make_request, prompt_key)

from tests._hypothesis_stub import given, settings, st


# ---------------------------------------------------------------------------
# BlockPool unit invariants

def test_pool_allocate_free_refcount_roundtrip():
    pool = BlockPool(num_blocks=8, block_size=4)
    assert pool.num_usable == 7 and pool.num_free == 7
    row = pool.allocate(3)
    assert row is not None and len(set(row)) == 3
    assert NULL_BLOCK not in row
    assert pool.blocks_in_use == 3
    assert all(pool.refcount(b) == 1 for b in row)
    pool.free(row)
    assert pool.num_free == 7
    assert all(pool.refcount(b) == 0 for b in row)
    pool.check()


def test_pool_allocation_is_all_or_nothing():
    pool = BlockPool(num_blocks=5, block_size=4)    # 4 usable
    assert pool.allocate(5) is None                 # doesn't fit
    assert pool.num_free == 4                       # pool untouched
    row = pool.allocate(4)
    assert row is not None
    assert pool.allocate(1) is None
    pool.free(row[:1])
    assert pool.allocate(1) is not None
    pool.check()


def test_pool_sharing_refcounts_and_registry_invalidation():
    pool = BlockPool(num_blocks=10, block_size=4)
    row = pool.allocate(4)
    key = prompt_key(np.arange(8))                  # 8 tokens = 2 full blocks
    pool.register_prefix(key, row[:2], None, 8)
    entry = pool.lookup_prefix(key)
    assert entry is not None and entry.full_blocks == tuple(row[:2])
    shared = pool.share(entry.full_blocks)
    assert all(pool.refcount(b) == 2 for b in shared)
    pool.free(row)                                  # donor leaves
    assert all(pool.refcount(b) == 1 for b in shared)
    assert pool.lookup_prefix(key) is not None      # sharers keep it alive
    pool.free(shared)                               # last sharer leaves
    assert all(pool.refcount(b) == 0 for b in shared)
    assert pool.lookup_prefix(key) is None          # dead blocks -> no entry
    assert pool.num_free == pool.num_usable
    pool.check()


def test_pool_double_free_asserts():
    pool = BlockPool(num_blocks=4, block_size=4)
    row = pool.allocate(1)
    pool.free(row)
    with pytest.raises(AssertionError):
        pool.free(row)


# ---------------------------------------------------------------------------
# page write / prefill round trips against the dense layout

def test_cache_write_paged_matches_dense_lane():
    cfg = get_config("smollm_360m").reduced()
    L, B, bs, nb = cfg.n_layers, 3, 4, 4
    cache_len = nb * bs
    dense = attn.init_cache(cfg, B, cache_len)
    pages = attn.init_paged_cache(cfg, 1 + B * nb, bs)
    rows = jnp.asarray([[1 + b * nb + j for j in range(nb)]
                        for b in range(B)], jnp.int32)
    rng = jax.random.PRNGKey(0)
    pos = jnp.asarray([0, 5, 11], jnp.int32)
    for step in range(3):
        ks = jax.random.normal(rng, (L, B, cfg.n_kv_heads, cfg.d_head))
        vs = ks * 0.5
        dense = attn.cache_write_stacked(dense, ks, vs, pos + step)
        pages = attn.cache_write_paged(pages, ks, vs, rows, pos + step)
    # virtual position j of row b lives at pages[rows[b, j//bs], :, j%bs]
    for b in range(B):
        for step in range(3):
            j = int(pos[b]) + step
            np.testing.assert_array_equal(
                np.asarray(pages["k"][:, rows[b, j // bs], :, j % bs]),
                np.asarray(dense["k"][:, b, :, j]))


def test_prefill_to_pages_round_trip():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bs, S = 4, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    prefill_cache, _, _ = model.prefill(cfg, params, {"tokens": tokens}, S)
    pages = attn.init_paged_cache(cfg, 4, bs)
    row = np.array([2, 1, 3], np.int32)       # deliberately out of order
    pages = attn.prefill_to_pages(pages, prefill_cache, row, S // bs)
    for j in range(S):
        np.testing.assert_array_equal(
            np.asarray(pages["k"][:, row[j // bs], :, j % bs]),
            np.asarray(prefill_cache["k"][:, 0, :, j]))


# ---------------------------------------------------------------------------
# engine-level parity: paged serving == dense serving, stop for stop

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias, smooth_window=2):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=smooth_window)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _prompts(mcfg, n, prompt_len=8, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, prompt_len), 0,
                              mcfg.vocab_size)


def _run_pair(model, params, scfg, prompts, paged_kwargs):
    pc, theta = _probe(model.cfg, 3.0)
    dense = OrcaScheduler(model, params, pc, theta, scfg, n_slots=2)
    d_done, _ = dense.run([make_request(p) for p in prompts])
    paged = OrcaScheduler(model, params, pc, theta, scfg, n_slots=2,
                          paged=True, **paged_kwargs)
    p_done, p_fleet = paged.run([make_request(p) for p in prompts])
    assert [r.stop_step for r in d_done] == [r.stop_step for r in p_done]
    for a, b in zip(d_done, p_done):
        np.testing.assert_allclose(np.array(a.scores), np.array(b.scores),
                                   atol=1e-4)
    return p_done, p_fleet, paged


def test_paged_scheduler_matches_dense_stop_decisions(small_model):
    model, params = small_model
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6,
                       burn_in=1)
    prompts = _prompts(model.cfg, 5)
    p_done, _, paged = _run_pair(model, params, scfg, prompts,
                                 dict(block_size=4))
    # eviction returned every page: refcounts all zero after the run
    for r in p_done:
        assert r.block_ids and all(paged.pool.refcount(b) == 0
                                   for b in r.block_ids)
    paged.pool.check()


def test_paged_pallas_impl_matches_dense(small_model, monkeypatch):
    """The Pallas paged-attention kernel (forced interpret off-TPU) serves
    the same stop decisions as the dense engine."""
    monkeypatch.setenv("REPRO_PAGED_ATTN", "pallas")
    model, params = small_model
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                       burn_in=1)
    prompts = _prompts(model.cfg, 3)
    _run_pair(model, params, scfg, prompts, dict(block_size=4))


def test_paged_int8_kv_matches_dense_int8():
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                       burn_in=1)
    prompts = _prompts(cfg, 3)
    _run_pair(model, params, scfg, prompts, dict(block_size=4))


def test_prefix_sharing_stops_and_refcounts(small_model):
    """N self-consistency samples of one prompt: prefill runs once, full
    prompt pages are shared, every sample stops exactly like a solo run,
    and refcounts return to zero after all sharers stop."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=12, lam=0.6,
                       burn_in=1)
    prompt = _prompts(model.cfg, 1, prompt_len=8)[0]   # 2 full blocks @ bs 4
    sched = OrcaScheduler(model, params, pc, theta, scfg, n_slots=4,
                          paged=True, block_size=4)
    done, fleet = sched.run([make_request(prompt) for _ in range(4)])
    assert fleet.prefill_skips == 3
    shared = done[0].block_ids[:2]
    for r in done[1:]:
        assert r.n_shared_blocks == 2
        assert r.block_ids[:2] == shared           # the prompt stored ONCE
    solo = OrcaScheduler(model, params, pc, theta, scfg, n_slots=1)
    s_done, _ = solo.run([make_request(prompt)])
    assert {r.stop_step for r in done} == {s_done[0].stop_step}
    assert all(sched.pool.refcount(b) == 0 for b in shared)
    sched.pool.check()


def test_pool_exhaustion_backpressures_without_overadmitting(small_model):
    """Pool smaller than the offered load: requests WAIT (FIFO), nothing
    crashes, no page is owned by two live requests, every request reaches a
    terminal state with dense-identical stop decisions."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=16, lam=0.6,
                       burn_in=1)
    prompts = _prompts(model.cfg, 6)
    per_req = blocks_needed(8 + 16, 4)                 # 6 pages each
    sched = OrcaScheduler(model, params, pc, theta, scfg, n_slots=4,
                          paged=True, block_size=4,
                          num_blocks=1 + 2 * per_req,  # only 2 fit at once
                          prefix_sharing=False)
    done, fleet = sched.run([make_request(p) for p in prompts])
    assert all(r.done for r in done)
    assert fleet.peak_blocks_in_use <= 2 * per_req
    # overlapping-in-time requests may never hold the same page (sharing off)
    for a, b in itertools.combinations(done, 2):
        overlap = not (a.completed_step <= b.admitted_step
                       or b.completed_step <= a.admitted_step)
        if overlap:
            assert not set(a.block_ids) & set(b.block_ids), (a, b)
    # backpressure showed up as queueing beyond the slot count
    assert fleet.mean_queue_steps > 0
    dense = OrcaScheduler(model, params, pc, theta, scfg, n_slots=4)
    d_done, _ = dense.run([make_request(p) for p in prompts])
    assert [r.stop_step for r in d_done] == [r.stop_step for r in done]


def test_paged_vlm_prefix_reserved_and_decode_resumes_after_it():
    """A vlm's patch prefix is part of the prefill sequence: the paged
    reservation covers prefix + decode budget, the auto-sized pool fits it,
    and decode resumes AFTER the whole prefix (pos = patches + prompt) so
    prompt K/V is readable and never clobbered."""
    cfg = get_config("llava_next_34b").reduced()     # 16 patch tokens
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pc, theta = _probe(cfg, 3.0)
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                       burn_in=1)
    # prefix = 4 prompt + 16 patches = 20; need 20 + 8 decode = 28 tokens
    prompts = _prompts(cfg, 3, prompt_len=4)
    patches = jnp.zeros((1, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    reqs = [make_request(p, extra={"patch_embeds": patches})
            for p in prompts]
    sched = OrcaScheduler(model, params, pc, theta, scfg, n_slots=2,
                          paged=True, block_size=4)
    done, fleet = sched.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.block_ids) == blocks_needed(20 + 8, 4) for r in done)
    assert fleet.pool_blocks >= 2 * blocks_needed(20 + 8, 4)
    # engine-level: admission parks the decode cursor after the prefix
    eng = sched._engine
    eng.admit(0, reqs[0].inputs, reqs[0].prompt_len,
              block_row=sched.pool.allocate(blocks_needed(28, 4)))
    assert int(eng.pos[0]) == 20


def test_oversized_request_raises_instead_of_hanging(small_model):
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=64, lam=0.6,
                       burn_in=1)
    sched = OrcaScheduler(model, params, pc, theta, scfg, n_slots=2,
                          paged=True, block_size=4, num_blocks=4)
    with pytest.raises(RuntimeError, match="pool holds"):
        sched.run([make_request(_prompts(model.cfg, 1)[0])])


# ---------------------------------------------------------------------------
# hypothesis sweep: no page simultaneously owned by two live requests under
# arbitrary admit / share / stop orderings

@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7)),
                    min_size=1, max_size=60))
def test_pool_fuzz_no_double_ownership(ops):
    pool = BlockPool(num_blocks=12, block_size=4)
    live = {}                     # req id -> (blocks, n_shared)
    next_id = 0
    keys = [prompt_key(np.arange(8) + g) for g in range(3)]
    for op, arg in ops:
        if op == 0:               # admit (maybe sharing group arg % 3)
            key = keys[arg % 3]
            entry = pool.lookup_prefix(key)
            if entry is not None:
                private = pool.allocate(2)
                if private is None:
                    continue
                blocks = pool.share(entry.full_blocks) + private
                live[next_id] = (blocks, len(entry.full_blocks))
            else:
                blocks = pool.allocate(4)
                if blocks is None:
                    continue
                pool.register_prefix(key, blocks[:2], None, 8)
                live[next_id] = (blocks, 0)
            next_id += 1
        elif op == 1 and live:    # ORCA stop: free everything
            rid = sorted(live)[arg % len(live)]
            blocks, _ = live.pop(rid)
            pool.free(blocks)
        # op == 2: no-op step
        pool.check()
        # private pages are exclusively owned; only registered full-prefix
        # pages may appear in two live requests
        owned = {}
        for rid, (blocks, n_shared) in live.items():
            for b in blocks[n_shared:]:
                assert b not in owned, f"page {b} owned twice"
                owned[b] = rid
    for blocks, _ in live.values():
        pool.free(blocks)
    assert pool.num_free == pool.num_usable
