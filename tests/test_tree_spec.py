"""Tree speculative decode + the fleet-wide shared draft cache.

The tentpole invariants: the per-token ancestor mask equals its explicit
root-path oracle (and collapses to the PR-9 block-causal chain at
width 1, bit for bit); packed attention under a tree mask is
pallas==jnp; serving with ``spec_tree="W.D"`` produces IDENTICAL stop
decisions, token streams and score trajectories to one-token decode
across tree shape x policy x packing x paged x int8 x forced preemption
x grouped consensus; the width-1 tree serves step-for-step identically
to PR-9 linear ``spec_tokens``; the token budget is never exceeded and
pages always drain; ONE step executable covers every draft-cache
hit/miss mix; spilling a mid-tree-verify slot restores bit-for-bit; and
the scheduler and router aggregate speculation metrics through the SAME
helper (they can never drift).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.kernels import ref as R
from repro.models import build
from repro.models.attention import attn_prefill_packed, packed_chunk_mask
from repro.serving import (ContinuousServingEngine, DraftCache, FleetRouter,
                           OrcaScheduler, RequestState, ServeConfig,
                           make_request, replay_model, replay_params,
                           replay_requests, served_stop_times, spec_stats)

from tests._hypothesis_stub import given, settings, st


# ---------------------------------------------------------------------------
# ancestor mask: fori_loop closure vs explicit root-path oracle

def _comb_layout(n_segs, width, depth):
    """The scheduler's BFS comb layout, packed contiguously: per segment
    1 + width*depth nodes; node 0 is the root (self-pointing), node
    1 + j*width + b is branch b at depth j+1, parent = root for j == 0
    else the same branch one level up."""
    kk = 1 + width * depth
    seg, anc = [], []
    for s in range(n_segs):
        off = s * kk
        seg.extend([s] * kk)
        anc.append(off)                       # root -> itself
        for j in range(depth):
            for b in range(width):
                i = 1 + j * width + b
                anc.append(off if j == 0 else off + i - width)
    return (jnp.asarray(seg, jnp.int32), jnp.asarray(anc, jnp.int32), kk)


@pytest.mark.parametrize("n_segs,width,depth", [
    (1, 2, 3), (2, 3, 2), (3, 1, 4), (2, 4, 1),
])
def test_ancestor_mask_matches_ref(n_segs, width, depth):
    """The fori_loop reachability closure equals the python root-path
    walk for comb trees of every aspect ratio, multi-segment packs
    included — and padding never serves as a key."""
    seg, anc, kk = _comb_layout(n_segs, width, depth)
    c = n_segs * kk
    valid = jnp.asarray([i % 5 != 3 for i in range(c)], bool)
    got = packed_chunk_mask(seg, valid, anc)
    want = R.packed_chunk_mask_ref(seg, valid, anc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # no token ever attends another segment's nodes
    s = np.asarray(seg)
    assert not np.asarray(got)[s[:, None] != s[None, :]].any()
    # every node attends itself when valid
    diag = np.diag(np.asarray(got))
    np.testing.assert_array_equal(diag, np.asarray(valid))


def test_ancestor_mask_random_trees_match_ref():
    """Arbitrary (non-comb) parent pointers: any forest where parents
    precede children within their segment agrees with the oracle."""
    rs = np.random.RandomState(7)
    for trial in range(5):
        bounds = [0, 4, 9, 14]
        c = bounds[-1]
        seg = np.zeros((c,), np.int32)
        anc = np.zeros((c,), np.int32)
        for s in range(len(bounds) - 1):
            lo, hi = bounds[s], bounds[s + 1]
            seg[lo:hi] = s
            anc[lo] = lo                       # root self-points
            for i in range(lo + 1, hi):
                anc[i] = rs.randint(lo, i)     # any earlier node
        valid = rs.rand(c) > 0.2
        got = packed_chunk_mask(jnp.asarray(seg), jnp.asarray(valid),
                                jnp.asarray(anc))
        want = R.packed_chunk_mask_ref(jnp.asarray(seg), jnp.asarray(valid),
                                       jnp.asarray(anc))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"trial {trial}")


def test_width_one_tree_mask_equals_causal_chain():
    """The degenerate width-1 tree (each node's parent is the previous
    segment token) IS the PR-9 linear verify: its ancestor mask equals
    the block-causal chunk mask bit for bit."""
    seg = jnp.asarray([0, 0, 0, 1, 1, 1, 1, 2], jnp.int32)
    c = int(seg.shape[0])
    s = np.asarray(seg)
    anc = np.arange(c, dtype=np.int32)
    for i in range(1, c):
        if s[i] == s[i - 1]:
            anc[i] = i - 1                     # chain; roots self-point
    valid = jnp.asarray([1, 1, 0, 1, 1, 1, 1, 1], bool)
    tree = packed_chunk_mask(seg, valid, jnp.asarray(anc))
    causal = packed_chunk_mask(seg, valid)
    np.testing.assert_array_equal(np.asarray(tree), np.asarray(causal))


def test_packed_attention_tree_mask_pallas_equals_jnp():
    """Full packed attention under an ancestor mask: the pallas kernel's
    cache partials + the masked within-chunk merge equal the jnp
    single-softmax path.  The kernel never sees the tree — the ancestor
    structure lives entirely in the merge mask."""
    seg, anc, kk = _comb_layout(2, 2, 2)       # two 5-node trees
    c = int(seg.shape[0])
    h, kv, d, bs, p, nb = 8, 4, 64, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(41), 5)
    q = jax.random.normal(ks[0], (c, h, d))
    k_new = jax.random.normal(ks[1], (c, kv, d))
    v_new = jax.random.normal(ks[2], (c, kv, d))
    cache = {"k": jax.random.normal(ks[3], (p, kv, bs, d)),
             "v": jax.random.normal(ks[4], (p, kv, bs, d))}
    tables = jax.random.randint(ks[0], (2, nb), 0, p)
    starts = jnp.asarray([13, 6], jnp.int32)
    mask = packed_chunk_mask(seg, jnp.ones((c,), bool), anc)
    out_j = attn_prefill_packed(q, k_new, v_new, cache, seg, starts, mask,
                                jnp.float32, seg_tables=tables, impl="jnp")
    out_p = attn_prefill_packed(q, k_new, v_new, cache, seg, starts, mask,
                                jnp.float32, seg_tables=tables,
                                impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ServeConfig: spec_tree parsing + validation

def test_spec_tree_config_validation():
    assert ServeConfig(spec_tree="2.3").tree_shape() == (2, 3)
    # tuples and "" normalize like the other CLI-facing optionals
    assert ServeConfig(spec_tree=(2, 3)).spec_tree == "2.3"
    assert ServeConfig(spec_tree="").spec_tree is None
    with pytest.raises(ValueError, match="not 'W.D'"):
        ServeConfig(spec_tree="2x3").validate()
    with pytest.raises(ValueError, match="is ambiguous"):
        ServeConfig(spec_tree="2.2", spec_tokens=4).validate()
    with pytest.raises(ValueError, match="width >= 1"):
        ServeConfig(spec_tree="0.3").validate()
    # 1 + W*D nodes must fit under chunk_tokens and token_budget
    with pytest.raises(ValueError, match="chunk_tokens"):
        ServeConfig(spec_tree="2.3", chunk_tokens=7).validate()
    with pytest.raises(ValueError, match="token_budget"):
        ServeConfig(spec_tree="3.3", token_budget=9).validate()
    ServeConfig(spec_tree="2.2", chunk_tokens=8, token_budget=12).validate()
    with pytest.raises(ValueError, match="draft_cache_size"):
        ServeConfig(draft_cache_size=-1).validate()


def test_spec_tree_warns_and_falls_back_without_support():
    cfg = get_config("rwkv6_1b6").reduced()
    model = build(cfg)
    assert not model.supports_tree
    pc = ProbeConfig(d_phi=cfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=6, lam=2.0,
                       burn_in=0, spec_tree="2.2")
    with pytest.warns(RuntimeWarning, match="ignored"):
        sched = OrcaScheduler(model, None, pc, theta, scfg, n_slots=2)
    assert sched.spec_tree is None and sched.spec_tokens is None
    assert sched.draft_cache is None


def test_spec_tree_with_spec_tokens_is_rejected():
    model = replay_model(np.zeros((2, 4, 8), np.float32))
    pc = ProbeConfig(d_phi=8, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ambiguous"):
        OrcaScheduler(model, replay_params(np.zeros((2, 4, 8), np.float32)),
                      pc, theta, ServeConfig(max_new_tokens=4),
                      n_slots=1, spec_tokens=3, spec_tree="2.2")


# ---------------------------------------------------------------------------
# DraftCache: the fleet-wide shared n-gram drafter

def test_draft_cache_observe_then_lookup():
    dc = DraftCache(capacity=16, ngram=2, fanout=2, store_len=4)
    dc.observe([1, 2, 3], [4, 5, 6])
    drafts, hit = dc.lookup([2, 3], width=2, depth=3)
    assert hit and dc.hits == 1
    np.testing.assert_array_equal(drafts[0], [4, 5, 6])
    # a second continuation of the same n-gram fills branch 1 (MRU first)
    dc.observe([1, 2, 3], [7, 8, 9])
    drafts, hit = dc.lookup([2, 3], width=2, depth=3)
    assert hit
    np.testing.assert_array_equal(drafts[0], [7, 8, 9])
    np.testing.assert_array_equal(drafts[1], [4, 5, 6])
    # misses count and return the model-fallback signal
    _, hit = dc.lookup([99, 98], width=2, depth=3)
    assert not hit and dc.misses == 1
    assert 0 < dc.hit_rate < 1


def test_draft_cache_chained_extension_and_padding():
    """Short stored continuations extend through chained lookups of
    their own tail; depth beyond all knowledge pads the tail token."""
    dc = DraftCache(capacity=16, ngram=2, fanout=2, store_len=2)
    dc.observe([1, 2], [3, 4])                # (1,2) -> (3,4)
    dc.observe([3, 4], [5, 6])                # (3,4) -> (5,6)
    drafts, hit = dc.lookup([1, 2], width=1, depth=6)
    assert hit
    np.testing.assert_array_equal(drafts[0], [3, 4, 5, 6, 6, 6])


def test_draft_cache_lru_eviction_and_fanout_trim():
    dc = DraftCache(capacity=2, ngram=1, fanout=2, store_len=2)
    dc.observe([1], [10])
    dc.observe([2], [20])
    dc.observe([3], [30])                     # evicts the LRU key
    assert len(dc) == 2
    _, hit = dc.lookup([1], 1, 1)
    assert not hit                            # (1,) was evicted
    for t in (40, 41, 42):
        dc.observe([3], [t])
    drafts, hit = dc.lookup([3], width=3, depth=1)
    assert hit
    # fanout=2: only the two most recent survive, cycled across branches
    np.testing.assert_array_equal(drafts[:, 0], [42, 41, 42])


def test_draft_cache_prefix_supersede_dedup():
    """Re-accepting a longer continuation of the same n-gram replaces
    its shorter prefix instead of duplicating it."""
    dc = DraftCache(capacity=8, ngram=1, fanout=4, store_len=4)
    dc.observe([1], [2])
    dc.observe([1], [2, 3, 4])
    drafts, hit = dc.lookup([1], width=2, depth=3)
    assert hit
    np.testing.assert_array_equal(drafts[0], [2, 3, 4])
    np.testing.assert_array_equal(drafts[1], [2, 3, 4])   # cycled, not (2,)


def test_draft_cache_is_deterministic():
    def run():
        dc = DraftCache(capacity=32, ngram=3, fanout=4, store_len=4)
        rs = np.random.RandomState(3)
        out = []
        for _ in range(50):
            ctx = rs.randint(0, 5, size=3).tolist()
            dc.observe(ctx, rs.randint(0, 5, size=4).tolist())
            d, h = dc.lookup(ctx, 2, 3)
            out.append((d.tolist(), h))
        return out, dc.hits, dc.misses, len(dc)
    assert run() == run()


# ---------------------------------------------------------------------------
# replay fleets: byte-identical serving under partial acceptance

def _tree_setup(seed=0, n=10, t=16, d=16, prompt_len=4, wrong=0.4):
    rs = np.random.RandomState(seed)
    bank = (rs.randn(n, t, d) * 0.6).astype(np.float32)
    model = replay_model(bank, prompt_len=prompt_len,
                         draft_wrong_rate=wrong)
    params = replay_params(bank)
    pc = ProbeConfig(d_phi=d, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(2))
    theta["b0"] = jnp.asarray(0.4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=2)
    return model, params, pc, theta, cfg, bank


def _reqs(bank, ids, prompt_len=4):
    return [make_request(np.full((prompt_len,), i, np.int64),
                         max_new_tokens=int(bank.shape[1]))
            for i in ids]


def _assert_identical(done_a, done_b, *, exact_scores=True, atol=1e-4):
    assert [r.stop_step for r in done_a] == [r.stop_step for r in done_b]
    assert [r.steps_run for r in done_a] == [r.steps_run for r in done_b]
    assert [r.tokens for r in done_a] == [r.tokens for r in done_b]
    for ra, rb in zip(done_a, done_b):
        a, b = np.asarray(ra.scores), np.asarray(rb.scores)
        if exact_scores:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, atol=atol)


@pytest.mark.parametrize("tree", ["1.3", "2.2", "3.2", "2.3"])
def test_replay_tree_serves_identical_and_saves_steps(tree):
    """Partial-acceptance tree fleets: stops, tokens and scores
    byte-equal to one-token decode for every tree aspect ratio, with
    fewer engine steps and populated tree metrics."""
    model, params, pc, theta, cfg, bank = _tree_setup()
    ids = range(bank.shape[0])
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3)
    done_o, fleet_o = base.run(_reqs(bank, ids))
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                          spec_tree=tree)
    done_s, fleet_s = sched.run(_reqs(bank, ids))
    _assert_identical(done_o, done_s)
    assert fleet_s.engine_steps < fleet_o.engine_steps
    assert fleet_s.tree_nodes_proposed > 0
    assert fleet_s.tree_nodes_proposed == sum(r.tree_nodes for r in done_s)
    assert fleet_s.tree_path_accepted_p99 >= fleet_s.tree_path_accepted_p50
    assert 0 < fleet_s.acceptance_rate <= 1.0
    counts = sched._engine.compile_counts()
    assert counts["step"] == 1, counts


def test_width_one_tree_equals_linear_spec_step_for_step():
    """spec_tree='1.3' IS spec_tokens=4: the same done lists, the same
    engine step count, the same acceptance counters — the probe kernel
    and acceptance rule are shared, not merely equivalent."""
    model, params, pc, theta, cfg, bank = _tree_setup(wrong=0.4)
    ids = range(bank.shape[0])
    lin = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                        spec_tokens=4)
    done_l, fleet_l = lin.run(_reqs(bank, ids))
    tr = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                       spec_tree="1.3")
    done_t, fleet_t = tr.run(_reqs(bank, ids))
    _assert_identical(done_l, done_t)
    assert fleet_t.engine_steps == fleet_l.engine_steps
    assert fleet_t.spec_tokens_proposed == fleet_l.spec_tokens_proposed
    assert fleet_t.spec_tokens_accepted == fleet_l.spec_tokens_accepted


def test_tree_consensus_groups_identical_and_cancelled_excluded():
    """Grouped consensus under tree decode: same groups fire at the same
    step, same siblings cancel, CANCELLED samples excluded from both the
    acceptance and the tree stats."""
    n_groups, gsz, t = 3, 3, 10
    n = n_groups * gsz
    rs = np.random.RandomState(6)
    drift = np.linspace(0, 1.0, t)[None, :, None]
    bank = (rs.randn(n, t, 8) * 0.3
            + drift * rs.rand(n, 1, 8)).astype(np.float32)
    answers = np.repeat(np.arange(n_groups), gsz)
    model = replay_model(bank, answers=answers, draft_wrong_rate=0.35)
    params = replay_params(bank, answers=answers)
    pc = ProbeConfig(d_phi=8, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(4))
    theta["b0"] = jnp.asarray(1.5)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=2.0,
                      burn_in=2)

    def reqs():
        out = replay_requests([t] * n)
        for i, r in enumerate(out):
            r.group_id, r.sample_idx = int(i // gsz), int(i % gsz)
        return out

    runs = {}
    for tree in (None, "2.2"):
        sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=4,
                              paged=True, block_size=4, consensus=0.8,
                              spec_tree=tree)
        done, fleet = sched.run(reqs())
        runs[tree] = (done, fleet, sched.groups)
        assert fleet.consensus_groups == n_groups
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()
    done_o, fleet_o, grp_o = runs[None]
    done_s, fleet_s, grp_s = runs["2.2"]
    assert [r.state for r in done_s] == [r.state for r in done_o]
    # the group OUTCOMES are invariant; a doomed sibling's score count at
    # the cancel instant is not (partial acceptance de-phases siblings,
    # so the cancel lands mid-block) — the contract covers survivors
    assert ([ (g.consensus_answer, g.consensus_agreement) for g in grp_s]
            == [(g.consensus_answer, g.consensus_agreement) for g in grp_o])
    assert fleet_s.samples_cancelled == fleet_o.samples_cancelled
    for rs_, ro in zip(done_s, done_o):
        if ro.state is not RequestState.CANCELLED:
            assert rs_.stop_step == ro.stop_step
            np.testing.assert_array_equal(np.asarray(rs_.scores),
                                          np.asarray(ro.scores))
    live = [r for r in done_s if r.state is not RequestState.CANCELLED]
    assert fleet_s.tree_nodes_proposed == sum(r.tree_nodes for r in live)
    cancelled = [r for r in done_s if r.state is RequestState.CANCELLED]
    assert cancelled and any(r.tree_nodes for r in cancelled)


def test_tree_forced_preemption_is_stop_invariant():
    """Tree fleets under REAL contention: mid-tree-verify residents are
    spilled AND restored, stops stay byte-identical to the abundant
    no-spec run."""
    n, t, d = 9, 24, 16
    rs = np.random.RandomState(0)
    drift = np.linspace(0, 1.2, t)[None, :, None]
    bank = (rs.randn(n, t, d) * 0.3
            + drift * rs.rand(n, 1, d)).astype(np.float32)
    theta = {"W0": (rs.randn(d) * 0.4).astype(np.float32),
             "b0": np.float32(-0.2)}
    pc = ProbeConfig(d_phi=d, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=3)
    blocks_per_req = -(-(1 + t) // 4)

    def fleet(n_slots, tree, num_blocks, wrong):
        sched = OrcaScheduler(replay_model(bank, draft_wrong_rate=wrong),
                              replay_params(bank), pc, theta, cfg,
                              n_slots=n_slots, paged=True, block_size=4,
                              num_blocks=num_blocks, spec_tree=tree)
        reqs = replay_requests([t] * n)
        for i, r in enumerate(reqs):
            r.priority = [1, 1, 1, 0, 0, 2, 2, 2, 2][i]
        return sched, reqs

    sched_a, reqs_a = fleet(n, None, 1 + n * blocks_per_req, 0.0)
    done_a, fleet_a = sched_a.run(reqs_a)
    assert fleet_a.preemptions == 0
    tau = served_stop_times(done_a, [t] * n)
    assert 0 < int((tau < t).sum()) < n
    sched_s, reqs_s = fleet(3, "2.2", 1 + 3 * blocks_per_req, 0.4)
    done_s, fleet_s = sched_s.run(reqs_s)
    assert fleet_s.preemptions > 0, "contention never materialized (vacuous)"
    assert fleet_s.restores == fleet_s.preemptions
    np.testing.assert_array_equal(served_stop_times(done_s, [t] * n), tau)
    assert fleet_s.tree_nodes_proposed > 0
    assert sched_s.pool.num_free == sched_s.pool.num_usable
    sched_s.pool.check()
    victims = [r for r in done_s if r.n_preempted > 0]
    assert victims and all(r.tree_nodes > 0 for r in victims)


def test_tree_engine_spill_restore_bit_for_bit():
    """Engine-level: preempting a mid-tree-verify slot and restoring it
    into a DIFFERENT physical slot replays the identical multi-token
    future, node count and all."""
    rs = np.random.RandomState(1)
    bank = (rs.randn(4, 20, 16) * 0.5).astype(np.float32)
    pc = ProbeConfig(d_phi=16, smooth_window=3)
    theta = init_outer(pc, jax.random.PRNGKey(1))

    def make():
        cfg = ServeConfig(tokens_per_step=1, max_new_tokens=20, lam=0.9,
                          burn_in=1)
        return ContinuousServingEngine(
            replay_model(bank, draft_wrong_rate=0.3), replay_params(bank),
            pc, theta, cfg, n_slots=3, cache_len=26, spec_tree=(2, 2))
    eng_a, eng_b = make(), make()
    kk = 1 + 2 * 2
    lens = np.asarray([kk, kk, 0], np.int32)
    for eng in (eng_a, eng_b):
        eng.admit(0, {"tokens": jnp.full((1, 1), 0, jnp.int32)}, 1)
        eng.admit(1, {"tokens": jnp.full((1, 1), 1, jnp.int32)}, 1)
        for _ in range(2):
            eng.step(spec_lens=lens)
    pos_before = int(eng_a.pos[0])
    spill = eng_a.preempt(0)
    assert spill.pos == pos_before
    eng_a.restore(2, spill)
    assert int(eng_a.pos[2]) == pos_before
    lens_a = np.asarray([0, kk, kk], np.int32)
    for i in range(4):
        va = eng_a.step(spec_lens=lens_a)
        vb = eng_b.step(spec_lens=lens)
        for f in ("gen", "seq", "seq_scores", "seq_n", "stopped",
                  "stop_step", "n_scores", "tokens"):
            np.testing.assert_array_equal(
                np.asarray(getattr(va, f))[2], np.asarray(getattr(vb, f))[0],
                err_msg=f"step {i}: {f} diverged after restore")
            np.testing.assert_array_equal(
                np.asarray(getattr(va, f))[1], np.asarray(getattr(vb, f))[1],
                err_msg=f"step {i}: {f} of the UNDISTURBED slot moved")


# ---------------------------------------------------------------------------
# the shared draft cache in the serving loop

def test_draft_cache_feeds_fleet_and_keeps_stops_identical():
    """An injected draft cache fronting the replay drafter: hit/miss
    mixes serve through ONE executable, stops stay byte-identical, and
    the hit/miss counters surface in the fleet metrics."""
    model, params, pc, theta, cfg, bank = _tree_setup(wrong=0.6)
    ids = list(range(bank.shape[0])) * 2      # repeat traffic -> cache hits
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3)
    done_o, _ = base.run(_reqs(bank, ids))
    dc = DraftCache(capacity=256, ngram=3)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                          spec_tree="2.2", draft_cache=dc)
    assert sched.draft_cache is dc
    done_s, fleet = sched.run(_reqs(bank, ids))
    _assert_identical(done_o, done_s)
    assert dc.hits + dc.misses > 0
    assert fleet.draft_cache_hits == dc.hits > 0
    assert fleet.draft_cache_misses == dc.misses
    assert fleet.draft_cache_hit_rate == pytest.approx(dc.hit_rate)
    counts = sched._engine.compile_counts()
    assert counts["step"] == 1, counts


def test_draft_cache_not_created_without_speculation():
    model, params, pc, theta, cfg, bank = _tree_setup()
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
    assert sched.draft_cache is None


# ---------------------------------------------------------------------------
# satellite: scheduler and router aggregate through the SAME helper

def _metrics_spec_fields(m):
    return {k: getattr(m, k)
            for k in ("spec_tokens_proposed", "spec_tokens_accepted",
                      "acceptance_rate", "accepted_len_p50",
                      "accepted_len_p99", "tree_nodes_proposed",
                      "tree_path_accepted_p50", "tree_path_accepted_p99",
                      "draft_cache_hits", "draft_cache_misses",
                      "draft_cache_hit_rate")}


def test_scheduler_and_router_share_spec_aggregation():
    """Both the scheduler's ``_metrics`` and the router's ``_aggregate``
    must be ``spec_stats`` verbatim: their FleetMetrics spec fields equal
    the helper applied to their own done lists, scheduler == router for
    the same traffic."""
    model, params, pc, theta, cfg, bank = _tree_setup(wrong=0.4)
    ids = range(bank.shape[0])
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                          spec_tree="2.2")
    done_s, fleet_s = sched.run(_reqs(bank, ids))
    assert _metrics_spec_fields(fleet_s) == spec_stats(done_s)
    rcfg = dataclasses.replace(cfg, spec_tree="2.2")
    router = FleetRouter(model, params, pc, theta, rcfg, n_hosts=2,
                         parallel_hosts=False)
    done_r, fleet_r = router.run(_reqs(bank, ids))
    assert _metrics_spec_fields(fleet_r) == spec_stats(done_r)
    # same traffic, one host vs two: the union-level counters agree
    assert (fleet_r.spec_tokens_accepted + fleet_r.spec_tokens_proposed) > 0


def test_router_shares_one_draft_cache_across_hosts():
    """The router's cache is the prefix-registry pattern: ONE object,
    every host scheduler holds the same reference (a continuation
    accepted on host 0 drafts for host 1)."""
    model, params, pc, theta, cfg, bank = _tree_setup()
    # replay has self_draft=False -> the router creates no cache ...
    rcfg = dataclasses.replace(cfg, spec_tree="2.2")
    router = FleetRouter(model, params, pc, theta, rcfg, n_hosts=2,
                         parallel_hosts=False)
    assert router.draft_cache is None
    # ... but a self-draft family gets exactly one, shared by reference
    scfg = get_config("smollm_360m").reduced()
    dense = build(scfg)
    assert dense.self_draft
    pc2 = ProbeConfig(d_phi=scfg.d_model, smooth_window=2)
    theta2 = init_outer(pc2, jax.random.PRNGKey(1))
    dcfg = ServeConfig(tokens_per_step=2, max_new_tokens=6, lam=0.6,
                       burn_in=1, spec_tree="2.2", n_slots=2)
    router2 = FleetRouter(dense, None, pc2, theta2, dcfg, n_hosts=2,
                          parallel_hosts=False)
    assert isinstance(router2.draft_cache, DraftCache)
    assert all(h.draft_cache is router2.draft_cache for h in router2.hosts)


# ---------------------------------------------------------------------------
# real model: tree decode == one-token decode through the dense family

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def int8_model():
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _prompts(mcfg, lens, seed=31):
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               mcfg.vocab_size)
            for i, L in enumerate(lens)]


_ORACLE_CACHE = {}


def _oracle(model, params):
    key = id(model)
    if key not in _ORACLE_CACHE:
        pc, theta = _probe(model.cfg, 1.5)
        cfg = ServeConfig(tokens_per_step=2, max_new_tokens=14, lam=0.6,
                          burn_in=1)
        prompts = _prompts(model.cfg, [5, 9, 3, 12, 7])
        sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
        _ORACLE_CACHE[key] = sched.run([make_request(p) for p in prompts])
    return _ORACLE_CACHE[key]


@pytest.mark.parametrize("tree,paged,chunk,policy,pack,int8", [
    ("2.2", False, None, "fifo", False, False),  # pure tree decode
    ("3.2", False, 8, "priority", True, False),
    ("2.2", True, None, "fifo", False, False),
    ("2.3", True, 8, "ttft", True, False),
    ("2.2", True, None, "fifo", False, True),    # int8 KV
    ("1.3", True, 8, "priority", True, True),    # width-1 == linear, int8
])
def test_tree_stops_match_one_token_matrix(small_model, int8_model, tree,
                                           paged, chunk, policy, pack, int8):
    """spec_tree serves the SAME stop decisions, token streams and (to fp
    tolerance) score trajectories as one-token decode across tree shape x
    policy x packing x paged x int8 — through ONE step executable, with
    every page back in the pool (off-path rollback never leaks)."""
    model, params = int8_model if int8 else small_model
    done_o, _ = _oracle(model, params)
    pc, theta = _probe(model.cfg, 1.5)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=14, lam=0.6,
                      burn_in=1)
    prompts = _prompts(model.cfg, [5, 9, 3, 12, 7])
    kw = dict(n_slots=2, spec_tree=tree, chunk_tokens=chunk, policy=policy,
              pack_chunks=pack)
    if chunk:
        kw["token_budget"] = 14
    if paged:
        kw.update(paged=True, block_size=4, num_blocks=64)
    sched = OrcaScheduler(model, params, pc, theta, cfg, **kw)
    done_s, fleet = sched.run([make_request(p) for p in prompts])
    # int8: same ulp-vs-quantization-bucket story as the linear matrix
    _assert_identical(done_o, done_s, exact_scores=False,
                      atol=(2e-2 if int8 else 1e-4))
    counts = sched._engine.compile_counts()
    assert counts["step"] == 1, counts
    if chunk:
        assert fleet.peak_step_tokens <= 14
    if paged:
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()
        assert sched.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# sweep: tree shape x budget x packing x paged

def _tree_sweep_case(width, depth, budget, pack, paged, lens):
    """Serving invariants under arbitrary (tree shape, budget, packing,
    paged, queue): the token budget is NEVER exceeded, ``pos`` only moves
    forward, stops are byte-equal to the unsped oracle, pages drain."""
    model, params, pc, theta, cfg, bank = _tree_setup(wrong=0.4)
    n_slots = 3
    kk = 1 + width * depth
    chunk = max(kk + 1, 4)
    budget = max(budget, n_slots, kk, chunk)
    ids = [L % bank.shape[0] for L in lens]
    oracle = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots)
    done_o, _ = oracle.run(_reqs(bank, ids))
    kw = dict(n_slots=n_slots, spec_tree=f"{width}.{depth}",
              chunk_tokens=chunk, token_budget=budget, pack_chunks=pack)
    if paged:
        kw.update(paged=True, block_size=4)
    sched = OrcaScheduler(model, params, pc, theta, cfg, **kw)
    sched.submit(_reqs(bank, ids))
    last_pos = None
    while sched.step():
        pos = np.asarray(sched._engine.pos).copy()
        if last_pos is not None:
            assert (pos >= last_pos).all() or (pos == 0)[pos < last_pos].all()
        # slots only rewind at release/admit (pos reset to 0, re-armed)
        last_pos = pos
    done_s, fleet = sched.drain()
    _assert_identical(done_o, done_s)
    assert fleet.peak_step_tokens <= budget
    assert fleet.spec_tokens_proposed >= fleet.spec_tokens_accepted
    assert fleet.tree_nodes_proposed >= 0
    if paged:
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()


@pytest.mark.parametrize("width,depth,budget,pack,paged,lens", [
    (2, 2, 3, False, False, [1, 2, 3]),      # budget == n_slots: no extras
    (2, 3, 16, True, True, [9, 1, 5, 7]),    # roomy budget, full trees
    (3, 2, 8, True, False, [4, 4, 4, 4, 4]), # tight budget throttles trees
    (1, 4, 9, False, True, [8, 3, 9, 1, 6, 2]),
    (2, 2, 7, True, True, [7, 7, 1, 3]),
])
def test_tree_sweep_explicit_cases(width, depth, budget, pack, paged, lens):
    """Pinned corners of the sweep space — runs even without the optional
    ``hypothesis`` dependency (the property test below skips there)."""
    _tree_sweep_case(width, depth, budget, pack, paged, lens)


@settings(max_examples=8, deadline=None)
@given(width=st.integers(1, 3), depth=st.integers(1, 3),
       budget=st.integers(3, 16), pack=st.booleans(), paged=st.booleans(),
       lens=st.lists(st.integers(1, 9), min_size=3, max_size=7))
def test_tree_sweep_invariants(width, depth, budget, pack, paged, lens):
    _tree_sweep_case(width, depth, budget, pack, paged, lens)
