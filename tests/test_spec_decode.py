"""Speculative draft-verify decode riding the unified packed-chunk step.

The tentpole invariants: the masked multi-token probe kernel equals its
chained one-token oracle (bitwise for stop decisions, to tolerance for
floats) for every accepted-length corner; serving with ``spec_tokens=k``
produces IDENTICAL stop decisions, token streams and score trajectories
to one-token decode across policy x packing x paged x int8 x forced
preemption x grouped consensus; rejected drafts leave no orphaned pages;
the token budget is never exceeded and ``pos`` only moves forward; ONE
step executable covers every draft length; and the replay model's
self-draft reaches 100% acceptance (the throughput upper bound the
benchmark gates on).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.kernels import ref as R
from repro.kernels.ttt_probe import serving_probe_spec_step, serving_probe_step
from repro.models import build
from repro.serving import (ContinuousServingEngine, OrcaScheduler,
                           RequestState, ServeConfig, make_request,
                           replay_model, replay_params, replay_requests,
                           served_stop_times)

from tests._hypothesis_stub import given, settings, st


# ---------------------------------------------------------------------------
# masked spec probe kernel vs the chained one-token oracle

def _fresh_state(batch, f, window, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    W = jax.random.normal(ks[0], (batch, f)) / np.sqrt(f)
    b = jax.random.normal(ks[1], (batch,)) * 0.2
    return (W, b, jnp.zeros((batch, window)), jnp.zeros((batch,), jnp.int32),
            jnp.zeros((batch,), bool), jnp.full((batch,), -1, jnp.int32))


def _accepts(mode, batch, k):
    if mode == "mixed":
        return jnp.asarray([(i * 3) % (k + 1) for i in range(batch)],
                           jnp.int32)
    n = {"zero": 0, "one": 1, "km1": k - 1, "k": k}[mode]
    return jnp.full((batch,), n, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("mode", ["zero", "one", "km1", "k", "mixed"])
def test_spec_kernel_matches_ref(dtype, mode):
    """Masked spec kernel vs the chained ``serving_probe_step_ref`` oracle
    over every accepted-length corner {0, 1, k-1, k} and a mixed vector:
    stop decisions / counters bitwise equal, floats to tolerance (same
    contract as the one-token parity suite)."""
    batch, k, f, window = 5, 4, 64, 3
    eta, lam = jnp.asarray(0.06), jnp.asarray(0.52)
    state = _fresh_state(batch, f, window, seed=11)
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (batch, k, f)) * 0.4
    if dtype == jnp.int8:
        z = (z * 30).astype(jnp.int8)       # both impls cast to f32
    else:
        z = z.astype(dtype)
    bnd = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7, (batch, k))
    accept = _accepts(mode, batch, k)
    out_k = serving_probe_spec_step(z, z, bnd, accept, *state, eta, lam,
                                    burn_in=1)
    out_r = R.serving_probe_spec_step_ref(z, z, bnd, accept, *state, eta,
                                          lam, burn_in=1)
    for fld in ("n_seq", "n_scores", "stopped", "stop_step"):
        np.testing.assert_array_equal(np.asarray(getattr(out_k, fld)),
                                      np.asarray(getattr(out_r, fld)),
                                      err_msg=f"{mode}/{dtype}: {fld}")
    for fld in ("s", "smoothed_seq", "W", "b", "ring", "smoothed"):
        np.testing.assert_allclose(np.asarray(getattr(out_k, fld)),
                                   np.asarray(getattr(out_r, fld)),
                                   atol=1e-5, err_msg=f"{mode}/{dtype}: {fld}")


@pytest.mark.parametrize("mode", ["zero", "one", "km1", "k", "mixed"])
def test_spec_kernel_equals_chained_one_token_kernel(mode):
    """The acceptance invariant held BITWISE against the production
    kernel: one spec call with ``accept[i] = a`` leaves slot i in exactly
    the state of ``a`` sequential one-token kernel calls."""
    batch, k, f, window = 4, 5, 64, 3
    eta, lam = jnp.asarray(0.08), jnp.asarray(0.5)
    state0 = _fresh_state(batch, f, window, seed=7)
    key = jax.random.PRNGKey(9)
    z = jax.random.normal(key, (batch, k, f)) * 0.5
    bnd = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.8, (batch, k))
    accept = _accepts(mode, batch, k)
    out = serving_probe_spec_step(z, z, bnd, accept, *state0, eta, lam,
                                  burn_in=1)
    state = state0
    for t in range(k):
        bt = bnd[:, t] & (t < accept)
        o = serving_probe_step(z[:, t], z[:, t], bt, *state, eta, lam,
                               burn_in=1)
        state = (o.W, o.b, o.ring, o.n_scores, o.stopped, o.stop_step)
        np.testing.assert_array_equal(np.asarray(out.n_seq[:, t]),
                                      np.asarray(o.n_scores))
        np.testing.assert_array_equal(np.asarray(out.smoothed_seq[:, t]),
                                      np.asarray(o.smoothed))
    final = dict(zip(("W", "b", "ring", "n_scores", "stopped", "stop_step"),
                     state))
    final["smoothed"] = o.smoothed
    for fld, want in final.items():
        np.testing.assert_array_equal(np.asarray(getattr(out, fld)),
                                      np.asarray(want), err_msg=fld)


def test_spec_kernel_accept_zero_is_noop():
    """accept == 0 everywhere: pure no-op compute — state out is state in,
    bit for bit (the parked-slot contract of the verify step)."""
    batch, k, f = 3, 4, 64
    state = _fresh_state(batch, f, 4, seed=5)
    z = jax.random.normal(jax.random.PRNGKey(1), (batch, k, f))
    bnd = jnp.ones((batch, k), bool)
    out = serving_probe_spec_step(z, z, bnd, jnp.zeros((batch,), jnp.int32),
                                  *state, jnp.asarray(0.1), jnp.asarray(0.5),
                                  burn_in=0)
    for fld, want in zip(("W", "b", "ring", "n_scores", "stopped",
                          "stop_step"), state):
        np.testing.assert_array_equal(np.asarray(getattr(out, fld)),
                                      np.asarray(want), err_msg=fld)


# ---------------------------------------------------------------------------
# ServeConfig validation + unsupported-family fallback

def test_spec_tokens_config_validation():
    with pytest.raises(ValueError, match="spec_tokens=1 must be >= 2"):
        ServeConfig(spec_tokens=1)
    with pytest.raises(ValueError, match="spec_tokens=8 >= chunk_tokens=8"):
        ServeConfig(spec_tokens=8, chunk_tokens=8)
    with pytest.raises(ValueError, match="spec_tokens=9 > token_budget=8"):
        ServeConfig(spec_tokens=9, token_budget=8)
    # the CLI's 0-for-disabled normalizes to None like the other optionals
    assert ServeConfig(spec_tokens=0).spec_tokens is None
    assert ServeConfig(spec_tokens=4, chunk_tokens=8,
                       token_budget=12).spec_tokens == 4


def test_spec_tokens_warns_and_falls_back_without_support():
    """A family without draft/verify serves one-token decode under a
    RuntimeWarning naming the fix (the chunk_tokens fallback contract)."""
    cfg = get_config("rwkv6_1b6").reduced()
    model = build(cfg)
    assert not model.supports_spec
    pc = ProbeConfig(d_phi=cfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    scfg = ServeConfig(tokens_per_step=2, max_new_tokens=6, lam=2.0,
                       burn_in=0, spec_tokens=4)
    with pytest.warns(RuntimeWarning, match="spec_tokens=4 ignored"):
        sched = OrcaScheduler(model, None, pc, theta, scfg, n_slots=2)
    assert sched.spec_tokens is None


# ---------------------------------------------------------------------------
# replay fleets: byte-identical serving + 100% self-draft acceptance

def _replay_setup(seed=0, n=10, t=16, d=16, prompt_len=4):
    rs = np.random.RandomState(seed)
    bank = (rs.randn(n, t, d) * 0.6).astype(np.float32)
    model = replay_model(bank, prompt_len=prompt_len)
    params = replay_params(bank)
    pc = ProbeConfig(d_phi=d, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(2))
    theta["b0"] = jnp.asarray(0.4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=2)
    return model, params, pc, theta, cfg, bank


def _replay_reqs(bank, ids, prompt_len=4):
    return [make_request(np.full((prompt_len,), i, np.int64),
                         max_new_tokens=int(bank.shape[1]))
            for i in ids]


def _assert_identical(done_a, done_b, *, exact_scores=True, atol=1e-4):
    assert [r.stop_step for r in done_a] == [r.stop_step for r in done_b]
    assert [r.steps_run for r in done_a] == [r.steps_run for r in done_b]
    assert [r.tokens for r in done_a] == [r.tokens for r in done_b]
    for ra, rb in zip(done_a, done_b):
        a, b = np.asarray(ra.scores), np.asarray(rb.scores)
        if exact_scores:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, atol=atol)


def test_replay_spec_serves_identical_and_accepts_all():
    """Replay self-draft: stops, tokens and scores byte-equal to one-token
    decode, 100% acceptance, strictly fewer engine steps — the speedup is
    real work saved, not bookkeeping."""
    model, params, pc, theta, cfg, bank = _replay_setup()
    reqs = lambda: _replay_reqs(bank, range(bank.shape[0]))
    base = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3)
    done_o, fleet_o = base.run(reqs())
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                          spec_tokens=4)
    done_s, fleet_s = sched.run(reqs())
    _assert_identical(done_o, done_s)
    assert fleet_s.acceptance_rate == 1.0
    assert fleet_s.spec_tokens_proposed == fleet_s.spec_tokens_accepted > 0
    assert fleet_s.accepted_len_p50 == 4.0
    assert fleet_s.engine_steps < fleet_o.engine_steps
    assert fleet_s.tokens_per_s > 0
    # fleet counters == the per-request counters they aggregate
    assert fleet_s.spec_tokens_proposed == sum(r.spec_proposed
                                               for r in done_s)
    assert fleet_s.spec_tokens_accepted == sum(r.spec_accepted
                                               for r in done_s)


def test_spec_consensus_groups_identical_and_cancelled_excluded():
    """Grouped consensus fleet under spec decode: the same groups fire at
    the same step, the same siblings cancel, and CANCELLED samples are
    excluded from the acceptance stats (the TTFT-tails contract)."""
    n_groups, gsz, t = 3, 3, 10
    n = n_groups * gsz
    rs = np.random.RandomState(6)
    drift = np.linspace(0, 1.0, t)[None, :, None]
    bank = (rs.randn(n, t, 8) * 0.3
            + drift * rs.rand(n, 1, 8)).astype(np.float32)
    answers = np.repeat(np.arange(n_groups), gsz)
    model = replay_model(bank, answers=answers)
    params = replay_params(bank, answers=answers)
    pc = ProbeConfig(d_phi=8, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(4))
    theta["b0"] = jnp.asarray(1.5)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=2.0,
                      burn_in=2)

    def reqs():
        out = replay_requests([t] * n)
        for i, r in enumerate(out):
            r.group_id, r.sample_idx = int(i // gsz), int(i % gsz)
        return out

    runs = {}
    for k in (0, 3):
        sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=4,
                              paged=True, block_size=4, consensus=0.8,
                              spec_tokens=(k or None))
        done, fleet = sched.run(reqs())
        runs[k] = (done, fleet, sched)
        assert fleet.consensus_groups == n_groups
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()
    done_o, fleet_o, _ = runs[0]
    done_s, fleet_s, _ = runs[3]
    assert [r.state for r in done_s] == [r.state for r in done_o]
    assert [len(r.scores) for r in done_s] == [len(r.scores) for r in done_o]
    assert fleet_s.consensus_steps == fleet_o.consensus_steps
    assert fleet_s.samples_cancelled == fleet_o.samples_cancelled
    live = [r for r in done_s if r.state is not RequestState.CANCELLED]
    assert fleet_s.spec_tokens_proposed == sum(r.spec_proposed for r in live)
    assert fleet_s.spec_tokens_accepted == sum(r.spec_accepted for r in live)
    cancelled = [r for r in done_s if r.state is RequestState.CANCELLED]
    assert cancelled and any(r.spec_proposed for r in cancelled)


def test_spec_forced_preemption_is_stop_invariant():
    """A spec fleet under REAL contention (mid-verify residents spilled
    AND restored) serves byte-identical stops to the abundant no-spec run
    — the ``Spill`` of a mid-verify slot round-trips exactly."""
    n, t, d = 9, 24, 16
    rs = np.random.RandomState(0)
    drift = np.linspace(0, 1.2, t)[None, :, None]
    bank = (rs.randn(n, t, d) * 0.3
            + drift * rs.rand(n, 1, d)).astype(np.float32)
    theta = {"W0": (rs.randn(d) * 0.4).astype(np.float32),
             "b0": np.float32(-0.2)}
    pc = ProbeConfig(d_phi=d, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=3)
    blocks_per_req = -(-(1 + t) // 4)

    def fleet(n_slots, spec, num_blocks):
        sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc,
                              theta, cfg, n_slots=n_slots, paged=True,
                              block_size=4, num_blocks=num_blocks,
                              spec_tokens=spec)
        reqs = replay_requests([t] * n)
        # batch traffic first, two urgent arrivals against a full fleet:
        # each spills the newest batch resident mid-verify
        for i, r in enumerate(reqs):
            r.priority = [1, 1, 1, 0, 0, 2, 2, 2, 2][i]
        return sched, reqs

    sched_a, reqs_a = fleet(n, None, 1 + n * blocks_per_req)
    done_a, fleet_a = sched_a.run(reqs_a)
    assert fleet_a.preemptions == 0
    tau = served_stop_times(done_a, [t] * n)
    assert 0 < int((tau < t).sum()) < n
    sched_s, reqs_s = fleet(3, 3, 1 + 3 * blocks_per_req)
    done_s, fleet_s = sched_s.run(reqs_s)
    assert fleet_s.preemptions > 0, "contention never materialized (vacuous)"
    assert fleet_s.restores == fleet_s.preemptions
    np.testing.assert_array_equal(served_stop_times(done_s, [t] * n), tau)
    assert fleet_s.spec_tokens_accepted > 0
    assert sched_s.pool.num_free == sched_s.pool.num_usable
    sched_s.pool.check()
    victims = [r for r in done_s if r.n_preempted > 0]
    assert victims and all(r.spec_proposed > 0 for r in victims)


def test_spec_engine_spill_restore_bit_for_bit():
    """Engine-level: preempting a mid-verify slot and restoring it into a
    DIFFERENT physical slot replays the identical multi-token future."""
    rs = np.random.RandomState(1)
    bank = (rs.randn(4, 20, 16) * 0.5).astype(np.float32)
    pc = ProbeConfig(d_phi=16, smooth_window=3)
    theta = init_outer(pc, jax.random.PRNGKey(1))

    def make():
        cfg = ServeConfig(tokens_per_step=1, max_new_tokens=20, lam=0.9,
                          burn_in=1)
        return ContinuousServingEngine(replay_model(bank),
                                       replay_params(bank), pc, theta, cfg,
                                       n_slots=3, cache_len=26,
                                       spec_tokens=3)
    eng_a, eng_b = make(), make()
    lens = np.asarray([3, 3, 0], np.int32)
    for eng in (eng_a, eng_b):
        eng.admit(0, {"tokens": jnp.full((1, 1), 0, jnp.int32)}, 1)
        eng.admit(1, {"tokens": jnp.full((1, 1), 1, jnp.int32)}, 1)
        for _ in range(2):
            eng.step(spec_lens=lens)
    pos_before = int(eng_a.pos[0])
    spill = eng_a.preempt(0)
    assert spill.pos == pos_before
    eng_a.restore(2, spill)
    assert int(eng_a.pos[2]) == pos_before
    lens_a = np.asarray([0, 3, 3], np.int32)
    for i in range(4):
        va = eng_a.step(spec_lens=lens_a)
        vb = eng_b.step(spec_lens=lens)
        for f in ("gen", "seq", "seq_scores", "seq_n", "stopped",
                  "stop_step", "n_scores", "tokens"):
            np.testing.assert_array_equal(
                np.asarray(getattr(va, f))[2], np.asarray(getattr(vb, f))[0],
                err_msg=f"step {i}: {f} diverged after restore")
            np.testing.assert_array_equal(
                np.asarray(getattr(va, f))[1], np.asarray(getattr(vb, f))[1],
                err_msg=f"step {i}: {f} of the UNDISTURBED slot moved")


# ---------------------------------------------------------------------------
# real model: the 8-config matrix, one executable, no orphaned pages

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def int8_model():
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              kv_cache_dtype="int8")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _prompts(mcfg, lens, seed=31):
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               mcfg.vocab_size)
            for i, L in enumerate(lens)]


_ORACLE_CACHE = {}


def _oracle(model, params):
    key = id(model)
    if key not in _ORACLE_CACHE:
        pc, theta = _probe(model.cfg, 1.5)
        cfg = ServeConfig(tokens_per_step=2, max_new_tokens=14, lam=0.6,
                          burn_in=1)
        prompts = _prompts(model.cfg, [5, 9, 3, 12, 7])
        sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=2)
        _ORACLE_CACHE[key] = sched.run([make_request(p) for p in prompts])
    return _ORACLE_CACHE[key]


@pytest.mark.parametrize("paged,chunk,policy,pack,int8", [
    (False, None, "fifo", False, False),   # pure spec decode, no chunking
    (False, 8, "fifo", True, False),
    (False, 8, "priority", False, False),
    (True, None, "fifo", False, False),
    (True, 8, "priority", True, False),
    (True, 8, "ttft", False, False),
    (True, None, "fifo", False, True),     # int8 KV
    (True, 8, "priority", True, True),
])
def test_spec_stops_match_one_token_matrix(small_model, int8_model, paged,
                                           chunk, policy, pack, int8):
    """spec_tokens=4 serves the SAME stop decisions, token streams and
    (to fp tolerance) score trajectories as one-token decode across
    policy x packing x paged x int8 — through ONE step executable, with
    every page back in the pool (rollback never leaks a block)."""
    model, params = int8_model if int8 else small_model
    done_o, _ = _oracle(model, params)
    pc, theta = _probe(model.cfg, 1.5)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=14, lam=0.6,
                      burn_in=1)
    prompts = _prompts(model.cfg, [5, 9, 3, 12, 7])
    kw = dict(n_slots=2, spec_tokens=4, chunk_tokens=chunk, policy=policy,
              pack_chunks=pack)
    if chunk:
        kw["token_budget"] = 12
    if paged:
        kw.update(paged=True, block_size=4, num_blocks=64)
    sched = OrcaScheduler(model, params, pc, theta, cfg, **kw)
    done_s, fleet = sched.run([make_request(p) for p in prompts])
    # int8 rows: the verify forward's K/V land a ulp off the one-token
    # forward's, and near a rounding boundary that ulp becomes a full
    # quantization bucket (~1/127 relative) — stops and tokens stay
    # exact, raw scores drift up to ~1e-2
    _assert_identical(done_o, done_s, exact_scores=False,
                      atol=(2e-2 if int8 else 1e-4))
    counts = sched._engine.compile_counts()
    assert counts["step"] == 1, counts
    if chunk:
        assert fleet.peak_step_tokens <= 12
    if paged:
        # rejected drafts rolled back: refcounts drain, no orphaned pages
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()
        assert sched.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# sweep: spec_tokens x budget x packing x paged

def _spec_sweep_case(spec, budget, pack, paged, lens):
    """Serving invariants under arbitrary (spec_tokens, budget, packing,
    paged, queue): the token budget is NEVER exceeded, ``pos`` only moves
    forward, stops are byte-equal to the unsped oracle, and pages drain."""
    model, params, pc, theta, cfg, bank = _replay_setup()
    n_slots = 3
    chunk = max(spec + 1, 4)
    budget = max(budget, n_slots, spec, chunk)
    ids = [L % bank.shape[0] for L in lens]
    oracle = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots)
    done_o, _ = oracle.run(_replay_reqs(bank, ids))
    kw = dict(n_slots=n_slots, spec_tokens=spec, chunk_tokens=chunk,
              token_budget=budget, pack_chunks=pack)
    if paged:
        kw.update(paged=True, block_size=4)
    sched = OrcaScheduler(model, params, pc, theta, cfg, **kw)
    sched.submit(_replay_reqs(bank, ids))
    last_pos = None
    while sched.step():
        pos = np.asarray(sched._engine.pos).copy()
        if last_pos is not None:
            assert (pos >= last_pos).all() or (pos == 0)[pos < last_pos].all()
        # slots only rewind at release/admit (pos reset to 0 then re-armed)
        last_pos = pos
    done_s, fleet = sched.drain()
    _assert_identical(done_o, done_s)
    assert fleet.peak_step_tokens <= budget
    assert fleet.spec_tokens_proposed >= fleet.spec_tokens_accepted
    if paged:
        assert sched.pool.num_free == sched.pool.num_usable
        sched.pool.check()


@pytest.mark.parametrize("spec,budget,pack,paged,lens", [
    (2, 3, False, False, [1, 2, 3]),        # budget == n_slots: no extras
    (4, 16, True, True, [9, 1, 5, 7]),      # roomy budget, full blocks
    (3, 5, True, False, [4, 4, 4, 4, 4]),   # tight budget throttles drafts
    (5, 9, False, True, [8, 3, 9, 1, 6, 2]),
    (4, 7, True, True, [7, 7, 1, 3]),
])
def test_spec_sweep_explicit_cases(spec, budget, pack, paged, lens):
    """Pinned corners of the sweep space — runs even without the optional
    ``hypothesis`` dependency (the property test below skips there)."""
    _spec_sweep_case(spec, budget, pack, paged, lens)


@settings(max_examples=10, deadline=None)
@given(spec=st.integers(2, 5), budget=st.integers(3, 16),
       pack=st.booleans(), paged=st.booleans(),
       lens=st.lists(st.integers(1, 9), min_size=3, max_size=7))
def test_spec_sweep_invariants(spec, budget, pack, paged, lens):
    _spec_sweep_case(spec, budget, pack, paged, lens)
